import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: lower one cell under a series of config variants
and report the three roofline terms + peak memory for each.

Usage:
  PYTHONPATH=src python -m benchmarks.perf_iter --arch llama3-405b \
      --shape train_4k --variants baseline,sqrt,sqrt_sp

Each named variant is a config-override dict; results append to
artifacts/perf/<arch>__<shape>.json so EXPERIMENTS.md §Perf can cite the
full iteration log (hypothesis -> change -> before -> after).
"""
import argparse
import json

from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, analyze
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh

VARIANTS = {
    "baseline": {},
    "remat_none": {"remat": "none"},
    "remat_block": {"remat": "block"},
    "remat_full": {"remat": "full"},
    "sqrt": {"remat": "sqrt"},
    "sp": {"sequence_parallel": True},
    "sqrt_sp": {"remat": "sqrt", "sequence_parallel": True},
    "full_sp": {"remat": "full", "sequence_parallel": True},
    "fourier_mixer": {"mixer": "fourier", "attention": "none",
                      "fourier_taps": 512},
    "moe_group_2048": {"moe_group_size": 2048},
    "moe_group_512": {"moe_group_size": 512},
    "moe_cap_1": {"capacity_factor": 1.0},
    "moe_bf16_dispatch": {"moe_dispatch_dtype": "bfloat16"},
    "bf16_reduce": {"reduce_dtype": "bfloat16"},
    "bf16_reduce_sqrt_sp": {"reduce_dtype": "bfloat16", "remat": "sqrt",
                            "sequence_parallel": True},
    "mixtral_best": {"reduce_dtype": "bfloat16", "remat": "sqrt",
                     "sequence_parallel": True, "moe_group_size": 512,
                     "grad_accum_steps": 4},
    "llama_best": {"reduce_dtype": "bfloat16", "remat": "sqrt",
                   "sequence_parallel": True, "grad_accum_steps": 8},
    "moe_combo": {"moe_dispatch_dtype": "bfloat16", "moe_group_size": 512,
                  "capacity_factor": 1.0, "sequence_parallel": True,
                  "remat": "sqrt"},
    "bf16_params": {"param_dtype": "bfloat16"},
    "sqrt_sp_accum4": {"remat": "sqrt", "sequence_parallel": True,
                       "grad_accum_steps": 4},
    "sqrt_sp_accum8": {"remat": "sqrt", "sequence_parallel": True,
                       "grad_accum_steps": 8},
    "full_sp_accum4": {"remat": "full", "sequence_parallel": True,
                       "grad_accum_steps": 4},
}


def run_variant(arch, shape, mesh, name) -> dict:
    res = run_cell(arch, shape, mesh, verbose=False,
                   overrides=VARIANTS[name])
    a = analyze(res)
    out = {"variant": name, "overrides": VARIANTS[name]}
    if a is None:
        out["status"] = res.get("status")
        return out
    out.update({k: a[k] for k in
                ("t_compute_s", "t_memory_s", "t_collective_s", "dominant",
                 "useful_flops_ratio", "peak_bytes_per_device", "hbm_ok")})
    # keep raw collective mix for the analysis narrative
    src = res.get("probe", res)
    out["collective_bytes"] = src["collective_bytes"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    os.makedirs("artifacts/perf", exist_ok=True)
    path = f"artifacts/perf/{args.arch}__{args.shape}.json"
    log = []
    if os.path.exists(path):
        with open(path) as f:
            log = json.load(f)
    for name in args.variants.split(","):
        r = run_variant(args.arch, args.shape, mesh, name)
        log.append(r)
        dom = r.get("dominant", "?")
        print(f"[perf] {args.arch}/{args.shape} variant={name}: "
              f"comp={r.get('t_compute_s', 0):.2e}s "
              f"mem={r.get('t_memory_s', 0):.2e}s "
              f"coll={r.get('t_collective_s', 0):.2e}s dom={dom} "
              f"peak={r.get('peak_bytes_per_device', 0) / 1e9:.1f}GB "
              f"useful={r.get('useful_flops_ratio', 0):.2f}")
        with open(path, "w") as f:
            json.dump(log, f, indent=2)


if __name__ == "__main__":
    main()
