"""Multi-pod vs single-pod comparison (proof the pod axis shards).

For every cell present on both meshes, reports per-device argument bytes
(FSDP params should shrink going 256 -> 512 devices) and the cross-pod
collective footprint. Emits CSV + a short markdown summary.
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import load_cells
from benchmarks.runlib import emit


def run(markdown: bool = False):
    single = {(c["arch"], c["shape"]): c for c in load_cells("singlepod")}
    multi = {(c["arch"], c["shape"]): c for c in load_cells("multipod")}
    rows = []
    for key in sorted(single):
        a, s = key
        c1, c2 = single[key], multi.get(key)
        if c2 is None or c1.get("status") != "ok" or c2.get("status") != "ok":
            continue
        r = {
            "arch": a, "shape": s,
            "arg_bytes_1pod": c1["argument_bytes_per_device"],
            "arg_bytes_2pod": c2["argument_bytes_per_device"],
            "arg_ratio": (c2["argument_bytes_per_device"]
                          / max(1, c1["argument_bytes_per_device"])),
            "peak_ratio": (c2["peak_bytes_per_device"]
                           / max(1, c1["peak_bytes_per_device"])),
        }
        rows.append(r)
        emit(f"multipod/{a}/{s}", 0.0,
             f"arg_ratio={r['arg_ratio']:.2f};peak_ratio={r['peak_ratio']:.2f}")
    if markdown and rows:
        train = [r for r in rows if r["shape"] == "train_4k"]
        print("\n| arch (train_4k) | args/dev 1-pod | args/dev 2-pod | ratio |")
        print("|---|---|---|---|")
        for r in train:
            print(f"| {r['arch']} | {r['arg_bytes_1pod'] / 1e9:.2f} GB | "
                  f"{r['arg_bytes_2pod'] / 1e9:.2f} GB | "
                  f"{r['arg_ratio']:.2f} |")
    return rows


if __name__ == "__main__":
    run(markdown=True)
