"""TPU-native Fourier-core benchmark (beyond-paper §Perf evidence).

Two parts:
 1. Wall-clock (CPU, XLA path) for the batched FFT / fused polymul at the
    paper's dimensions — us_per_call CSV (structure check: O(n log n)).
 2. Structural HBM-pass accounting for the Pallas kernels: the VMEM-resident
    kernel does exactly 1 read + 1 write of the operands per transform
    (the paper's "in-memory" property), vs. log_r(n)-pass staged
    implementations. Derived column reports the single-pass memory-bound
    time on v5e (819 GB/s) — the roofline target the kernel is built to hit
    — and the pass ratio vs. a staged baseline.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.runlib import emit, time_jax
from repro.core import fft as F
from repro.kernels.fft import plan_batch_block

HBM_BW = 819e9
DIMS = (2048, 4096, 8192, 16384)


def hbm_passes_staged(n: int, radix_log2: int = 6) -> int:
    import math
    return max(1, math.ceil(math.log2(n) / radix_log2))


def run():
    rng = np.random.default_rng(0)
    for n in DIMS:
        B = 256
        x = jnp.asarray(rng.standard_normal((B, n))
                        + 1j * rng.standard_normal((B, n)), jnp.complex64)
        fft_fn = jax.jit(lambda v: F.fft(v, backend="xla"))
        us = time_jax(fft_fn, x)
        bytes_io = 2 * B * n * 8                       # one read + one write
        t_roof = bytes_io / HBM_BW * 1e6               # us, single-pass bound
        emit(f"tpu_fft/xla_cpu/n={n}/B={B}", us,
             f"v5e_single_pass_us={t_roof:.1f};"
             f"staged_passes={hbm_passes_staged(n)};our_passes=1;"
             f"vmem_batch_block={plan_batch_block(n)}")

        a = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
        pm_fn = jax.jit(lambda u, v: F.polymul(u, v, mode="circular",
                                               backend="xla"))
        us_pm = time_jax(pm_fn, a, b)
        # fused kernel: read a,b + write c = 3 arrays; unfused: 3 transforms
        # x 2 passes + pointwise 3 arrays
        fused_io = 3 * B * n * 4
        unfused_io = (3 * 2 + 3) * B * n * 8
        emit(f"tpu_polymul/xla_cpu/n={n}/B={B}", us_pm,
             f"v5e_fused_us={fused_io / HBM_BW * 1e6:.1f};"
             f"v5e_unfused_us={unfused_io / HBM_BW * 1e6:.1f};"
             f"fusion_traffic_ratio={unfused_io / fused_io:.1f}")


if __name__ == "__main__":
    run()
