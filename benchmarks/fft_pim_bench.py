"""Paper Figure 5: batched FFT throughput & energy, FourierPIM vs cuFFT.

Sweeps n in {2K, 4K, 8K, 16K} x {full, half} precision on FourierPIM-8/40
(partitions swept up to 2, matching the paper's evaluated partition count)
against the RTX 3070 and A100 cuFFT models. Emits CSV rows:

    fig5/<prec>/n=<n>/<device>, us_per_call, throughput=<per_s>;energy_uj=<uJ>
    fig5/<prec>/n=<n>/ratio,    0,           thr8_vs_3070=..x;thr40_vs_A100=..x;...

The ratio rows are what EXPERIMENTS.md validates against the paper's claimed
5-15x throughput / 4-13x energy bands.
"""
from __future__ import annotations

from benchmarks.runlib import emit
from repro.core.pim import (A100, FOURIERPIM_8, FOURIERPIM_40, FP16, FP32,
                            RTX3070, complex_word_bits, fft_energy_j_per_op,
                            fft_latency_cycles, fft_throughput_per_s,
                            gpu_model, with_partitions)

DIMS = (2048, 4096, 8192, 16384)
#: paper text: "a throughput improvement of up to 1.7x using only two
#: partitions"; the evaluation sweeps p in {1, 2}.
MAX_PARTITIONS = 2


def best_pim(n, base, spec):
    """Best valid (throughput, p) over the partition sweep (footnote 7
    restricts high partition counts at wide data layouts)."""
    word = complex_word_bits(spec)
    best, best_p = None, 1
    for p in (1, 2, 4):
        if p > MAX_PARTITIONS:
            continue
        cfg = with_partitions(base, p)
        if not cfg.valid_config(n, word):
            continue
        if cfg.crossbars_per_fft(n, word) > 2.0:
            continue  # scratch spill beyond a paired array: reject
        t = fft_throughput_per_s(n, cfg, spec)
        if best is None or t > best[0]:
            best, best_p = (t, cfg), p
    assert best is not None, f"no valid PIM config for n={n}"
    return best[0], best[1], best_p


def run() -> dict:
    """Returns {(prec, n): ratio-dict} for EXPERIMENTS.md validation."""
    out = {}
    for prec, spec, wbytes in (("full", FP32, 8), ("half", FP16, 4)):
        for n in DIMS:
            thr8, cfg8, p8 = best_pim(n, FOURIERPIM_8, spec)
            thr40, cfg40, p40 = best_pim(n, FOURIERPIM_40, spec)
            e_pim = fft_energy_j_per_op(n, cfg8, spec)
            g30 = gpu_model.fft_throughput_per_s(n, RTX3070, wbytes)
            ga = gpu_model.fft_throughput_per_s(n, A100, wbytes)
            e30 = gpu_model.fft_energy_j_per_op(n, RTX3070, wbytes)
            ea = gpu_model.fft_energy_j_per_op(n, A100, wbytes)
            lat_us = fft_latency_cycles(n, cfg8, spec) / cfg8.clock_hz * 1e6
            emit(f"fig5/{prec}/n={n}/FourierPIM-8(p{p8})", lat_us,
                 f"throughput={thr8:.3e};energy_uj={e_pim * 1e6:.2f}")
            emit(f"fig5/{prec}/n={n}/FourierPIM-40(p{p40})", lat_us,
                 f"throughput={thr40:.3e}")
            emit(f"fig5/{prec}/n={n}/RTX3070",
                 1e6 / g30, f"throughput={g30:.3e};energy_uj={e30 * 1e6:.2f}")
            emit(f"fig5/{prec}/n={n}/A100",
                 1e6 / ga, f"throughput={ga:.3e};energy_uj={ea * 1e6:.2f}")
            ratios = {
                "thr8_vs_3070": thr8 / g30,
                "thr40_vs_A100": thr40 / ga,
                "energy_vs_3070": e30 / e_pim,
                "energy_vs_A100": ea / e_pim,
            }
            emit(f"fig5/{prec}/n={n}/ratio", 0.0,
                 ";".join(f"{k}={v:.2f}x" for k, v in ratios.items()))
            out[(prec, n)] = ratios
    return out


if __name__ == "__main__":
    run()
