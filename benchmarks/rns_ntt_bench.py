"""Multi-limb RNS/CRT polymul sweep: the FHE-scale companion of ntt/*.

Sweeps target modulus widths (60..180 bits — the CKKS/BGV modulus-chain
range) at n in {1K..4K} and emits, per (bits, n):

    rns/n=<n>/Q<bits>b,  us_per_call,  limbs=..;waves=..;throughput=..
    rns/n=<n>/Q<bits>b/premium, 0,     rns_vs_single_word=..x

The latency row is the closed-form PIM wave schedule (k limbs over the
crossbar pool, ``rns_polymul_wave_stats``); the premium row is total RNS
cycles vs one single-word polymul at the same n — the structural cost of
exactness past one machine word (k limb transforms for a k-limb Q). A
bit-exact check of the fused limb-batched kernel against the python big-int
schoolbook oracle runs at a reduced size so the sweep can't silently rot.
"""
from __future__ import annotations

import numpy as np

from benchmarks.runlib import emit
from repro.core.ntt.rns import (RNSParams, random_poly, rns_polymul,
                                schoolbook_polymul_mod)
from repro.core.pim import (FOURIERPIM_8, INT32, ntt_polymul_latency_cycles,
                            rns_polymul_latency_cycles,
                            rns_polymul_wave_stats)

DIMS = (1024, 2048, 4096)
MODULUS_BITS = (60, 120, 180)


def exactness_check(n: int = 64, modulus_bits: int = 100) -> RNSParams:
    """Fused kernel == big-int schoolbook mod Q (negacyclic), tiny n."""
    rns = RNSParams.make(n, modulus_bits=modulus_bits)
    rng = np.random.default_rng(7)
    a = random_poly(rng, n, rns.modulus)
    b = random_poly(rng, n, rns.modulus)
    got = rns_polymul(a, b, rns)
    want = schoolbook_polymul_mod(a, b, rns.modulus)
    assert (got == want).all(), "RNS polymul mismatch vs big-int oracle"
    return rns


def run() -> dict:
    """Returns {(modulus_bits, n): row-dict} for tests / EXPERIMENTS.md."""
    out = {}
    rns_small = exactness_check()
    emit("rns/exact/n=64", 0.0,
         f"limbs={rns_small.k};Q_bits={rns_small.modulus.bit_length()}"
         f";exact=bit")
    for n in DIMS:
        single = ntt_polymul_latency_cycles(n, FOURIERPIM_8, INT32)
        for bits in MODULUS_BITS:
            rns = RNSParams.make(n, modulus_bits=bits)
            st = rns_polymul_wave_stats(n, rns.k, FOURIERPIM_8, INT32)
            lat_us = st["latency_s"] * 1e6
            emit(f"rns/n={n}/Q{bits}b", lat_us,
                 f"limbs={rns.k};waves={st['waves']}"
                 f";throughput={st['throughput_per_s']:.3e}"
                 f";utilization={st['utilization']:.2f}")
            total = rns_polymul_latency_cycles(n, rns.k, FOURIERPIM_8, INT32)
            emit(f"rns/n={n}/Q{bits}b/premium", 0.0,
                 f"rns_vs_single_word={total / single:.2f}x"
                 f";total_cycles={total}")
            out[(bits, n)] = {
                "limbs": rns.k,
                "waves": st["waves"],
                "latency_us": lat_us,
                "throughput_per_s": st["throughput_per_s"],
                "rns_vs_single_word": total / single,
            }
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
