"""Roofline analysis from the dry-run artifacts (deliverable (g)).

Per (arch x shape) on the single-pod mesh:
    compute term    = HLO_FLOPs_per_dev / peak_FLOPs          (197 TF bf16)
    memory term     = HLO_bytes_per_dev / HBM_bw              (819 GB/s)
    collective term = collective_bytes_per_dev / link_bw      (50 GB/s,
                      all-reduce counted 2x: reduce-scatter + all-gather)
plus the dominant term, MODEL_FLOPS = 6 N D (train) / 2 N D (prefill) /
2 N_active B + attention-KV flops (decode), and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs which exposes remat recompute and padding waste.

Reads artifacts/dryrun/<mesh>/ written by repro.launch.dryrun. Emits CSV
rows and (with --markdown) the EXPERIMENTS.md table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.runlib import emit
from repro.configs.registry import SHAPES, get_config
# Single source of truth for the machine model lives with the serving
# cost model (repro.core.cost) so the planner and this roofline can
# never drift apart; re-exported here for the existing callers.
from repro.core.cost import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: F401


def collective_term_from_ledger(led) -> float:
    """Seconds on the ICI link for traffic recorded by the
    ``repro.dist.collectives`` byte ledger — the shard_map code paths whose
    HLO the dry-run artifacts don't capture. psum counted 2x
    (reduce-scatter + all-gather halves), matching ``analyze``'s
    all-reduce accounting."""
    b = led.bytes_by_kind
    nbytes = (b["all-gather"] + b["all-to-all"] + b["ppermute"]
              + b["compressed-psum"] + 2 * b["psum"])
    return nbytes / LINK_BW


def model_flops_per_device(arch: str, shape_name: str, n_dev: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len

    def attn_flops(fwd_factor: float) -> float:
        """Score+value matmuls: 4 B S_eff S H hd per layer (x0.5 causal)."""
        if cfg.mixer not in ("attn", "hymba"):
            return 0.0
        s_eff = min(S, cfg.window) if cfg.attention == "swa" else S
        per_layer = 4.0 * B * S * s_eff * cfg.num_heads * cfg.head_dim * 0.5
        return fwd_factor * per_layer * cfg.num_layers

    if shape.kind == "train":
        total = 6.0 * N * B * S + attn_flops(3.0)   # fwd + 2x bwd
    elif shape.kind == "prefill":
        total = 2.0 * N * B * S + attn_flops(1.0)
    else:  # decode: one token across the batch
        total = 2.0 * N * B
        if cfg.mixer in ("attn", "hymba"):
            from repro.models.lm import cache_len
            s_eff = cache_len(cfg, S)
            total += (4.0 * B * cfg.num_heads * cfg.head_dim * s_eff
                      * cfg.num_layers)
    return total / n_dev


def load_cells(mesh_tag: str) -> list[dict]:
    pat = os.path.join("artifacts", "dryrun", mesh_tag, "*.json")
    cells = []
    for path in sorted(glob.glob(pat)):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyze(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    n_dev = 1
    for v in cell["mesh"].values():
        n_dev *= v
    # prefer the unrolled cost-probe numbers: the scanned lowering's
    # cost_analysis counts while bodies once (see dryrun._cost_probe)
    probe = cell.get("probe")
    if probe:
        cell = {**cell,
                "flops_per_device": probe["flops_per_device"],
                "bytes_accessed_per_device":
                    probe["bytes_accessed_per_device"],
                "collective_bytes": {**probe["collective_bytes"],
                                     "counts": {}}}
    coll = cell["collective_bytes"]
    coll_bytes = (coll["all-gather"] + coll["reduce-scatter"]
                  + coll["all-to-all"] + coll["collective-permute"]
                  + 2 * coll["all-reduce"])
    t_comp = cell["flops_per_device"] / PEAK_FLOPS
    t_mem = cell["bytes_accessed_per_device"] / HBM_BW
    t_coll = coll_bytes / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops_per_device(cell["arch"], cell["shape"], n_dev)
    useful = mf / max(cell["flops_per_device"], 1e-9)
    bound = max(t_comp, t_mem, t_coll)
    return {
        **{k: cell[k] for k in ("arch", "shape")},
        "n_dev": n_dev,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant[0],
        "model_flops_per_dev": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
        "peak_bytes_per_device": cell["peak_bytes_per_device"],
        "hbm_ok": cell["peak_bytes_per_device"] <= 16e9,
    }


def run(mesh_tag: str = "singlepod", markdown: bool = False) -> list[dict]:
    rows = []
    for cell in load_cells(mesh_tag):
        a = analyze(cell)
        if a is None:
            emit(f"roofline/{cell['arch']}/{cell['shape']}", 0.0,
                 f"status={cell['status']}")
            continue
        rows.append(a)
        emit(f"roofline/{a['arch']}/{a['shape']}",
             max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
             * 1e6,
             f"dominant={a['dominant']};comp={a['t_compute_s']:.2e}"
             f";mem={a['t_memory_s']:.2e};coll={a['t_collective_s']:.2e}"
             f";useful={a['useful_flops_ratio']:.2f}"
             f";frac={a['roofline_fraction']:.2f}")
    if markdown:
        print("\n| arch | shape | compute s | memory s | collective s | "
              "dominant | useful | roofline frac | peak GB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for a in rows:
            print(f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.2e} | "
                  f"{a['t_memory_s']:.2e} | {a['t_collective_s']:.2e} | "
                  f"{a['dominant']} | {a['useful_flops_ratio']:.2f} | "
                  f"{a['roofline_fraction']:.2f} | "
                  f"{a['peak_bytes_per_device'] / 1e9:.1f} |")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod",
                    choices=["singlepod", "multipod"])
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    run(args.mesh, args.markdown)
