"""Paper Figure 6: polynomial multiplication, FourierPIM vs cuFFT-based GPU.

(a, b): complex-coefficient polymul; (c, d): real-coefficient polymul with
the Eq. (10) packing. Dimensions index the transform size (degree-n/2 inputs
zero-padded to n, footnote 4) so both devices run identical transforms.
CSV format matches fft_pim_bench.
"""
from __future__ import annotations

from benchmarks.runlib import emit
from repro.core.pim import (A100, FOURIERPIM_8, FOURIERPIM_40, FP16, FP32,
                            RTX3070, complex_word_bits, gpu_model,
                            polymul_energy_j_per_op, polymul_latency_cycles,
                            polymul_throughput_per_s, with_partitions)
from benchmarks.fft_pim_bench import DIMS, MAX_PARTITIONS


def best_pim(n, base, spec, real):
    word = complex_word_bits(spec)
    best, best_p = None, 1
    for p in (1, 2, 4):
        if p > MAX_PARTITIONS:
            continue
        cfg = with_partitions(base, p)
        if not cfg.valid_config(n, word):
            continue
        t = polymul_throughput_per_s(n, cfg, spec, real=real)
        if best is None or t > best[0]:
            best, best_p = (t, cfg), p
    assert best is not None
    return best[0], best[1], best_p


def run() -> dict:
    out = {}
    for real, panel in ((False, "complex"), (True, "real")):
        for prec, spec, wbytes in (("full", FP32, 8), ("half", FP16, 4)):
            for n in DIMS:
                thr8, cfg8, p8 = best_pim(n, FOURIERPIM_8, spec, real)
                thr40, cfg40, p40 = best_pim(n, FOURIERPIM_40, spec, real)
                g30 = gpu_model.polymul_throughput_per_s(n, RTX3070, wbytes,
                                                         real=real)
                ga = gpu_model.polymul_throughput_per_s(n, A100, wbytes,
                                                        real=real)
                e_pim = polymul_energy_j_per_op(n, cfg8, spec, real=real)
                e30 = gpu_model.polymul_energy_j_per_op(n, RTX3070, wbytes,
                                                        real=real)
                ea = gpu_model.polymul_energy_j_per_op(n, A100, wbytes,
                                                       real=real)
                lat_us = (polymul_latency_cycles(n, cfg8, spec, real=real)
                          / cfg8.clock_hz * 1e6)
                emit(f"fig6/{panel}/{prec}/n={n}/FourierPIM-8(p{p8})", lat_us,
                     f"throughput={thr8:.3e};energy_uj={e_pim * 1e6:.2f}")
                emit(f"fig6/{panel}/{prec}/n={n}/RTX3070", 1e6 / g30,
                     f"throughput={g30:.3e};energy_uj={e30 * 1e6:.2f}")
                emit(f"fig6/{panel}/{prec}/n={n}/A100", 1e6 / ga,
                     f"throughput={ga:.3e};energy_uj={ea * 1e6:.2f}")
                ratios = {
                    "thr8_vs_3070": thr8 / g30,
                    "thr40_vs_A100": thr40 / ga,
                    "energy_vs_3070": e30 / e_pim,
                    "energy_vs_A100": ea / e_pim,
                }
                emit(f"fig6/{panel}/{prec}/n={n}/ratio", 0.0,
                     ";".join(f"{k}={v:.2f}x" for k, v in ratios.items()))
                out[(panel, prec, n)] = ratios
    return out


if __name__ == "__main__":
    run()
