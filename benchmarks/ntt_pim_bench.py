"""Exact modular polymul on PIM: NTT latency/throughput/energy sweep.

The crypto-workload companion of fig5/fig6: sweeps n in {2K..16K} for the
32-bit residue word (and 16-bit as the toy-modulus point) on FourierPIM-8,
partitions in {1, 2}, and emits

    ntt/<w>bit/n=<n>/p<p>,  us_per_call,  throughput=..;energy_uj=..
    ntt/<w>bit/n=<n>/ratio, 0,            exact_vs_float_polymul=..x;...

The ratio row is the *exactness premium*: cycles of the negacyclic modular
polymul vs the float (complex) FFT polymul at the same n. Integer
butterflies carry no IEEE special-case overhead but pay the quadratic
shift-and-add multiplier, so the premium is a structural output of the
AritPIM model, not a tuned constant (validated in tests/test_pim_ntt.py).
"""
from __future__ import annotations

from benchmarks.runlib import emit
from repro.core.pim import (FOURIERPIM_8, FP32, INT16, INT32,
                            ntt_energy_j_per_op, ntt_latency_cycles,
                            ntt_polymul_latency_cycles,
                            ntt_throughput_per_s, polymul_latency_cycles,
                            with_partitions)

DIMS = (2048, 4096, 8192, 16384)
MAX_PARTITIONS = 2


def run() -> dict:
    """Returns {(word_bits, n): row-dict} for tests / EXPERIMENTS.md."""
    out = {}
    for spec in (INT32, INT16):
        w = spec.word_bits
        for n in DIMS:
            best_thr, best_p = None, 1
            for p in (1, 2):
                if p > MAX_PARTITIONS:
                    continue
                cfg = with_partitions(FOURIERPIM_8, p)
                if 2 * max(1, n // (2 * cfg.crossbar_rows)) * w \
                        > cfg.crossbar_cols:
                    continue
                t = ntt_throughput_per_s(n, cfg, spec)
                if best_thr is None or t > best_thr:
                    best_thr, best_p = t, p
            cfg = with_partitions(FOURIERPIM_8, best_p)
            lat_us = ntt_latency_cycles(n, cfg, spec) / cfg.clock_hz * 1e6
            if spec is INT32:
                # simulator-counted energy needs an actual q ≡ 1 (mod 2n);
                # those exist below 2^30 for every n here, but not below
                # 2^16 — the 16-bit rows are pure cost-model what-ifs.
                e_uj = ntt_energy_j_per_op(n, cfg, spec) * 1e6
                derived = f"throughput={best_thr:.3e};energy_uj={e_uj:.3f}"
            else:
                e_uj = None
                derived = f"throughput={best_thr:.3e}"
            emit(f"ntt/{w}bit/n={n}/p{best_p}", lat_us, derived)
            pm_exact = ntt_polymul_latency_cycles(n, cfg, spec)
            pm_float = polymul_latency_cycles(n, cfg, FP32)
            emit(f"ntt/{w}bit/n={n}/ratio", 0.0,
                 f"exact_vs_float_polymul={pm_exact / pm_float:.2f}x"
                 f";polymul_cycles={pm_exact}")
            out[(w, n)] = {
                "throughput_per_s": best_thr,
                "latency_us": lat_us,
                "energy_uj": e_uj,
                "exact_vs_float_polymul": pm_exact / pm_float,
            }
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
