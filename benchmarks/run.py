"""Benchmark driver: one function per paper table/figure + the roofline.

Emits ``name,us_per_call,derived`` CSV rows.

  fig5/*      — paper Figure 5: batched FFT, FourierPIM vs cuFFT models
  fig6/*      — paper Figure 6: complex & real polynomial multiplication
  ntt/*       — exact modular polymul (NTT) latency/throughput/energy sweep
  tpu_fft/*   — TPU-native kernel path (beyond-paper; wall-clock + roofline)
  roofline/*  — per (arch x shape) three-term roofline from the dry-run
                artifacts (skipped if artifacts/dryrun is absent)

``--smoke`` runs a minutes-scale subset (one PIM cell through the
``repro.dist.batching`` scheduler, the exact-NTT path incl. a bit-exact
fused-polymul check, a tiny XLA FFT timing, and a ledger-accounted
distributed-FFT trace) so CI catches perf-harness bitrot without paying
for the full sweeps.
"""
from __future__ import annotations

import argparse
import os


def smoke() -> None:
    import numpy as np
    import jax
    import jax.numpy as jnp

    from benchmarks import roofline
    from benchmarks.runlib import emit, time_jax
    from repro.core import fft as F
    from repro.core.fft import distributed as dfft
    from repro.core.pim import FOURIERPIM_8, FP32
    from repro.core.pim.fft_pim import batched_fft_stats
    from repro.dist import collectives

    # 1. PIM closed-form throughput through the crossbar-batch scheduler
    #    (full wave + ragged batch so tail-wave utilization is exercised).
    full_wave = batched_fft_stats(2048, None, FOURIERPIM_8, FP32)
    arrays = full_wave["arrays_per_device"]
    ragged = batched_fft_stats(2048, arrays + arrays // 2, FOURIERPIM_8, FP32)
    for tag, stats in (("full", full_wave), ("ragged", ragged)):
        emit(f"smoke/pim_fft/n=2048/{tag}", stats["latency_s"] * 1e6,
             f"throughput={stats['throughput_per_s']:.3e}"
             f";waves={stats['waves']}"
             f";utilization={stats['utilization']:.2f}")

    # 2. Exact-NTT subsystem: closed-form throughput through the same wave
    #    scheduler, plus a bit-exact fused-polymul check vs the schoolbook
    #    oracle at a tiny n (kernel runs in interpret mode on CPU).
    from repro.core.ntt import NTTParams, schoolbook_polymul
    from repro.core.pim import INT32
    from repro.core.pim.ntt_pim import batched_ntt_stats
    from repro.kernels.ntt import ntt_polymul
    nstats = batched_ntt_stats(2048, None, FOURIERPIM_8, INT32)
    emit("smoke/pim_ntt/n=2048/full", nstats["latency_s"] * 1e6,
         f"throughput={nstats['throughput_per_s']:.3e}"
         f";waves={nstats['waves']}"
         f";utilization={nstats['utilization']:.2f}")
    params = NTTParams.make(64)
    rng_mod = np.random.default_rng(1)
    a = rng_mod.integers(0, params.q, (2, 64)).astype(np.uint32)
    b = rng_mod.integers(0, params.q, (2, 64)).astype(np.uint32)
    got = np.asarray(ntt_polymul(jnp.asarray(a), jnp.asarray(b), params))
    want = schoolbook_polymul(a, b, params.q, negacyclic=True)
    assert (got == want.astype(np.uint32)).all(), "NTT polymul mismatch"
    emit("smoke/ntt_polymul/n=64", 0.0, f"q={params.q};exact=bit")

    # 2b. Multi-limb RNS route: the limb-batched kernel must stay bit-exact
    #     against the python big-int schoolbook oracle (k limbs, Q > 2^100),
    #     and the limb wave schedule must go through dist.batching.
    from benchmarks import rns_ntt_bench
    from repro.core.pim import rns_polymul_wave_stats
    rns = rns_ntt_bench.exactness_check(n=64, modulus_bits=100)
    rst = rns_polymul_wave_stats(2048, rns.k, FOURIERPIM_8, INT32)
    emit("smoke/rns_polymul/n=64", 0.0,
         f"limbs={rns.k};Q_bits={rns.modulus.bit_length()};exact=bit"
         f";waves_at_2048={rst['waves']}")

    # 3. XLA FFT wall-clock at a reduced shape (structure check only).
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 1024))
                    + 1j * rng.standard_normal((8, 1024)), jnp.complex64)
    us = time_jax(jax.jit(lambda v: F.fft(v, backend="xla")), x)
    emit("smoke/tpu_fft/n=1024", us, "backend=xla")

    # 4. Distributed-FFT trace on a trivial mesh: the dist.collectives
    #    ledger must see the all-to-alls and price them on the link.
    mesh = jax.make_mesh((1,), ("model",))
    spec = jax.ShapeDtypeStruct((2, 256), jnp.complex64)
    with collectives.ledger() as led:
        jax.jit(dfft.make_sharded_fft(mesh, batch_axes=())).lower(spec)
    assert led.counts["all-to-all"] == 3, led.as_dict()
    emit("smoke/dist_fft/n=256", 0.0,
         f"a2a_bytes={led.bytes_by_kind['all-to-all']}"
         f";t_collective_s={roofline.collective_term_from_ledger(led):.3e}")

    # 5. Real-Hermitian fast path: the perf trajectory pin. Simulated-cycle
    #    ratio (paired-inverse real polymul vs complex, per product) is the
    #    hard gate — a ratio above 0.65 means the two-for-one packing or the
    #    paired inverse regressed, and the assert fails CI. Everything is
    #    also written to BENCH_fourier.json (machine-readable; uploaded as a
    #    CI artifact) so the trajectory is tracked from this PR onward.
    bench_fourier_smoke()
    print("smoke ok")


def serve_engine_smoke(requests: int = 36, max_batch: int = 8) -> dict:
    """Drive the continuous-batching engine in-process with a mixed
    (op, n) stream drawn from the op registry — the serve-layer harness
    check. Returns the record written into BENCH_fourier.json
    (``serve_p50_ms`` / ``serve_p99_ms`` / per-bucket utilization).

    The op mix is DERIVED from ``repro.launch.ops`` (every local float op),
    so a registry entry that stops binding breaks this smoke, not just the
    serve CLI.
    """
    import numpy as np

    from benchmarks.runlib import emit
    from repro.launch import ops as op_registry
    from repro.launch.engine import ServeEngine

    ops = [s.name for s in op_registry.registry()
           if not s.uses_modulus_bits and not s.uses_model_shards]
    ops.append("polymul-real")            # the headline serving op
    lens = (256, 512)
    engine = ServeEngine(max_batch=max_batch, max_pending=256)
    combos = [(op, n) for op in ops for n in lens]
    for op, n in combos:
        engine.register(op, n)
    engine.warmup()
    rng = np.random.default_rng(0)
    kept = {}
    for rid in range(requests):
        op, n = combos[rid % len(combos)]
        payload = engine.bound(op, n).random_payload(rng)
        if (op, n) not in kept:
            kept[(op, n)] = (rid, payload)
        engine.submit(op, n, payload, rid=rid)
    stats = engine.run(requests)
    assert stats["served"] == requests, stats
    for (op, n), (rid, payload) in kept.items():
        engine.bound(op, n).verify(payload, engine.results[rid])
    lat = stats["latency_ms"]
    util = {name: round(b["utilization"], 4)
            for name, b in stats["buckets"].items()}
    # tail batches must have executed at actual size (the engine asserts
    # row counts internally; re-assert the trace here so the artifact is
    # evidence, not trust)
    for name, b in stats["buckets"].items():
        assert all(1 <= s <= max_batch for s in b["batch_sizes"]), (name, b)
    emit(f"smoke/serve_engine/requests={requests}", 0.0,
         f"buckets={len(stats['buckets'])};p50_ms={lat['p50']:.2f}"
         f";p99_ms={lat['p99']:.2f}"
         f";throughput={stats['throughput_per_s']:.1f}")
    return {
        "op": "serve-engine", "requests": requests, "max_batch": max_batch,
        "buckets": len(stats["buckets"]),
        "serve_p50_ms": lat["p50"], "serve_p99_ms": lat["p99"],
        "throughput_per_s": stats["throughput_per_s"],
        "bucket_utilization": util,
    }


def auto_plan_agreement_smoke() -> dict:
    """Predicted-best vs measured-best tier agreement across the planner
    grid — the acceptance gate for the cost-model-driven auto planner
    (docs/planner.md).

    For every grid point, ``plan(n, batch, workload=...)``'s choice is
    re-derived from MEASURED quantities:

      * PIM cycle counts come from live ``CrossbarSim`` runs (the closed
        forms the model uses are asserted equal to the counters first);
      * distributed collective bytes come from a live ``dist.collectives``
        ledger trace of the actual sharded builders — every closed-form
        term is linear in the per-device block n/D, so the single-device
        trace divided by D IS the per-device traffic at D (asserted
        divisible);
      * the XLA on-chip roofline terms are shared by both sides (there is
        no hardware to measure in CI), so the comparison is decided by
        the measured cycles and bytes.

    The measured totals re-run the planner's argmin (same tie-break);
    predicted (tier, packing) must equal measured (tier, packing) on
    EVERY point. The agreement rate lands in BENCH_fourier.json and is
    ratcheted by benchmarks/trajectory.py (direction: max, i.e. 1.0
    forever)."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from benchmarks.runlib import emit
    from repro.core import cost as cost_lib
    from repro.core.fft import distributed as dfft
    from repro.core.fft.planner import plan
    from repro.core.ntt import NTTParams
    from repro.core.ntt import distributed as dntt
    from repro.core.pim import (FOURIERPIM_8, FP32, INT32, aritpim,
                                fft_pim, ntt_pim, polymul_pim)
    from repro.dist import collectives

    cfg = FOURIERPIM_8
    rng = np.random.default_rng(0)
    unpack = fft_pim.realpack_unpack_cycles(cfg, FP32)

    def sim_local_cycles(wl, n, batch):
        """Measured side of ``cost.pim_local_unit_cycles``: run the
        CrossbarSim and read the counter. ``wl`` is the effective PIM
        workload (complex fallbacks already mapped to fft/polymul)."""
        if wl == "fft":
            z = rng.standard_normal(n) + 1j * rng.standard_normal(n)
            return fft_pim.pim_fft(z, cfg, FP32).counters.cycles
        if wl == "rfft":
            return fft_pim.pim_rfft(rng.standard_normal(n),
                                    rng.standard_normal(n),
                                    cfg, FP32).counters.cycles
        if wl == "polymul":
            a = rng.standard_normal(n) + 1j * rng.standard_normal(n)
            b = rng.standard_normal(n) + 1j * rng.standard_normal(n)
            return polymul_pim.pim_polymul(a, b, cfg, FP32).counters.cycles
        if wl == "polymul-real":
            a = rng.standard_normal((batch, n))
            b = rng.standard_normal((batch, n))
            return polymul_pim.pim_polymul_real(a, b, cfg,
                                                FP32).counters.cycles
        params = NTTParams.make(n)
        a = rng.integers(0, params.q, n).astype(np.uint32)
        b = rng.integers(0, params.q, n).astype(np.uint32)
        return ntt_pim.pim_ntt_polymul(a, b, params, cfg,
                                       INT32).counters.cycles

    def sim_dist_cycles(wl, n, D):
        """Measured side of ``cost.pim_dist_unit_cycles``: per-shard
        transform cycles from the distributed sims; the polymul
        compositions substitute the measured transform into the model's
        own (analytic) glue, mirroring the closed forms exactly."""
        if wl == "polymul-mod":
            params = NTTParams.make(n)
            x = rng.integers(0, params.q, n).astype(np.uint32)
            ntt_meas = ntt_pim.pim_ntt_distributed(
                x, params, D, cfg, INT32).latency_cycles
            return 3 * ntt_meas + 4 * aritpim.mod_mul_cycles(INT32)
        r = fft_pim.pim_rfft_distributed(rng.standard_normal(n),
                                         rng.standard_normal(n),
                                         D, cfg, FP32)
        rfft_meas = max(c.cycles for c in r.shard_counters)
        fft_meas = rfft_meas - unpack     # counter before the split charge
        if wl == "fft":
            return fft_meas
        if wl == "rfft":
            return rfft_meas
        if wl == "polymul":
            return 3 * fft_meas + aritpim.complex_mul_cycles(FP32)
        assert wl == "polymul-real", wl
        return (3 * fft_meas + 2 * unpack
                + 2 * aritpim.complex_mul_cycles(FP32))

    def traced_dist_bytes(wl, n, batch, D, real):
        """Live ledger bytes of the actual sharded builder for one
        distributed candidate, traced at the REAL shard count on an
        AbstractMesh (no devices needed for a ``lower()`` trace, so the
        single-CPU smoke can measure the D=64 tier it plans for)."""
        mesh = jax.sharding.AbstractMesh((("model", D),))
        if wl == "polymul-mod":
            params = NTTParams.make(n)
            build = dntt.make_sharded_ntt_polymul(
                mesh, params, axis_name="model", batch_axes=())
            spec = jax.ShapeDtypeStruct((batch, n), jnp.uint32)
            args_ = (spec, spec)
        elif wl == "rfft" and real:
            build = dfft.make_sharded_rfft(mesh, batch_axes=())
            args_ = (jax.ShapeDtypeStruct((batch, n), jnp.float32),)
        elif wl == "polymul-real" and real:
            build = dfft.make_sharded_polymul_real(mesh, batch_axes=())
            spec = jax.ShapeDtypeStruct((batch, n), jnp.float32)
            args_ = (spec, spec)
        elif wl in ("polymul", "polymul-real"):
            build = dfft.make_sharded_polymul(mesh, batch_axes=())
            spec = jax.ShapeDtypeStruct((batch, n), jnp.complex64)
            args_ = (spec, spec)
        else:                      # fft, or the rfft complex fallback
            build = dfft.make_sharded_fft(mesh, batch_axes=())
            args_ = (jax.ShapeDtypeStruct((batch, n), jnp.complex64),)
        with collectives.ledger() as led:
            jax.jit(build).lower(*args_)
        return (led.bytes_by_kind["all-to-all"]
                + led.bytes_by_kind["ppermute"])

    GRID = [
        # (workload, n, batch, D). Local wins the small shapes at D=8;
        # n=8192 = D*1024 additionally exercises the PIM four-step closed
        # forms (the n1 = D cap is satisfied); at n=65536 over D=64 the
        # aggregate bandwidth pays for the all-to-alls and the four-step
        # tier must win the argmin.
        ("fft", 4096, 8, 8), ("rfft", 4096, 8, 8),
        ("polymul", 4096, 8, 8), ("polymul-real", 4096, 8, 8),
        ("polymul-mod", 4096, 8, 8),
        ("fft", 8192, 8, 8), ("rfft", 8192, 8, 8),
        ("polymul-mod", 8192, 8, 8),
        ("fft", 65536, 64, 64), ("rfft", 65536, 64, 64),
        ("polymul-real", 65536, 64, 64), ("polymul-mod", 65536, 64, 64),
    ]
    points = []
    agree = 0
    for wl, n, batch, D in GRID:
        p = plan(n, batch, workload=wl, model_shards=D)
        best = p.cost["best"]
        measured = []
        for c in p.cost["candidates"]:
            xla = c["backends"]["xla"]
            if c["tier"] == "distributed":
                mb = traced_dist_bytes(wl, n, batch, D, c["real"])
                assert mb == xla["collective_bytes"], \
                    (wl, n, D, c["real"], mb, xla["collective_bytes"])
                t_cand = (max(xla["t_compute_s"], xla["t_memory_s"])
                          + mb / cost_lib.LINK_BW)
            else:
                t_cand = xla["total_s"]
            pim = c["backends"]["pim"]
            if "infeasible" not in pim:
                wl_pim = cost_lib._pim_workload(wl, c["real"])
                if c["tier"] == "local":
                    mc = sim_local_cycles(wl_pim, n, batch)
                else:
                    mc = sim_dist_cycles(wl_pim, n, D)
                assert mc == pim["pim_cycles"], \
                    (wl, n, D, c["tier"], c["real"], mc, pim["pim_cycles"])
                # measured cycles through the model's own cycle->seconds
                # conversion (linear in cycles, so this is substitution,
                # not approximation)
                t_pim = (pim["t_compute_s"] * (mc / pim["pim_cycles"])
                         + pim["t_collective_s"])
                t_cand = min(t_cand, t_pim)
            measured.append((t_cand, c["tier"] != "local",
                             not c["real"], c))
        measured.sort(key=lambda m: m[:3])   # the planner's tie-break
        m_best = measured[0][3]
        ok = ((m_best["tier"], m_best["real"])
              == (best["tier"], best["real"]))
        agree += ok
        points.append({"workload": wl, "n": n, "batch": batch, "D": D,
                       "predicted": {"tier": best["tier"],
                                     "real": best["real"],
                                     "backend": best["backend_best"]},
                       "measured_tier": m_best["tier"],
                       "measured_real": m_best["real"],
                       "agree": bool(ok)})
        emit(f"smoke/auto_plan/{wl}/n={n}/D={D}", 0.0,
             f"predicted={best['tier']};measured={m_best['tier']}"
             f";backend={best['backend_best']};agree={bool(ok)}")

    # A grid point with NO executable candidate must fail naming every
    # pruning constraint, not with a bare error (the serve layer surfaces
    # this message verbatim as a 400).
    try:
        plan(2 ** 20, 4, workload="fft", model_shards=3)
    except ValueError as e:
        msg = str(e)
        assert "_MAX_LOCAL_N" in msg and "D^2 | n" in msg, msg
    else:
        raise AssertionError("plan() accepted an unexecutable grid point")

    return {"op": "auto-plan-agreement", "grid_points": len(GRID),
            "agreement": agree / len(GRID), "points": points}


REAL_COMPLEX_CYCLE_GATE = 0.65  # per-product simulated-cycle ratio ceiling
# Distributed real tier: total interconnect bytes (all-to-all + the
# conjugate-bin ppermute) vs the complex distributed path, per product /
# per real-sequence pair. The per-shard Hermitian split keeps the
# half-spectrum off the wire at full width: 3.5 vs 6 block-units ~ 0.583.
DIST_REAL_COMPLEX_BYTE_GATE = 0.6
# ABFT integrity check (ft/abft.py): simulated check cycles over the
# batch=2 transform it verifies. Measured 0.04-0.19 across the op grid;
# the gate holds the check CHEAP relative to the work it guards.
ABFT_OVERHEAD_GATE = 0.25


def static_analysis_smoke() -> dict:
    """Invariant-linter gate + rule-count record (docs/static_analysis.md).

    Runs ``repro.analysis`` over src/tests/benchmarks exactly like the CI
    static-analysis job, and records the ACTIVE RULE COUNT as a
    deterministic metric: ``benchmarks/trajectory.py`` ratchets it with
    direction=max, so rules can be added but never silently dropped — the
    linter's coverage is part of the perf trajectory's contract surface."""
    from benchmarks.runlib import emit
    from repro import analysis

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = analysis.analyze_paths(
        [os.path.join(root, p) for p in ("src", "tests", "benchmarks")])
    emit("smoke/static_analysis", 0.0,
         f"rules={len(analysis.RULES)};findings={len(res.findings)}"
         f";suppressed={len(res.suppressed)};files={res.n_files}")
    return {"op": "static-analysis",
            "rule_count": len(analysis.RULES),
            "findings": len(res.findings),
            "suppressed": len(res.suppressed),
            "messages": [f.format() for f in res.findings]}


def bench_fourier_smoke(path: str = "BENCH_fourier.json") -> dict:
    """Emit the real-path perf record + gate; returns the written dict.

    The committed ``path`` (if present) is the perf-trajectory BASELINE:
    every deterministic metric is ratcheted against it through
    ``benchmarks/trajectory.py`` — a regression within the absolute gates
    still fails — and a history record is appended, so the artifact
    carries the measured trajectory, not just the latest snapshot."""
    import json

    import numpy as np
    import jax.numpy as jnp

    from benchmarks.runlib import emit, time_jax
    from repro.core.pim import (FOURIERPIM_8, FP32, fft_throughput_per_s,
                                polymul_latency_cycles,
                                polymul_real_pair_latency_cycles,
                                polymul_throughput_per_s,
                                rfft_latency_cycles, rfft_throughput_per_s)
    from repro.kernels import polymul as kpoly

    records = []
    ratios = {}
    for n in (1024, 4096):
        cyc_c = polymul_latency_cycles(n, FOURIERPIM_8, FP32)
        cyc_pair = polymul_real_pair_latency_cycles(n, FOURIERPIM_8, FP32)
        ratio = cyc_pair / (2 * cyc_c)
        ratios[str(n)] = ratio
        # pim_cycles is per CALL (complex: 1 product, real: the 2-product
        # pair); pim_cycles_per_product is the unit consumers should
        # compare across ops.
        records.append({
            "op": "polymul", "n": n, "batch": 1, "pim_cycles": cyc_c,
            "pim_cycles_per_product": cyc_c,
            "throughput_per_s": polymul_throughput_per_s(
                n, FOURIERPIM_8, FP32)})
        records.append({
            "op": "polymul-real", "n": n, "batch": 2,
            "pim_cycles": cyc_pair,
            "pim_cycles_per_product": cyc_pair / 2,
            "throughput_per_s": polymul_throughput_per_s(
                n, FOURIERPIM_8, FP32, real=True)})
        records.append({
            "op": "rfft", "n": n, "batch": 2,
            "pim_cycles": rfft_latency_cycles(n, FOURIERPIM_8, FP32),
            "throughput_per_s": rfft_throughput_per_s(
                n, FOURIERPIM_8, FP32),
            "complex_fft_throughput_per_s": fft_throughput_per_s(
                n, FOURIERPIM_8, FP32)})
        emit(f"smoke/pim_polymul_real/n={n}", 0.0,
             f"cycle_ratio={ratio:.3f};gate<={REAL_COMPLEX_CYCLE_GATE}")

    # Interpret-mode wall clock: the serve fast path (two-for-one + paired
    # inverse = 1.5 transforms/product) must beat the complex kernel's 3
    # even through the Pallas interpreter. The shape is large enough that
    # butterfly work dominates the interpreter/XLA-op overhead (smaller
    # shapes are overhead-bound and time ~equal).
    B, n = 16, 8192
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
    zero = jnp.zeros_like(a)
    us_real = time_jax(
        lambda x, y: kpoly.polymul_real_planes(x, y, block_b=8),
        a, b, warmup=2, iters=5)
    us_cplx = time_jax(
        lambda xr, xi, yr, yi: kpoly.polymul_complex_planes(
            xr, xi, yr, yi, block_b=8),
        a, zero, b, zero, warmup=2, iters=5)
    emit(f"smoke/polymul_real_wallclock/n={n}", us_real,
         f"complex_us={us_cplx:.1f};speedup={us_cplx / us_real:.2f}")
    records.append({"op": "polymul-interpret-wallclock", "n": n, "batch": B,
                    "real_us": us_real, "complex_us": us_cplx,
                    "speedup": us_cplx / us_real})

    # Distributed real tier: trace the sharded real ops on a trivial mesh,
    # pin the collective ledger against the closed form, and gate the
    # real/complex interconnect-byte ratio. The ratio is D-independent
    # (every term scales with the block size), so the single-device trace
    # is the same gate CI's 8-device tier re-asserts.
    import jax

    from repro.core.fft import distributed as dfft
    from repro.dist import collectives
    mesh = jax.make_mesh((1,), ("model",))
    Bd, nd = 4, 4096
    rspec = jax.ShapeDtypeStruct((Bd, nd), jnp.float32)
    dist_ratios = {}
    for op, build, args_ in (
            ("rfft", dfft.make_sharded_rfft(mesh, batch_axes=()), (rspec,)),
            ("polymul_real",
             dfft.make_sharded_polymul_real(mesh, batch_axes=()),
             (rspec, rspec))):
        with collectives.ledger() as led:
            jax.jit(build).lower(*args_)
        want = dfft.four_step_collective_stats(nd, Bd, 1, op=op)
        assert led.counts["all-to-all"] == want["a2a_count"], (op, led.as_dict())
        assert led.bytes_by_kind["all-to-all"] == want["a2a_bytes"], \
            (op, led.as_dict())
        assert led.bytes_by_kind["ppermute"] == want["ppermute_bytes"], \
            (op, led.as_dict())
        base = dfft.four_step_collective_stats(
            nd, Bd, 1, op="polymul" if op == "polymul_real" else "fft")
        ratio = want["total_bytes"] / base["total_bytes"]
        dist_ratios[op] = ratio
        emit(f"smoke/dist_real_bytes/{op}/n={nd}", 0.0,
             f"byte_ratio={ratio:.3f};gate<={DIST_REAL_COMPLEX_BYTE_GATE}")
    records.append({"op": "dist-real-bytes", "n": nd, "batch": Bd,
                    "byte_ratio": dist_ratios})

    # ABFT verified-mode overhead: for every checkable workload, the
    # simulated cycles of one integrity check (charged on a live sim —
    # asserted equal to the closed form, so the planner prices exactly
    # what the sim counts) over the batch=2 transform it verifies.
    # Deterministic, ratcheted, and absolutely gated at ABFT_OVERHEAD_GATE.
    from repro.core import cost as cost_lib
    from repro.core.pim import INT32, CrossbarSim
    from repro.ft import abft
    abft_ratios = {}
    for wl in sorted(abft.CHECKS):
        for n in (1024, 4096):
            spec = INT32 if wl == "polymul-mod" else FP32
            sim = CrossbarSim(FOURIERPIM_8, spec)
            abft.charge_check(sim, wl, n)
            check = sim.ctr.cycles
            assert check == cost_lib.abft_check_cycles(wl, n), \
                f"{wl}/n={n}: sim-charged check diverged from closed form"
            base = cost_lib.pim_local_unit_cycles(wl, n, batch=2)
            abft_ratios[f"{wl}/n={n}"] = check / base
            emit(f"smoke/abft_overhead/{wl}/n={n}", 0.0,
                 f"ratio={check / base:.3f};gate<={ABFT_OVERHEAD_GATE}")
    records.append({"op": "abft-overhead", "ratios": abft_ratios})

    # Continuous-batching serve engine: mixed-op stream through the op
    # registry; per-request p50/p99 and bucket utilization land in the
    # trajectory artifact (no latency gate — shared runners — but a served
    # shortfall or oracle mismatch fails the smoke).
    serve_record = serve_engine_smoke()
    records.append(serve_record)

    # Auto-tiering planner: predicted-best tier must equal the tier the
    # measured quantities (sim counters + live ledger bytes) pick, on
    # every grid point (docs/planner.md). The rate is ratcheted.
    auto_record = auto_plan_agreement_smoke()
    records.append(auto_record)

    # Invariant linter: zero findings over the tree, rule count ratcheted
    # (a dropped rule is a silently-unenforced contract).
    sa_record = static_analysis_smoke()
    records.append(sa_record)
    static_analysis = {"rule_count": sa_record["rule_count"],
                       "findings": sa_record["findings"],
                       "suppressed": sa_record["suppressed"]}

    # Evaluate every gate, record the honest verdicts, and only then
    # assert: the artifact must exist AND tell the truth on a failing run
    # (it is uploaded with if: always() in CI).
    from benchmarks import trajectory
    baseline = trajectory.load(path)
    fresh = {"real_complex_cycle_ratio": ratios,
             "dist_real_complex_byte_ratio": dist_ratios,
             "abft_overhead_ratio": abft_ratios,
             "auto_plan": auto_record,
             "static_analysis": static_analysis,
             "records": records}
    violations = trajectory.compare(baseline, fresh) if baseline else []
    cycle_ok = all(r <= REAL_COMPLEX_CYCLE_GATE for r in ratios.values())
    bytes_ok = all(r <= DIST_REAL_COMPLEX_BYTE_GATE
                   for r in dist_ratios.values())
    abft_ok = all(r <= ABFT_OVERHEAD_GATE for r in abft_ratios.values())
    # Timing sanity with slack for loaded shared runners (the observed
    # speedup is 1.5-2x; the deterministic regression gates are the ratio
    # gates above, so this only catches a grossly slower real path).
    wallclock_ok = us_real < 1.15 * us_cplx
    auto_ok = auto_record["agreement"] == 1.0
    sa_ok = sa_record["findings"] == 0
    out = {
        "schema": "bench_fourier/v1",
        "device_model": "FOURIERPIM_8", "spec": "fp32",
        "records": records,
        "real_complex_cycle_ratio": ratios,
        "dist_real_complex_byte_ratio": dist_ratios,
        "abft_overhead_ratio": abft_ratios,
        "auto_plan": auto_record,
        "static_analysis": static_analysis,
        "serve": {"p50_ms": serve_record["serve_p50_ms"],
                  "p99_ms": serve_record["serve_p99_ms"],
                  "throughput_per_s": serve_record["throughput_per_s"],
                  "bucket_utilization": serve_record["bucket_utilization"]},
        "gate": {"max_real_complex_cycle_ratio": REAL_COMPLEX_CYCLE_GATE,
                 "max_dist_real_complex_byte_ratio":
                     DIST_REAL_COMPLEX_BYTE_GATE,
                 "max_abft_overhead_ratio": ABFT_OVERHEAD_GATE,
                 "cycle_ratio_pass": cycle_ok,
                 "dist_byte_ratio_pass": bytes_ok,
                 "abft_overhead_pass": abft_ok,
                 "wallclock_pass": wallclock_ok,
                 "auto_plan_agreement_pass": auto_ok,
                 "static_analysis_pass": sa_ok,
                 "ratchet_slack": trajectory.RATCHET_SLACK,
                 "trajectory_pass": not violations,
                 "trajectory_violations": violations,
                 "pass": (cycle_ok and bytes_ok and abft_ok
                          and wallclock_ok and auto_ok and sa_ok
                          and not violations)},
    }
    out["history"] = trajectory.extend_history(baseline, out)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("smoke/bench_fourier_json", 0.0,
         f"path={path};history={len(out['history'])}"
         f";ratchet={'armed' if baseline else 'unarmed'}")
    assert cycle_ok, \
        f"real/complex polymul cycle ratio regressed: {ratios}"
    assert bytes_ok, \
        f"distributed real/complex byte ratio regressed: {dist_ratios}"
    assert abft_ok, \
        f"ABFT check overhead exceeds {ABFT_OVERHEAD_GATE:.0%} of the " \
        f"transform it verifies: {abft_ratios}"
    assert wallclock_ok, \
        f"real path grossly slower than complex in interpret mode: " \
        f"{us_real:.0f}us vs {us_cplx:.0f}us"
    assert auto_ok, \
        "auto planner predicted-best tier disagrees with the measured " \
        f"best on some grid point: {auto_record['points']}"
    assert sa_ok, \
        "invariant linter found contract violations:\n  " + \
        "\n  ".join(sa_record["messages"])
    assert not violations, \
        "perf trajectory ratchet violated vs the committed " \
        f"BENCH_fourier.json baseline:\n  " + "\n  ".join(violations)
    return out


def full() -> None:
    from benchmarks import (fft_pim_bench, ntt_pim_bench, polymul_pim_bench,
                            rns_ntt_bench, roofline, tpu_fft_bench)
    fft_pim_bench.run()
    polymul_pim_bench.run()
    ntt_pim_bench.run()
    rns_ntt_bench.run()
    tpu_fft_bench.run()
    if os.path.isdir(os.path.join("artifacts", "dryrun", "singlepod")):
        roofline.run("singlepod")
    else:
        print("roofline/skipped,0,no artifacts (run repro.launch.dryrun)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI subset (~seconds, asserts harness "
                         "wiring instead of sweeping)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.smoke:
        smoke()
    else:
        full()


if __name__ == "__main__":
    main()
