"""Benchmark driver: one function per paper table/figure + the roofline.

Emits ``name,us_per_call,derived`` CSV rows.

  fig5/*      — paper Figure 5: batched FFT, FourierPIM vs cuFFT models
  fig6/*      — paper Figure 6: complex & real polynomial multiplication
  tpu_fft/*   — TPU-native kernel path (beyond-paper; wall-clock + roofline)
  roofline/*  — per (arch x shape) three-term roofline from the dry-run
                artifacts (skipped if artifacts/dryrun is absent)
"""
from __future__ import annotations

import os


def main() -> None:
    from benchmarks import (fft_pim_bench, polymul_pim_bench, roofline,
                            tpu_fft_bench)
    print("name,us_per_call,derived")
    fft_pim_bench.run()
    polymul_pim_bench.run()
    tpu_fft_bench.run()
    if os.path.isdir(os.path.join("artifacts", "dryrun", "singlepod")):
        roofline.run("singlepod")
    else:
        print("roofline/skipped,0,no artifacts (run repro.launch.dryrun)")


if __name__ == "__main__":
    main()
