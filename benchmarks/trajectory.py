"""Perf-trajectory ratchet over BENCH_fourier.json.

BENCH_fourier.json used to be a SNAPSHOT: every smoke run overwrote it and
the only protection was the absolute gates (cycle ratio <= 0.65, byte
ratio <= 0.6) — a 20% regression that stayed under an absolute gate landed
invisibly. This module turns the file into a TRAJECTORY:

* the previous run's file is committed at the repo root (the baseline);
* ``compare(prev, new)`` ratchets every DETERMINISTIC metric against it —
  closed-form PIM cycle ratios, throughput and interconnect-byte ratios
  may drift at most ``RATCHET_SLACK`` (2%) in the losing direction per
  run, independent of how much absolute-gate headroom remains;
* wall-clock metrics (interpreter timings, serve p50/p99) are recorded in
  the history but NOT ratcheted — shared CI runners make them noisy;
* ``extend_history`` appends one summary record per run, so the artifact
  carries the whole measured trajectory, not just the latest point.

``benchmarks/run.py --smoke`` compares BEFORE overwriting the file and
fails on a violation (the verdict is written into the artifact first, so a
failing run still uploads an honest file). CI re-checks independently:
``python -m benchmarks.trajectory --baseline-git HEAD`` diffs the fresh
file against the committed baseline.

Accepting a deliberate trade (e.g. a feature that costs 1% of cycle
ratio) is explicit: commit the new BENCH_fourier.json in the same PR —
the ratchet then measures from the new baseline. What it forbids is the
SILENT version of the same drift.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RATCHET_SLACK = 0.02    # max losing-direction drift per run, deterministic
HISTORY_CAP = 100       # entries kept in the artifact's history list


def load(path: str) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def load_git(ref: str, path: str = "BENCH_fourier.json",
             cwd: str | None = None) -> dict | None:
    """The baseline as committed at ``ref`` (None if absent there)."""
    res = subprocess.run(["git", "show", f"{ref}:{path}"],
                         capture_output=True, text=True, cwd=cwd)
    if res.returncode != 0:
        return None
    return json.loads(res.stdout)


def deterministic_metrics(bench: dict) -> dict[str, tuple[float, str]]:
    """name -> (value, direction): every closed-form metric the ratchet
    guards. direction 'min' = lower is better (ratios), 'max' = higher is
    better (throughput). Wall-clock numbers are deliberately absent."""
    out: dict[str, tuple[float, str]] = {}
    for n, v in (bench.get("real_complex_cycle_ratio") or {}).items():
        out[f"real_complex_cycle_ratio/n={n}"] = (float(v), "min")
    for op, v in (bench.get("dist_real_complex_byte_ratio") or {}).items():
        out[f"dist_real_complex_byte_ratio/{op}"] = (float(v), "min")
    for key, v in (bench.get("abft_overhead_ratio") or {}).items():
        # simulated ABFT check cycles / verified transform cycles: a rise
        # means integrity got more expensive relative to the work it guards
        out[f"abft_overhead_ratio/{key}"] = (float(v), "min")
    ap = bench.get("auto_plan") or {}
    if "agreement" in ap:
        # predicted-vs-measured tier agreement of the auto planner:
        # pinned at 1.0 — any drop is a cost-model rot, not noise.
        out["auto_plan_agreement"] = (float(ap["agreement"]), "max")
    sa = bench.get("static_analysis") or {}
    if "rule_count" in sa:
        # active invariant-linter rules (repro.analysis): rules may be
        # added but never silently dropped — with 2% slack, losing even
        # one rule from a set of <= 50 trips the ratchet.
        out["static_analysis_rule_count"] = (float(sa["rule_count"]),
                                             "max")
    for rec in bench.get("records", []):
        op = rec.get("op")
        # closed-form PIM model outputs: deterministic per commit
        if op in ("polymul", "polymul-real", "rfft") \
                and "throughput_per_s" in rec:
            out[f"pim_throughput/{op}/n={rec['n']}"] = (
                float(rec["throughput_per_s"]), "max")
        if op in ("polymul", "polymul-real") and "pim_cycles" in rec:
            out[f"pim_cycles/{op}/n={rec['n']}"] = (
                float(rec["pim_cycles"]), "min")
    return out


def compare(prev: dict, new: dict,
            slack: float = RATCHET_SLACK) -> list[str]:
    """Ratchet violations of ``new`` against the ``prev`` baseline.

    A metric present in prev but missing from new is itself a violation
    (dropping a measurement is how regressions hide); new metrics with no
    baseline pass freely and enter the ratchet on the next commit.
    """
    prev_m = deterministic_metrics(prev)
    new_m = deterministic_metrics(new)
    violations = []
    for name, (pv, direction) in sorted(prev_m.items()):
        if name not in new_m:
            violations.append(f"{name}: measured in baseline ({pv:.6g}) "
                              f"but missing from this run")
            continue
        nv, _ = new_m[name]
        if direction == "min":
            bound = pv * (1.0 + slack)
            if nv > bound:
                violations.append(
                    f"{name}: {nv:.6g} > ratchet {bound:.6g} "
                    f"(baseline {pv:.6g}, slack {slack:.0%})")
        else:
            bound = pv * (1.0 - slack)
            if nv < bound:
                violations.append(
                    f"{name}: {nv:.6g} < ratchet {bound:.6g} "
                    f"(baseline {pv:.6g}, slack {slack:.0%})")
    return violations


def history_entry(bench: dict) -> dict:
    """One per-run trajectory record: the deterministic metrics plus the
    (noisy, informational) serve latencies and gate verdicts."""
    serve = bench.get("serve", {})
    return {
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "metrics": {k: v for k, (v, _) in
                    deterministic_metrics(bench).items()},
        "serve_ms": {k: serve.get(k) for k in ("p50_ms", "p99_ms")},
        "gate_pass": bench.get("gate", {}).get("pass"),
    }


def extend_history(prev: dict | None, new: dict) -> list[dict]:
    """prev's history + one entry for the new run (bounded length)."""
    hist = list((prev or {}).get("history", []))
    hist.append(history_entry(new))
    return hist[-HISTORY_CAP:]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="ratchet-check a fresh BENCH_fourier.json against the "
                    "committed baseline")
    ap.add_argument("--current", default="BENCH_fourier.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline file path (default: --baseline-git)")
    ap.add_argument("--baseline-git", default="HEAD", metavar="REF",
                    help="read the baseline from this git ref "
                         "(default HEAD)")
    ap.add_argument("--slack", type=float, default=RATCHET_SLACK)
    args = ap.parse_args(argv)
    new = load(args.current)
    if new is None:
        print(f"[trajectory] FAIL: {args.current} does not exist "
              f"(run benchmarks/run.py --smoke first)")
        return 1
    prev = load(args.baseline) if args.baseline \
        else load_git(args.baseline_git, args.current)
    if prev is None:
        print("[trajectory] no committed baseline — nothing to ratchet "
              "(first run passes; commit the artifact to arm the ratchet)")
        return 0
    violations = compare(prev, new, slack=args.slack)
    n_hist = len(new.get("history", []))
    if violations:
        print(f"[trajectory] RATCHET VIOLATION "
              f"({len(violations)} metric(s), history={n_hist}):")
        for v in violations:
            print(f"  - {v}")
        print("  (a deliberate trade must commit the new "
              "BENCH_fourier.json in the same PR)")
        return 1
    print(f"[trajectory] ok: {len(deterministic_metrics(new))} "
          f"deterministic metrics within {args.slack:.0%} of the "
          f"committed baseline (history={n_hist})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
