"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the benchmark-specific figure of merit, e.g. a throughput ratio).
"""
from __future__ import annotations

import time
from typing import Callable


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def time_jax(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time in microseconds for a jitted JAX callable."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
