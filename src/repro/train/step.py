"""Train / serve step functions (pure, pjit-friendly).

make_train_step builds: loss -> grads -> global-norm clip -> (optional int8
error-feedback cross-pod gradient compression) -> AdamW update. The returned
callable signature is step(params, opt_state, batch) -> (params, opt_state,
metrics) and is what launch/train.py jits and launch/dryrun.py lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                    grad_transform: Optional[Callable] = None) -> Callable:
    accum = max(1, cfg.grad_accum_steps)

    def compute_grads(params, batch):
        if accum == 1:
            return jax.value_and_grad(
                lambda p: lm.loss_fn(cfg, p, batch))(params)
        mb = jax.tree.map(
            lambda v: v.reshape(accum, v.shape[0] // accum, *v.shape[1:]),
            batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def one(carry, b):
            lsum, gsum = carry
            l, g = jax.value_and_grad(
                lambda p: lm.loss_fn(cfg, p, b))(params)
            gsum = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), gsum, g)
            return (lsum + l, gsum), None

        (lsum, gsum), _ = jax.lax.scan(one, (jnp.zeros(()), zeros), mb)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        return lsum / accum, grads

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, opt_cfg.grad_clip)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state = adamw.apply(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": adamw.lr_schedule(opt_cfg, opt_state["step"])}
        return params, opt_state, metrics
    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        return lm.loss_fn(cfg, params, batch)
    return eval_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch.get("tokens"),
                          positions=batch.get("positions"),
                          embeds=batch.get("embeds"))
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, state, token, pos, positions=None, embed=None):
        return lm.decode_step(cfg, params, state, token, pos,
                              positions=positions, embed=embed)
    return decode_step
