"""Train / serve step functions (pure, pjit-friendly).

make_train_step builds: loss -> grads -> global-norm clip -> (optional int8
error-feedback cross-pod gradient compression) -> AdamW update. The returned
callable signature is step(params, opt_state, batch) -> (params, opt_state,
metrics) and is what launch/train.py jits and launch/dryrun.py lowers.

With ``pod_axis`` set, the step is the POD-MESH variant: it must run inside
``shard_map`` over that axis, carries an error-feedback residual tree
(``dist.collectives.zeros_like_errs`` for step 0), and reduces gradients
across pods through ``dist.collectives.compressed_psum`` (int8 wire format,
4x fewer DCN bytes than an f32 all-reduce; the quantization error rides the
residual into the next step instead of being lost). Signature becomes
step(params, opt_state, grad_err, batch) -> (params, opt_state, grad_err,
metrics). Contract pinned by
tests/test_substrate.py::test_train_step_compressed_psum_pod_mesh_subprocess.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                    grad_transform: Optional[Callable] = None, *,
                    pod_axis: Optional[str] = None,
                    data_axis: Optional[str] = None) -> Callable:
    """``data_axis`` (pod variant only) names an intra-pod data-parallel
    shard_map axis the batch is also sharded over: gradients mean-reduce
    across it FIRST (cheap ICI psum), so the cross-pod compressed psum
    sees one gradient per pod and every device applies the same update.
    Without it, a batch sharded over (pod, data) would silently leave the
    data-axis contributions unreduced."""
    accum = max(1, cfg.grad_accum_steps)

    def compute_grads(params, batch):
        if accum == 1:
            return jax.value_and_grad(
                lambda p: lm.loss_fn(cfg, p, batch))(params)
        mb = jax.tree.map(
            lambda v: v.reshape(accum, v.shape[0] // accum, *v.shape[1:]),
            batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def one(carry, b):
            lsum, gsum = carry
            l, g = jax.value_and_grad(
                lambda p: lm.loss_fn(cfg, p, b))(params)
            gsum = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), gsum, g)
            return (lsum + l, gsum), None

        (lsum, gsum), _ = jax.lax.scan(one, (jnp.zeros(()), zeros), mb)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        return lsum / accum, grads

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, opt_cfg.grad_clip)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state = adamw.apply(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": adamw.lr_schedule(opt_cfg, opt_state["step"])}
        return params, opt_state, metrics

    if pod_axis is None:
        return train_step

    from repro.dist import collectives

    def train_step_pod(params, opt_state, grad_err, batch):
        """Per-pod body: local grads -> (intra-pod data mean) -> clip ->
        int8 compressed cross-pod mean (error feedback carried in
        grad_err) -> replicated update."""
        loss, grads = compute_grads(params, batch)
        if data_axis is not None:
            grads = jax.tree.map(
                lambda g: collectives.pmean(g, data_axis), grads)
            loss = collectives.pmean(loss, data_axis)
        grads, gnorm = adamw.clip_by_global_norm(grads, opt_cfg.grad_clip)
        grads, grad_err = collectives.compressed_psum(grads, grad_err,
                                                      pod_axis)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state = adamw.apply(params, grads, opt_state, opt_cfg)
        # Reduced grads are identical across pods, so params/opt_state stay
        # replicated; the metrics are averaged so they are too.
        metrics = {"loss": collectives.pmean(loss, pod_axis),
                   "grad_norm": collectives.pmean(gnorm, pod_axis),
                   "lr": adamw.lr_schedule(opt_cfg, opt_state["step"])}
        return params, opt_state, grad_err, metrics
    return train_step_pod


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        return lm.loss_fn(cfg, params, batch)
    return eval_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch.get("tokens"),
                          positions=batch.get("positions"),
                          embeds=batch.get("embeds"))
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, state, token, pos, positions=None, embed=None):
        return lm.decode_step(cfg, params, state, token, pos,
                              positions=positions, embed=embed)
    return decode_step
