"""Train/prefill/decode step builders."""
