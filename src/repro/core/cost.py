"""Unified cost model behind the auto-tiering planner (docs/planner.md).

One module owns every number the planner compares so the comparison is
apples-to-apples:

  * **PIM closed forms** (``core.pim.fft_pim`` / ``polymul_pim`` /
    ``ntt_pim``): bit-serial cycle counts asserted equal to the
    ``CrossbarSim`` counters in tests — the cost twin of the paper's
    crossbar schedule.
  * **Collective byte formulas** (``core.fft.distributed`` /
    ``core.ntt.distributed`` ``four_step_collective_stats``): the same
    closed forms pinned against the live ``dist.collectives`` ledger.
  * **Roofline host/XLA estimates**: the v5e constants that
    ``benchmarks/roofline.py`` uses for the dry-run analysis (the
    constants LIVE here now; roofline imports them back, so the serving
    cost model and the training roofline can never drift apart).

``workload_cost(workload, n, batch, ...)`` enumerates every (tier,
packing) candidate that is *executable on the XLA path* (the planner
only ever returns plans the kernels accept), scores each candidate on
both backends (PIM cost twin and XLA roofline), and returns the
predicted-cheapest candidate plus a machine-readable breakdown — every
pruned candidate carries the NAME of the constraint that pruned it
(the ``n1 = D`` four-step cap, ``D^2 | n`` tiling, the VMEM ceiling),
so a non-executable request fails with the reason, not a bare error.

Accounting conventions (shared by the smoke bench's "measured" side so
predicted-vs-measured agreement is meaningful, benchmarks/run.py):

  * XLA: ``total = max(t_compute, t_memory) + t_collective`` — roofline
    max of the on-chip terms, plus serialized interconnect time.
    Distributed splits the on-chip work over D devices and charges the
    per-device ledger bytes of ``four_step_collective_stats``.
  * PIM local: steady-state batched throughput (every crossbar runs the
    schedule in parallel, net of scratch area — the paper's §6 model).
  * PIM distributed: one in-flight transform holds one crossbar on each
    of the D shards, so ``num_crossbars * concurrency`` units pipeline;
    inter-shard transpose bytes cross each device's link at
    ``bytes/D / LINK_BW``. Only valid under the ``n1 = D`` cap
    (``n == D * crossbar_rows``) that the closed forms assert.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.pim import aritpim
from repro.core.pim.device_model import FOURIERPIM_8, PIMConfig
from repro.core.pim.fft_pim import (
    fft_distributed_a2a_bytes,
    fft_distributed_latency_cycles,
    fft_latency_cycles,
    fft_throughput_per_s,
    realpack_unpack_cycles,
    rfft_distributed_a2a_bytes,
    rfft_distributed_latency_cycles,
    rfft_distributed_permute_bytes,
    rfft_latency_cycles,
    rfft_throughput_per_s,
)
from repro.core.pim.ntt_pim import (
    ntt_distributed_a2a_bytes,
    ntt_distributed_latency_cycles,
    ntt_polymul_latency_cycles,
)
from repro.core.pim.polymul_pim import (
    polymul_latency_cycles,
    polymul_real_batch_latency_cycles,
    polymul_throughput_per_s,
)

# Hardware model constants (v5e-class host chip). benchmarks/roofline.py
# imports these back — single source of truth for both the training-side
# dry-run roofline and the serving-side planner.
PEAK_FLOPS = 197e12        # bf16 FLOPs/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

#: Workloads the chooser understands — exactly the ``OpSpec`` registry
#: names (launch/ops.py), so serve buckets can ask for costs verbatim.
WORKLOADS = ("fft", "rfft", "polymul", "polymul-real", "polymul-mod")

_PIM_CFG = FOURIERPIM_8
_FP = aritpim.FP32
_INT = aritpim.INT32


@dataclasses.dataclass(frozen=True)
class TierCost:
    """Predicted cost of one executable (tier, packing) candidate on one
    backend. ``total_s`` is the comparison key; the component terms and
    raw PIM cycle / collective byte counts ride along so tests can pin
    them against simulator counters and ledger bytes."""
    tier: str               # "local" | "distributed"
    backend: str            # "pim" | "xla"
    real: bool
    exact: bool
    seq_shards: int
    total_s: float
    t_compute_s: float = 0.0
    t_memory_s: float = 0.0
    t_collective_s: float = 0.0
    pim_cycles: int = 0           # per-unit closed-form latency (pim only)
    collective_bytes: int = 0     # per-batch interconnect bytes

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _require_pow2(n: int) -> None:
    if n <= 0 or n & (n - 1):
        raise ValueError(f"n={n} must be a power of two")


def _word_bits(*, real: bool, exact: bool) -> int:
    if exact:
        return _INT.word_bits
    # Real rows pack pairwise into full complex words; the per-row
    # capacity doubling is carried by the throughput closed forms, so
    # crossbar feasibility is judged at the complex word width.
    return aritpim.complex_word_bits(_FP)


# ---------------------------------------------------------------------------
# Executability constraints — each returns None (ok) or the prune reason.
# The reason strings NAME the constraint; tests pin them.
# ---------------------------------------------------------------------------

def local_prune_reason(workload: str, n: int) -> str | None:
    """XLA local tier: the sequence must stay VMEM-resident."""
    from repro.core.fft import planner
    exact = workload == "polymul-mod"
    cap = planner._MAX_LOCAL_N_EXACT if exact else planner._MAX_LOCAL_N
    if n > cap:
        which = "_MAX_LOCAL_N_EXACT" if exact else "_MAX_LOCAL_N"
        return (f"local tier: n={n} exceeds the VMEM-resident ceiling "
                f"{which}={cap} (sequence no longer fits one kernel)")
    return None


def dist_prune_reason(workload: str, n: int, n_devices: int, *,
                      real: bool) -> str | None:
    """XLA four-step tier: device count and transpose tiling."""
    if n_devices <= 1:
        return ("distributed tier: four-step needs model_shards > 1 "
                f"(have {n_devices})")
    d2 = n_devices * n_devices
    if n % d2:
        return (f"distributed tier: four-step tiling needs D^2 | n "
                f"(transposes + twiddle blocks): n={n}, D^2={d2}")
    if real and workload == "rfft" and n % (2 * d2):
        return (f"distributed real tier: the ordered rfft's half-width "
                f"ordering all-to-all needs 2*D^2 | n: n={n}, "
                f"2*D^2={2 * d2}")
    return None


def pim_local_infeasible(workload: str, n: int,
                         cfg: PIMConfig = _PIM_CFG) -> str | None:
    """PIM cost twin, local tier: one sequence must fit one crossbar's
    columns (``PIMConfig.valid_config`` — the paper's footnote 7)."""
    real = workload in ("rfft", "polymul-real")
    exact = workload == "polymul-mod"
    word = _word_bits(real=real, exact=exact)
    if not cfg.valid_config(n, word):
        beta = max(1, n // (2 * cfg.crossbar_rows))
        return (f"pim local: 2*beta*word_bits={2 * beta * word} exceeds "
                f"crossbar_cols={cfg.crossbar_cols} (valid_config: "
                f"multi-crossbar FFT is the paper's future work)")
    return None


def pim_dist_infeasible(n: int, n_devices: int,
                        cfg: PIMConfig = _PIM_CFG) -> str | None:
    """PIM cost twin, distributed tier: the closed forms assert the
    ``n1 = D`` four-step cap (each shard's block is exactly one r-config
    crossbar column: n2 = n/D == crossbar_rows)."""
    if n_devices <= 1:
        return "pim distributed: needs model_shards > 1"
    r = cfg.crossbar_rows
    if n != n_devices * r:
        return (f"pim distributed: closed forms need n2 = n/D == "
                f"crossbar_rows={r} (the n1 = D four-step cap): "
                f"n={n}, D={n_devices}, n/D={n // n_devices}")
    return None


# ---------------------------------------------------------------------------
# XLA roofline estimates
# ---------------------------------------------------------------------------

def _xla_local_terms(workload: str, n: int, batch: int, *,
                     real: bool) -> tuple[float, float]:
    """(flops, hbm_bytes) of one local batched call.

    FFT flop model: 5 n log2 n per complex transform (the textbook
    split-radix-free count XLA's Stockham hits within a small constant);
    real packing runs batch/2 packed transforms plus O(n) unpack adds.
    Byte model: the Pallas kernels are VMEM-resident single-pass — each
    operand/result crosses HBM exactly once.
    """
    lg = n.bit_length() - 1
    fft_flops = 5.0 * n * lg
    # repro: noqa[dispatch-ladder]: per-workload closed-form flop/byte FORMULAS (cost-model data, not op dispatch) — executable routes bind through the launch/ops.py registry
    if workload == "fft":
        flops = batch * fft_flops
        nbytes = batch * 2 * n * 8                      # c64 in + out
    elif workload == "rfft":
        if real:
            flops = batch * (fft_flops / 2 + 4.0 * n)   # packed + unpack
            nbytes = batch * (n * 4 + n * 4)            # f32 in, half c64 out
        else:                                           # complex fallback
            flops = batch * fft_flops
            nbytes = batch * (2 * n * 8)
    elif workload == "polymul":
        flops = batch * (3 * fft_flops + 6.0 * n)
        nbytes = batch * 3 * n * 8                      # a, b in + out
    elif workload == "polymul-real":
        if real:   # paired inverse: ~1.5 transform-equivalents/product
            flops = batch * (1.5 * fft_flops + 12.0 * n)
            nbytes = batch * 3 * n * 4                  # f32 a, b, out
        else:      # cast-to-complex fallback: full complex product
            flops = batch * (3 * fft_flops + 6.0 * n)
            nbytes = batch * 3 * n * 8
    elif workload == "polymul-mod":
        # Montgomery butterfly ~ 8 int-op equivalents; 3 transforms +
        # pointwise + negacyclic twists.
        flops = batch * (3 * 8.0 * (n / 2) * lg + 4.0 * 2 * n)
        nbytes = batch * 3 * n * 4                      # u32 a, b, out
    else:
        raise ValueError(f"unknown workload {workload!r}")
    return flops, nbytes


def _xla_collective_bytes(workload: str, n: int, batch: int,
                          n_devices: int, *, real: bool) -> int:
    """Per-device ledger bytes of one distributed call — the
    ``four_step_collective_stats`` closed forms (pinned against the live
    ledger), with the even-batch pad the engine applies to odd real
    batches folded in."""
    if workload == "polymul-mod":
        from repro.core.ntt.distributed import four_step_collective_stats
        return four_step_collective_stats(
            n, batch, n_devices, op="polymul")["bytes"]
    from repro.core.fft.distributed import four_step_collective_stats
    # repro: noqa[dispatch-ladder]: maps workload -> ledger closed-form key (byte-formula selection, not op dispatch); the registry is the only executable dispatch surface
    if workload == "rfft":
        op = "rfft" if real else "fft"
    elif workload == "polymul-real":
        op = "polymul_real" if real else "polymul"
    else:
        op = {"fft": "fft", "polymul": "polymul"}[workload]
    if op in ("rfft", "polymul_real") and batch % 2:
        batch += 1                      # engine pads odd real batches
    return four_step_collective_stats(n, batch, n_devices,
                                      op=op)["total_bytes"]


def xla_cost(workload: str, n: int, batch: int, *, tier: str,
             n_devices: int = 1, real: bool = False,
             verified: bool = False) -> TierCost:
    exact = workload == "polymul-mod"
    flops, nbytes = _xla_local_terms(workload, n, max(batch, 1), real=real)
    if verified:
        # Host-side integrity check (ft/abft.py): O(n) reductions over
        # each operand/result row plus one more pass over the result.
        ops = {"fft": 2, "rfft": 2}.get(workload, 3)
        flops += max(batch, 1) * ops * 4.0 * n
        nbytes += max(batch, 1) * n * 8
    if tier == "distributed":
        flops /= n_devices
        nbytes /= n_devices
        coll = _xla_collective_bytes(workload, n, max(batch, 1),
                                     n_devices, real=real)
    else:
        coll = 0
    t_comp = flops / PEAK_FLOPS
    t_mem = nbytes / HBM_BW
    t_coll = coll / LINK_BW
    return TierCost(tier=tier, backend="xla", real=real, exact=exact,
                    seq_shards=n_devices if tier == "distributed" else 1,
                    total_s=max(t_comp, t_mem) + t_coll,
                    t_compute_s=t_comp, t_memory_s=t_mem,
                    t_collective_s=t_coll, collective_bytes=coll)


# ---------------------------------------------------------------------------
# PIM cost-twin estimates
# ---------------------------------------------------------------------------

def abft_check_cycles(workload: str, n: int, *,
                      cfg: PIMConfig = _PIM_CFG) -> int:
    """Closed-form cycles of one ABFT integrity check (ft/abft.py) at the
    planner's default device model — the quantity ``verified=True``
    pricing adds per work unit, and the counter-parity gate pins against
    ``abft.charge_check`` on a live sim. Lazy import: abft pulls the
    crossbar stack, and this module is imported by the planner on every
    bind — the check cost is only computed on verified paths."""
    from repro.ft import abft
    spec = _INT if workload == "polymul-mod" else _FP
    return abft.check_cycles(workload, n, cfg, spec)


def pim_local_unit_cycles(workload: str, n: int, *, batch: int = 2,
                          cfg: PIMConfig = _PIM_CFG) -> int:
    """Closed-form latency cycles of one unit of work on one crossbar —
    the quantity tests assert equal to ``CrossbarSim`` counters.

    Units: one transform (fft), one packed run of TWO real rows (rfft),
    one product (polymul / polymul-mod), a ``batch``-product real call
    (polymul-real: pairs share the inverse, so cycles are per-call)."""
    if workload == "fft":
        return fft_latency_cycles(n, cfg, _FP)
    if workload == "rfft":
        return rfft_latency_cycles(n, cfg, _FP)
    if workload == "polymul":
        return polymul_latency_cycles(n, cfg, _FP)
    if workload == "polymul-real":
        return polymul_real_batch_latency_cycles(n, batch, cfg, _FP)
    if workload == "polymul-mod":
        return ntt_polymul_latency_cycles(n, cfg, _INT)
    raise ValueError(f"unknown workload {workload!r}")


def _pim_local_throughput(workload: str, n: int,
                          cfg: PIMConfig = _PIM_CFG) -> float:
    if workload == "fft":
        return fft_throughput_per_s(n, cfg, _FP)
    if workload == "rfft":
        return rfft_throughput_per_s(n, cfg, _FP)
    if workload == "polymul":
        return polymul_throughput_per_s(n, cfg, _FP, real=False)
    if workload == "polymul-real":
        return polymul_throughput_per_s(n, cfg, _FP, real=True)
    # polymul-mod: mirror polymul_throughput_per_s's operand-area
    # accounting at the residue word width (a and b both resident).
    lat = ntt_polymul_latency_cycles(n, cfg, _INT) / cfg.clock_hz
    word = _INT.word_bits
    beta = max(1, n // (2 * cfg.crossbar_rows))
    data_cols = 2 * 2 * beta * word
    scratch = cfg.temp_words * word * cfg.partitions
    area = max(1.0, (data_cols + scratch) / cfg.crossbar_cols)
    return int(cfg.num_crossbars / area) * cfg.concurrency / lat


def pim_dist_unit_cycles(workload: str, n: int, n_devices: int, *,
                         cfg: PIMConfig = _PIM_CFG) -> int:
    """Per-shard closed-form cycles of one distributed unit. Transforms
    use the pinned dist closed forms; the polymul workloads compose them
    (2 forwards + 1 inverse + pointwise), mirroring the local forms."""
    spec = _FP
    serial = 1                      # n2 == r: beta = 1 per shard block
    if workload == "fft":
        return fft_distributed_latency_cycles(n, n_devices, cfg, spec)
    if workload == "rfft":
        return rfft_distributed_latency_cycles(n, n_devices, cfg, spec)
    if workload == "polymul":
        return (3 * fft_distributed_latency_cycles(n, n_devices, cfg, spec)
                + aritpim.complex_mul_cycles(spec) * serial)
    if workload == "polymul-real":
        # Per PAIR: 2 packed forwards + 1 inverse + 2 unpacks + 2 cmuls
        # + the Q-pack (same schedule as the local paired form).
        return (3 * fft_distributed_latency_cycles(n, n_devices, cfg, spec)
                + 2 * realpack_unpack_cycles(cfg, spec)
                + 2 * aritpim.complex_mul_cycles(spec))
    if workload == "polymul-mod":
        return (3 * ntt_distributed_latency_cycles(n, n_devices, cfg, _INT)
                + 4 * aritpim.mod_mul_cycles(_INT))
    raise ValueError(f"unknown workload {workload!r}")


def pim_dist_unit_bytes(workload: str, n: int, n_devices: int) -> int:
    """Inter-array transpose traffic of one distributed unit (global
    bytes across the fabric), from the pinned per-transform formulas."""
    if workload == "fft":
        return fft_distributed_a2a_bytes(n, _FP, ordered=True)
    if workload == "rfft":
        return (rfft_distributed_a2a_bytes(n, _FP)
                + rfft_distributed_permute_bytes(n, _FP))
    if workload == "polymul":
        # 2 fwd + 1 inv, two transposes each, no ordering move needed
        # inside the product: 6 full-width transform widths.
        return 3 * fft_distributed_a2a_bytes(n, _FP, ordered=False)
    if workload == "polymul-real":
        # Per PAIR: 3 packed transforms (2 transposes each) + the mirror
        # permute — the PIM twin of the TPU tier's 3.5-block-unit ratio.
        return (3 * fft_distributed_a2a_bytes(n, _FP, ordered=False)
                + rfft_distributed_permute_bytes(n, _FP))
    if workload == "polymul-mod":
        return 3 * ntt_distributed_a2a_bytes(n, n_devices, _INT)
    raise ValueError(f"unknown workload {workload!r}")


def _pim_workload(workload: str, real: bool) -> str:
    """Effective PIM schedule for a (workload, packing) candidate: the
    complex-fallback candidates of the real workloads run the plain
    complex schedules on the crossbar, exactly as they do on XLA."""
    if not real:
        if workload == "rfft":
            return "fft"
        if workload == "polymul-real":
            return "polymul"
    return workload


def _pim_units(workload: str, batch: int, *, real: bool) -> int:
    """Work units in a batch: packed real transforms carry two rows per
    run; real products pair per-call (pairs already amortized inside the
    closed forms, so units = calls of the batch form)."""
    if workload == "rfft" and real:
        return max(1, math.ceil(batch / 2))
    if workload == "polymul-real" and real:
        return 1            # one batched call; cycles already batch-wide
    return max(batch, 1)


def pim_cost(workload: str, n: int, batch: int, *, tier: str,
             n_devices: int = 1, real: bool = False,
             verified: bool = False,
             cfg: PIMConfig = _PIM_CFG) -> TierCost:
    exact = workload == "polymul-mod"
    batch = max(batch, 1)
    wl = _pim_workload(workload, real)
    check = abft_check_cycles(wl, n, cfg=cfg) if verified else 0
    if tier == "local":
        unit_cycles = pim_local_unit_cycles(wl, n, batch=batch, cfg=cfg)
        t = batch / _pim_local_throughput(wl, n, cfg)
        if verified:
            # The check rides the same vectored column ops as the
            # transform (batch rows in parallel), so throughput scales by
            # the per-unit cycle stretch — the closed-form overhead the
            # BENCH abft_overhead_ratio gate pins.
            t *= (unit_cycles + check) / unit_cycles
            unit_cycles += check
        return TierCost(tier="local", backend="pim", real=real, exact=exact,
                        seq_shards=1, total_s=t, t_compute_s=t,
                        pim_cycles=unit_cycles)
    unit_cycles = pim_dist_unit_cycles(wl, n, n_devices, cfg=cfg) + check
    unit_bytes = pim_dist_unit_bytes(wl, n, n_devices)
    units = _pim_units(workload, batch, real=real)
    if workload == "polymul-real" and real:
        units = max(1, math.ceil(batch / 2))    # dist form is per pair
    capacity = max(1, int(cfg.num_crossbars * cfg.concurrency))
    waves = math.ceil(units / capacity)
    t_comp = waves * unit_cycles / cfg.clock_hz
    coll = units * unit_bytes
    t_coll = (coll / n_devices) / LINK_BW
    return TierCost(tier="distributed", backend="pim", real=real,
                    exact=exact, seq_shards=n_devices,
                    total_s=t_comp + t_coll, t_compute_s=t_comp,
                    t_collective_s=t_coll, pim_cycles=unit_cycles,
                    collective_bytes=coll)


# ---------------------------------------------------------------------------
# The chooser
# ---------------------------------------------------------------------------

def _packings(workload: str) -> list[bool]:
    """Packing candidates (``real`` flag values) per workload. Real
    workloads may fall back to complex packing (cast + full-width route)
    when the real tier is pruned — e.g. the ordered distributed rfft's
    2*D^2 | n constraint where the complex tier only needs D^2 | n."""
    if workload in ("rfft", "polymul-real"):
        return [True, False]
    return [False]


def workload_cost(workload: str, n: int, batch: int, *,
                  n_devices: int = 1,
                  tiers: tuple[str, ...] = ("local", "distributed"),
                  packings: list[bool] | None = None,
                  verified: bool = False, pim_ok: bool = True) -> dict:
    """Score every executable (tier, packing) candidate on both backends.

    ``verified=True`` prices the ABFT integrity check on every backend
    (``abft_check_cycles`` on PIM, the O(n) host reductions on XLA) so a
    verified serve bucket's predicted costs include the checksum
    overhead. ``pim_ok=False`` marks the PIM backend infeasible on every
    candidate — the serve engine's circuit breaker quarantining a faulty
    array re-plans with the PIM placement off the table.

    Returns a machine-readable breakdown::

        {"workload", "n", "batch", "n_devices",
         "candidates": [{"tier", "real", "exact", "total_s",
                         "backend_best", "backends": {...}}, ...],
         "pruned":     [{"tier", "real", "reason"}, ...],
         "best":       <cheapest candidate or None>,
         "constants":  {"peak_flops", "hbm_bw", "link_bw"}}

    A candidate is listed iff the XLA path can execute it (the planner
    never returns a plan ``bind()`` rejects); the PIM backend may be
    marked infeasible per candidate (crossbar columns, the ``n1 = D``
    cap) without pruning the candidate itself — the plan still runs on
    the host, it just doesn't win a PIM placement.
    """
    _require_pow2(n)
    if workload not in WORKLOADS:
        raise ValueError(f"unknown workload {workload!r}; "
                         f"expected one of {WORKLOADS}")
    exact = workload == "polymul-mod"
    candidates, pruned = [], []
    for tier in tiers:
        for real in (packings if packings is not None
                     else _packings(workload)):
            if tier == "local":
                reason = local_prune_reason(workload, n)
            else:
                reason = dist_prune_reason(workload, n, n_devices,
                                           real=real)
            if reason is not None:
                pruned.append({"tier": tier, "real": real, "exact": exact,
                               "reason": reason})
                continue
            backends = {}
            xc = xla_cost(workload, n, batch, tier=tier,
                          n_devices=n_devices, real=real,
                          verified=verified)
            backends["xla"] = xc.as_dict()
            if not pim_ok:
                pim_bad = ("quarantined (circuit breaker): pim backend "
                           "disabled for this bucket")
            elif tier == "local":
                pim_bad = pim_local_infeasible(
                    _pim_workload(workload, real), n)
            else:
                pim_bad = pim_dist_infeasible(n, n_devices)
            if pim_bad is None:
                pc = pim_cost(workload, n, batch, tier=tier,
                              n_devices=n_devices, real=real,
                              verified=verified)
                backends["pim"] = pc.as_dict()
                best_backend = ("pim" if pc.total_s <= xc.total_s
                                else "xla")
                total = min(pc.total_s, xc.total_s)
            else:
                backends["pim"] = {"infeasible": pim_bad}
                best_backend, total = "xla", xc.total_s
            candidates.append({"tier": tier, "real": real, "exact": exact,
                               "seq_shards": (n_devices
                                              if tier == "distributed"
                                              else 1),
                               "total_s": total,
                               "backend_best": best_backend,
                               "backends": backends})
    # Deterministic tie-break: cheapest first; on ties prefer local over
    # distributed (fewer moving parts), then real packing over complex
    # (the route the workload named). Sort is stable, so encode the
    # preference in the key.
    candidates.sort(key=lambda c: (c["total_s"],
                                   c["tier"] != "local",
                                   not c["real"]))
    return {"workload": workload, "n": n, "batch": batch,
            "n_devices": n_devices,
            "candidates": candidates, "pruned": pruned,
            "best": candidates[0] if candidates else None,
            "constants": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                          "link_bw": LINK_BW}}
