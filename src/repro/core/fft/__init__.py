"""TPU-native Fourier ops (production path of the FourierPIM reproduction).

Public surface:
  fft / ifft / polymul / realpack_fft / fft_causal_conv   (kernels.ops)
  rfft / irfft / polymul_real                             (real fast path)
  packed_to_halfspec / halfspec_to_packed                 (layout converters)
  fft_distributed / make_sharded_fft / make_sharded_polymul (four-step)
  rfft_distributed / irfft_distributed / polymul_real_distributed
  make_sharded_rfft / make_sharded_irfft / make_sharded_polymul_real
  four_step_collective_stats                               (byte ledger form)
  plan / FFTPlan                                           (planner)
"""
from repro.kernels.ops import (fft, fft_causal_conv, halfspec_to_packed,
                               ifft, irfft, packed_to_halfspec, polymul,
                               polymul_real, realpack_fft, rfft)
from repro.core.fft.distributed import (fft_distributed,
                                        four_step_collective_stats,
                                        irfft_distributed, make_sharded_fft,
                                        make_sharded_irfft,
                                        make_sharded_polymul,
                                        make_sharded_polymul_real,
                                        make_sharded_rfft,
                                        polymul_real_distributed,
                                        rfft_distributed)
from repro.core.fft.planner import FFTPlan, plan

__all__ = [
    "fft", "ifft", "rfft", "irfft", "polymul", "polymul_real",
    "realpack_fft", "fft_causal_conv",
    "packed_to_halfspec", "halfspec_to_packed",
    "fft_distributed", "make_sharded_fft", "make_sharded_polymul",
    "rfft_distributed", "irfft_distributed", "polymul_real_distributed",
    "make_sharded_rfft", "make_sharded_irfft", "make_sharded_polymul_real",
    "four_step_collective_stats",
    "FFTPlan", "plan",
]
