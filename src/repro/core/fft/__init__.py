"""TPU-native Fourier ops (production path of the FourierPIM reproduction).

Public surface:
  fft / ifft / polymul / realpack_fft / fft_causal_conv   (kernels.ops)
  rfft / irfft / polymul_real                             (real fast path)
  fft_distributed / make_sharded_fft / make_sharded_polymul (four-step)
  plan / FFTPlan                                           (planner)
"""
from repro.kernels.ops import (fft, fft_causal_conv, ifft, irfft, polymul,
                               polymul_real, realpack_fft, rfft)
from repro.core.fft.distributed import (fft_distributed, make_sharded_fft,
                                        make_sharded_polymul)
from repro.core.fft.planner import FFTPlan, plan

__all__ = [
    "fft", "ifft", "rfft", "irfft", "polymul", "polymul_real",
    "realpack_fft", "fft_causal_conv",
    "fft_distributed", "make_sharded_fft", "make_sharded_polymul",
    "FFTPlan", "plan",
]
