"""Distributed four-step (Bailey) FFT across a mesh axis via shard_map.

FourierPIM §7 leaves "multi-crossbar FFT" as future work: a transform whose
sequence exceeds one array. This module is that extension on the TPU mesh:
the sequence dimension is sharded across the ``model`` axis and the transform
is computed as

  n = n1 * n2,  x viewed as M[j1, j2] (row-major, j = j1*n2 + j2)
  1. all-to-all transpose so each device owns all j1 for a j2 slice
  2. local FFT_{n1} along j1                        -> Y[k1, j2]
  3. twiddle multiply by omega_n^{j2 k1}            (local)
  4. all-to-all transpose so each device owns all j2 for a k1 slice
  5. local FFT_{n2} along j2                        -> Z[k1, k2]
  X[k1 + k2*n1] = Z[k1, k2]

With ``ordered=False`` the result stays in Z-order (k1-sharded): for
convolution/polymul the pointwise product is order-agnostic as long as both
operands share the order, and the inverse transform undoes it — saving one
all-to-all per transform in each direction. This mirrors the paper's
cancellation of the FFT/IFFT input permutations across DFT.IDFT (§5), lifted
to the collective level.

All collectives go through `repro.dist.collectives.all_to_all(tiled=True)`
inside `shard_map`, so the dry-run HLO shows real all-to-all ops AND the
moved bytes land in the `dist.collectives` ledger the roofline accounting
reads.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import batching, collectives
from repro.dist.compat import shard_map
from repro.kernels import ops as kops


def _local_fft(x: jax.Array, *, inverse: bool, backend: str | None) -> jax.Array:
    return kops.fft(x, inverse=inverse, backend=backend)


def _twiddle(n: int, n1: int, n2: int, j2_start: int, j2_len: int,
             inverse: bool) -> jax.Array:
    """omega_n^{j2 k1} block for local j2 slice; shape (n1, j2_len)."""
    k1 = jnp.arange(n1, dtype=jnp.float32)[:, None]
    j2 = (j2_start + jnp.arange(j2_len, dtype=jnp.float32))[None, :]
    sign = 1.0 if inverse else -1.0
    ang = sign * 2.0 * jnp.pi * (k1 * j2) / n
    return jnp.cos(ang) + 1j * jnp.sin(ang)


def fft_distributed(x: jax.Array, *, axis_name: str = "model",
                    n_devices: int, inverse: bool = False,
                    ordered: bool = True, backend: str | None = None,
                    _in_zorder: bool = False) -> jax.Array:
    """FFT of (..., n) with the last axis sharded over ``axis_name``.

    Must be called INSIDE shard_map: ``x`` is the per-device local block
    (..., n / D). n1 = D * ceil-pow2 rows, n2 = n / n1 — we pick n1 = D so
    each all-to-all moves exactly one tile per peer and local FFT lengths
    stay balanced (planner may override by reshaping beforehand).
    """
    D = n_devices
    *lead, n_loc = x.shape
    n = n_loc * D
    n1, n2 = D, n_loc
    idx = jax.lax.axis_index(axis_name)
    x = x.astype(jnp.complex64)

    if not inverse:
        # Local block is M[j1 in my chunk, j2 all] = (n1/D=1 rows of j1 ... )
        # With n1 = D each device holds exactly one j1 row: (..., 1, n2).
        m = x.reshape(*lead, 1, n2)
        # Step 1: transpose -> each device owns all j1 for a j2 slice.
        m = collectives.all_to_all(m, axis_name, split_axis=len(lead) + 1,
                                   concat_axis=len(lead), tiled=True)
        # Now (..., n1, n2/D); axis -2 is full j1.
        y = _local_fft(jnp.swapaxes(m, -1, -2), inverse=False, backend=backend)
        y = jnp.swapaxes(y, -1, -2)  # (..., n1=k1, n2/D)
        tw = _twiddle(n, n1, n2, 0, n2 // D, inverse)
        # global j2 = idx * (n2/D) + local: omega^{k1 * j2} =
        # omega^{k1 * local} * omega^{k1 * idx * n2/D}
        k1 = jnp.arange(n1, dtype=jnp.float32)
        ang = (1.0 if inverse else -1.0) * 2.0 * jnp.pi * k1 * (
            idx.astype(jnp.float32) * (n2 // D)) / n
        phase = (jnp.cos(ang) + 1j * jnp.sin(ang))[:, None]
        y = y * (tw * phase)
        # Step 4: transpose -> each device owns all j2 for a k1 slice.
        y = collectives.all_to_all(y, axis_name, split_axis=len(lead),
                                   concat_axis=len(lead) + 1, tiled=True)
        # (..., n1/D=1? no: split k1 (axis -2) across D, concat j2: (..., 1, n2))
        z = _local_fft(y.reshape(*lead, n2), inverse=False, backend=backend)
        z = z.reshape(*lead, 1, n2)
        if not ordered:
            return z.reshape(*lead, n_loc)  # Z-order: k1-sharded, k2 local
        # Step 7: Z[k1, k2] -> natural order X[k1 + k2 n1], outer digit k2
        # sharded: transpose once more.
        z = collectives.all_to_all(z, axis_name, split_axis=len(lead) + 1,
                                   concat_axis=len(lead), tiled=True)
        # (..., D, n2/D) rows k1 full? After split of k2-axis: each device has
        # Z[k1 all? ...]. Layout: (..., n1, n2/D) with j2 slice owned.
        z = jnp.swapaxes(z, -1, -2)  # (..., n2/D, n1): [k2_local, k1]
        return z.reshape(*lead, n_loc)
    else:
        # Inverse of the above; input in Z-order if _in_zorder else natural.
        if not _in_zorder:
            # natural X sharded by outer k2 chunk: (..., n2/D, n1) view
            z = x.reshape(*lead, n2 // D, n1)
            z = jnp.swapaxes(z, -1, -2)  # (..., n1, n2/D)
            z = collectives.all_to_all(z, axis_name, split_axis=len(lead),
                                       concat_axis=len(lead) + 1, tiled=True)
            # (..., 1, n2): one k1 row, all k2
            z = z.reshape(*lead, n2)
        else:
            z = x
        # Undo step 5: inverse local FFT over k2.
        y = _local_fft(z, inverse=True, backend=backend)
        y = y.reshape(*lead, 1, n2)
        # Undo step 4.
        y = collectives.all_to_all(y, axis_name, split_axis=len(lead) + 1,
                                   concat_axis=len(lead), tiled=True)
        # (..., n1, n2/D): all k1 for a j2 slice. Undo twiddle (conjugate).
        tw = _twiddle(n, n1, n2, 0, n2 // D, inverse=True)
        k1 = jnp.arange(n1, dtype=jnp.float32)
        ang = 2.0 * jnp.pi * k1 * (idx.astype(jnp.float32) * (n2 // D)) / n
        phase = (jnp.cos(ang) + 1j * jnp.sin(ang))[:, None]
        y = y * (tw * phase)
        # Undo step 2: inverse local FFT over j1 (axis -2).
        m = _local_fft(jnp.swapaxes(y, -1, -2), inverse=True, backend=backend)
        m = jnp.swapaxes(m, -1, -2)
        # Undo step 1 transpose.
        m = collectives.all_to_all(m, axis_name, split_axis=len(lead),
                                   concat_axis=len(lead) + 1, tiled=True)
        return m.reshape(*lead, n_loc)


def make_sharded_fft(mesh: jax.sharding.Mesh, *, axis_name: str = "model",
                     batch_axes: Sequence[str] = ("data",),
                     inverse: bool = False, ordered: bool = True,
                     backend: str | None = None):
    """Build a jit-able distributed FFT over ``mesh``: (B, n) -> (B, n).

    Batch is sharded over ``batch_axes``; the transform dimension over
    ``axis_name``.
    """
    D = mesh.shape[axis_name]
    spec = P(tuple(batch_axes), axis_name)

    fn = functools.partial(fft_distributed, axis_name=axis_name, n_devices=D,
                           inverse=inverse, ordered=ordered, backend=backend)
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)


def make_sharded_polymul(mesh: jax.sharding.Mesh, *, axis_name: str = "model",
                         batch_axes: Sequence[str] = ("data",),
                         backend: str | None = None):
    """Distributed circular polymul: both transforms stay in Z-order, the
    pointwise product is local, and the final inverse restores natural order.
    Saves 2 all-to-alls per call vs. composing ordered transforms."""
    D = mesh.shape[axis_name]
    spec = P(tuple(batch_axes), axis_name)

    def local_fn(a, b):
        fa = fft_distributed(a, axis_name=axis_name, n_devices=D,
                             ordered=False, backend=backend)
        fb = fft_distributed(b, axis_name=axis_name, n_devices=D,
                             ordered=False, backend=backend)
        prod = fa * fb
        return fft_distributed(prod, axis_name=axis_name, n_devices=D,
                               inverse=True, _in_zorder=True, backend=backend)

    return shard_map(local_fn, mesh=mesh, in_specs=(spec, spec),
                     out_specs=spec, check_vma=False)


def batch_plan(mesh: jax.sharding.Mesh, batch: int, *,
               batch_axes: Sequence[str] = ("pod", "data"),
               transforms_per_device: int = 1) -> batching.CrossbarBatchPlan:
    """Map B transforms onto the mesh's batch axes (paper-§6 batching lifted
    to the pod level): per-device share, wave count, and the utilization the
    tail wave / mesh padding costs. ``transforms_per_device`` is how many
    transforms one device runs concurrently (1 for the XLA path)."""
    return batching.plan_crossbar_batch(
        batch, num_arrays=transforms_per_device, mesh=mesh,
        axes=tuple(batch_axes))
