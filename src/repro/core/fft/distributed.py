"""Distributed four-step (Bailey) FFT across a mesh axis via shard_map.

FourierPIM §7 leaves "multi-crossbar FFT" as future work: a transform whose
sequence exceeds one array. This module is that extension on the TPU mesh:
the sequence dimension is sharded across the ``model`` axis and the transform
is computed as

  n = n1 * n2,  x viewed as M[j1, j2] (row-major, j = j1*n2 + j2)
  1. all-to-all transpose so each device owns all j1 for a j2 slice
  2. local FFT_{n1} along j1                        -> Y[k1, j2]
  3. twiddle multiply by omega_n^{j2 k1}            (local)
  4. all-to-all transpose so each device owns all j2 for a k1 slice
  5. local FFT_{n2} along j2                        -> Z[k1, k2]
  X[k1 + k2*n1] = Z[k1, k2]

With ``ordered=False`` the result stays in Z-order (k1-sharded): for
convolution/polymul the pointwise product is order-agnostic as long as both
operands share the order, and the inverse transform undoes it — saving one
all-to-all per transform in each direction. This mirrors the paper's
cancellation of the FFT/IFFT input permutations across DFT.IDFT (§5), lifted
to the collective level.

Step-3 twiddles are built from integer exponents reduced mod n (exact at any
n) with the angles evaluated in float64 host-side and rounded ONCE to
complex64. An earlier revision computed ``k1 * j2`` and the device phase in
float32 inside the trace, which accumulates several float32 roundings per
twiddle (pinned at ~4e-7 vs ~4e-8 for this path by the regression test in
tests/test_dist_real.py; the end-to-end n=2^20 pin is in
tests/test_distributed_fft.py).

Real-Hermitian tier (the serving tier for real coefficients): the packed
transform Z = FFT(a + i b) runs four-step in Z-order and the Eq.-(10)
Hermitian split happens PER SHARD, before any ordering collective. The
conjugate bin n-k of a Z-order bin k = idx + D*k2 lives at k1' = (D - idx)
mod D — a single known peer — so one ppermute to the mirror device routes
every conjugate partner, and only the packed half-spectrum (half the
complex width) ever crosses the interconnect in the ordering all-to-all.
``four_step_collective_stats`` is the byte-ledger closed form; the real
tier's total traffic is 3.5/6 ~ 0.58x the complex path's (gated <= 0.6 in
benchmarks/run.py --smoke).

All collectives go through ``repro.dist.collectives`` (all_to_all/ppermute)
inside ``shard_map``, so the dry-run HLO shows real collective ops AND the
moved bytes land in the `dist.collectives` ledger the roofline accounting
reads.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import batching, collectives
from repro.dist.compat import shard_map
from repro.kernels import ops as kops


def _local_fft(x: jax.Array, *, inverse: bool, backend: str | None) -> jax.Array:
    return kops.fft(x, inverse=inverse, backend=backend)


def check_four_step_shape(n: int, n_devices: int, *, real: bool = False) -> None:
    """Validate that the four-step decomposition is well formed for (n, D).

    The transposes split the local j2/k2 axis into D tiles and the step-3
    twiddle block is (D, n2/D) wide, so D^2 must divide n (2*D^2 for the
    real tier, whose ordering all-to-all moves n/(2D)-wide half-spectrum
    tiles). A non-dividing shape used to fall through to ``n2 // D``
    truncation / opaque all_to_all shape errors deep inside the trace;
    rejecting here keeps the failure loud and attributable.
    """
    D = n_devices
    if D < 1:
        raise ValueError(f"n_devices={D} must be >= 1")
    need = 2 * D * D if real else D * D
    if n % need or n < need:
        tier = "real four-step" if real else "four-step"
        raise ValueError(
            f"{tier} FFT needs {'2*' if real else ''}D^2 | n so every "
            f"all-to-all tile and twiddle slice is whole: got n={n}, "
            f"D={D} (n % {need} = {n % need})")


@functools.lru_cache(maxsize=64)
def _twiddle_tables(n: int, n1: int, width: int, inverse: bool
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Host-side step-3 twiddle tables, exact-integer exponents, fp64 angles.

    ``local[k1, j2] = w_n^{+-k1*j2}`` for the local j2 slice (j2 < width)
    and ``offset[m] = w_n^{+-m*width}`` for m = k1*idx < n1^2 — the global
    j2 offset of device idx enters as ``local * offset[k1*idx]``, so every
    factor is exp of an exponent reduced mod n in int64 (never a float
    product) evaluated in float64 and rounded once to complex64. Cached as
    NUMPY: jnp values cached across traces would leak tracers.
    """
    sign = 1.0 if inverse else -1.0
    k1 = np.arange(n1, dtype=np.int64)[:, None]
    j2 = np.arange(width, dtype=np.int64)[None, :]
    local = np.exp(sign * 2j * np.pi * ((k1 * j2) % n) / n)
    m = np.arange(n1 * n1, dtype=np.int64)
    offset = np.exp(sign * 2j * np.pi * ((m * width) % n) / n)
    return local.astype(np.complex64), offset.astype(np.complex64)


def _twiddle(n: int, n1: int, width: int, idx: jax.Array,
             inverse: bool) -> jax.Array:
    """w_n^{+-k1*j2} for device ``idx``'s global j2 slice; shape (n1, width)."""
    local, offset = _twiddle_tables(n, n1, width, inverse)
    k1 = jnp.arange(n1, dtype=jnp.int32)
    phase = jnp.asarray(offset)[k1 * idx.astype(jnp.int32)]
    return jnp.asarray(local) * phase[:, None]


def fft_distributed(x: jax.Array, *, axis_name: str = "model",
                    n_devices: int, inverse: bool = False,
                    ordered: bool = True, backend: str | None = None,
                    _in_zorder: bool = False) -> jax.Array:
    """FFT of (..., n) with the last axis sharded over ``axis_name``.

    Must be called INSIDE shard_map: ``x`` is the per-device local block
    (..., n / D). n1 = D * ceil-pow2 rows, n2 = n / n1 — we pick n1 = D so
    each all-to-all moves exactly one tile per peer and local FFT lengths
    stay balanced (planner may override by reshaping beforehand).
    """
    D = n_devices
    *lead, n_loc = x.shape
    n = n_loc * D
    check_four_step_shape(n, D)
    n1, n2 = D, n_loc
    idx = jax.lax.axis_index(axis_name)
    x = x.astype(jnp.complex64)

    if not inverse:
        # Local block is M[j1 in my chunk, j2 all] = (n1/D=1 rows of j1 ... )
        # With n1 = D each device holds exactly one j1 row: (..., 1, n2).
        m = x.reshape(*lead, 1, n2)
        # Step 1: transpose -> each device owns all j1 for a j2 slice.
        m = collectives.all_to_all(m, axis_name, split_axis=len(lead) + 1,
                                   concat_axis=len(lead), tiled=True)
        # Now (..., n1, n2/D); axis -2 is full j1.
        y = _local_fft(jnp.swapaxes(m, -1, -2), inverse=False, backend=backend)
        y = jnp.swapaxes(y, -1, -2)  # (..., n1=k1, n2/D)
        # global j2 = idx * (n2/D) + local; the exact-exponent table pair
        # folds the offset in (see _twiddle_tables).
        y = y * _twiddle(n, n1, n2 // D, idx, inverse)
        # Step 4: transpose -> each device owns all j2 for a k1 slice.
        y = collectives.all_to_all(y, axis_name, split_axis=len(lead),
                                   concat_axis=len(lead) + 1, tiled=True)
        # (..., n1/D=1? no: split k1 (axis -2) across D, concat j2: (..., 1, n2))
        z = _local_fft(y.reshape(*lead, n2), inverse=False, backend=backend)
        z = z.reshape(*lead, 1, n2)
        if not ordered:
            return z.reshape(*lead, n_loc)  # Z-order: k1-sharded, k2 local
        # Step 7: Z[k1, k2] -> natural order X[k1 + k2 n1], outer digit k2
        # sharded: transpose once more.
        z = collectives.all_to_all(z, axis_name, split_axis=len(lead) + 1,
                                   concat_axis=len(lead), tiled=True)
        # (..., D, n2/D) rows k1 full? After split of k2-axis: each device has
        # Z[k1 all? ...]. Layout: (..., n1, n2/D) with j2 slice owned.
        z = jnp.swapaxes(z, -1, -2)  # (..., n2/D, n1): [k2_local, k1]
        return z.reshape(*lead, n_loc)
    else:
        # Inverse of the above; input in Z-order if _in_zorder else natural.
        if not _in_zorder:
            # natural X sharded by outer k2 chunk: (..., n2/D, n1) view
            z = x.reshape(*lead, n2 // D, n1)
            z = jnp.swapaxes(z, -1, -2)  # (..., n1, n2/D)
            z = collectives.all_to_all(z, axis_name, split_axis=len(lead),
                                       concat_axis=len(lead) + 1, tiled=True)
            # (..., 1, n2): one k1 row, all k2
            z = z.reshape(*lead, n2)
        else:
            z = x
        # Undo step 5: inverse local FFT over k2.
        y = _local_fft(z, inverse=True, backend=backend)
        y = y.reshape(*lead, 1, n2)
        # Undo step 4.
        y = collectives.all_to_all(y, axis_name, split_axis=len(lead) + 1,
                                   concat_axis=len(lead), tiled=True)
        # (..., n1, n2/D): all k1 for a j2 slice. Undo twiddle (conjugate).
        y = y * _twiddle(n, n1, n2 // D, idx, inverse=True)
        # Undo step 2: inverse local FFT over j1 (axis -2).
        m = _local_fft(jnp.swapaxes(y, -1, -2), inverse=True, backend=backend)
        m = jnp.swapaxes(m, -1, -2)
        # Undo step 1 transpose.
        m = collectives.all_to_all(m, axis_name, split_axis=len(lead),
                                   concat_axis=len(lead) + 1, tiled=True)
        return m.reshape(*lead, n_loc)


# ---------------------------------------------------------------------------
# Real-Hermitian tier: per-shard split, half-width collectives.
# ---------------------------------------------------------------------------

def _mirror_perm(n_devices: int) -> tuple[tuple[int, int], ...]:
    """The conjugate-bin route: Z-order bin k = idx + D*k2 has its mirror
    n-k at k1' = (D - idx) mod D, so every device's partner block lives on
    one peer (devices 0 and D/2 are their own mirror)."""
    return tuple((i, (n_devices - i) % n_devices) for i in range(n_devices))


def _split_even_odd(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    return x[..., 0::2, :], x[..., 1::2, :]


def _interleave_rows(a: jax.Array, b: jax.Array) -> jax.Array:
    *lead, half, w = a.shape
    return jnp.stack([a, b], axis=-2).reshape(*lead, 2 * half, w)


def _require_row_pairs(b: int, what: str) -> None:
    if b % 2:
        raise ValueError(
            f"{what} pairs rows two-for-one (z = row[2j] + i row[2j+1]); "
            f"the local batch must be even, got {b}")


def _zhalf_to_natural(p: jax.Array, axis_name: str, D: int) -> jax.Array:
    """Z-half layout (device idx owns packed bins idx + D*k2) -> natural
    contiguous chunks, via one half-width all-to-all + local transpose."""
    *lead, w = p.shape
    la = len(lead)
    p = p.reshape(*lead, 1, w)
    p = collectives.all_to_all(p, axis_name, split_axis=la + 1,
                               concat_axis=la, tiled=True)   # (..., D, w/D)
    return jnp.swapaxes(p, -1, -2).reshape(*lead, w)


def _natural_to_zhalf(p: jax.Array, axis_name: str, D: int) -> jax.Array:
    *lead, w = p.shape
    la = len(lead)
    p = jnp.swapaxes(p.reshape(*lead, w // D, D), -1, -2)    # (..., D, w/D)
    p = collectives.all_to_all(p, axis_name, split_axis=la,
                               concat_axis=la + 1, tiled=True)
    return p.reshape(*lead, w)


def rfft_distributed(x: jax.Array, *, axis_name: str = "model",
                     n_devices: int, ordered: bool = True,
                     backend: str | None = None) -> jax.Array:
    """Packed half-spectrum FFT of real rows, sequence-sharded.

    Must be called INSIDE shard_map. ``x`` is the local real block
    (..., B, n/D) with B even: rows pair two-for-one (Z = FFT(row[2j] +
    i row[2j+1])) through the Z-order four-step transform, then the
    Hermitian split (Eq. (10)) runs per shard — the conjugate bin comes
    from the mirror peer via one HALF-width ppermute — and the result is
    the packed-Nyquist half-spectrum (kernels/fft.py layout: bin 0 carries
    DC.re + i*Nyquist.re), (..., B, n/(2D)) complex64 per device.

    ``ordered=True`` finishes with the ordering all-to-all at HALF the
    complex width (device d owns packed bins [d*n/(2D), (d+1)*n/(2D)));
    ``ordered=False`` leaves the Z-half layout (bin k on device k mod D)
    for pipelines that stay in frequency space.
    """
    D = n_devices
    *lead, B, n_loc = x.shape
    # real=ordered: the half-width ordering all-to-all (2*D^2 | n) only
    # runs for ordered output; the Z-half layout needs just D^2 | n.
    check_four_step_shape(n_loc * D, D, real=ordered)
    _require_row_pairs(B, "rfft_distributed")
    nh = n_loc // 2
    idx = jax.lax.axis_index(axis_name)
    ev, od = _split_even_odd(x)
    z = ev.astype(jnp.complex64) + 1j * od.astype(jnp.complex64)
    zz = fft_distributed(z, axis_name=axis_name, n_devices=D, ordered=False,
                         backend=backend)               # Z-order (.., B/2, n/D)
    zd, zu = zz[..., :nh], zz[..., nh:]
    # Mirror route: Z_{n-k} for my kept (lower-half) bins lives in the
    # UPPER half of the mirror peer's block — half the block crosses.
    mu = collectives.ppermute(zu, axis_name, _mirror_perm(D))
    flip = jnp.flip(mu, axis=-1)
    # Device 0 wraps: its bin 0 is self-conjugate and its other mirrors sit
    # one slot off the pure reversal (k2' = n2 - k2, not n2 - 1 - k2).
    wrap = jnp.concatenate([zd[..., :1], flip[..., :-1]], axis=-1)
    zm = jnp.where(idx == 0, wrap, flip)
    a = 0.5 * (zd + jnp.conj(zm))
    b = -0.5j * (zd - jnp.conj(zm))
    # Packed-Nyquist bin 0 on device 0: X[0] and X[n/2] are both real and
    # both live here (k2 = 0 and k2 = n2/2 of the idx = 0 block).
    a0 = jnp.real(zd[..., :1]) + 1j * jnp.real(zu[..., :1])
    b0 = jnp.imag(zd[..., :1]) + 1j * jnp.imag(zu[..., :1])
    is0 = idx == 0
    a = jnp.concatenate([jnp.where(is0, a0, a[..., :1]), a[..., 1:]], axis=-1)
    b = jnp.concatenate([jnp.where(is0, b0, b[..., :1]), b[..., 1:]], axis=-1)
    p = _interleave_rows(a, b)                          # (..., B, n/(2D))
    if not ordered:
        return p
    return _zhalf_to_natural(p, axis_name, D)


def irfft_distributed(p: jax.Array, *, axis_name: str = "model",
                      n_devices: int, ordered: bool = True,
                      backend: str | None = None) -> jax.Array:
    """Inverse of ``rfft_distributed``: packed half-spectra (..., B, n/(2D))
    -> real rows (..., B, n/D).

    The full Z-order spectrum is re-mirrored per shard before the inverse
    four-step: the upper-half bins are conj(V_{n-k}) with V = A - iB, so
    ONE half-width ppermute of V routes every mirror and two spectra ride
    one inverse complex transform (Z = A + iB). ``ordered`` describes the
    INPUT layout (natural vs Z-half), matching the forward's output.
    """
    D = n_devices
    *lead, B, w = p.shape
    n_loc = 2 * w
    check_four_step_shape(n_loc * D, D, real=ordered)
    _require_row_pairs(B, "irfft_distributed")
    idx = jax.lax.axis_index(axis_name)
    p = p.astype(jnp.complex64)
    if ordered:
        p = _natural_to_zhalf(p, axis_name, D)
    pa, pb = _split_even_odd(p)
    # Unpack device 0's packed-Nyquist bin 0: A[0] = re, A[n/2] = im.
    is0 = idx == 0
    a_nyq = jnp.imag(pa[..., :1])
    b_nyq = jnp.imag(pb[..., :1])
    a0 = jnp.real(pa[..., :1]).astype(jnp.complex64)
    b0 = jnp.real(pb[..., :1]).astype(jnp.complex64)
    pa = jnp.concatenate([jnp.where(is0, a0, pa[..., :1]), pa[..., 1:]],
                         axis=-1)
    pb = jnp.concatenate([jnp.where(is0, b0, pb[..., :1]), pb[..., 1:]],
                         axis=-1)
    zd = pa + 1j * pb              # Z = A + iB at the kept (lower) bins
    v = pa - 1j * pb               # mirror carrier: Z_upper = conj(V_{n-k})
    vm = collectives.ppermute(v, axis_name, _mirror_perm(D))
    flip = jnp.conj(jnp.flip(vm, axis=-1))
    nyq = (a_nyq + 1j * b_nyq).astype(jnp.complex64)
    wrap = jnp.concatenate([nyq, flip[..., :-1]], axis=-1)
    zu = jnp.where(is0, wrap, flip)
    z = jnp.concatenate([zd, zu], axis=-1)              # Z-order full block
    out = fft_distributed(z, axis_name=axis_name, n_devices=D, inverse=True,
                          _in_zorder=True, backend=backend)
    x = _interleave_rows(jnp.real(out), jnp.imag(out))
    return x.astype(jnp.float32)


def polymul_real_distributed(a: jax.Array, b: jax.Array, *,
                             axis_name: str = "model", n_devices: int,
                             backend: str | None = None) -> jax.Array:
    """Circular product of REAL coefficient rows, sequence-sharded, with
    the paired inverse kept at the collective level.

    Per product, z = a + i b rides ONE Z-order forward transform; the
    product spectrum P = A*B = (Z^2 - conj(Z^2_{n-k})) / 4i needs only the
    mirror of Z^2, and for a PAIR of products the two mirrors travel as one
    block (W = Z0^2 - i Z1^2, so Q = P0 + i P1 = (S - conj(W_{n-k})) / 4i
    with S = Z0^2 + i Z1^2): one ppermute per pair. The shared inverse
    consumes Q in Z-order and lands both real results in natural order —
    1.5 transform-equivalents + half a permute per product, 3.5/6 ~ 0.58x
    the complex path's collective bytes (four_step_collective_stats).
    """
    D = n_devices
    if a.shape != b.shape:
        raise ValueError(f"operand shapes differ: {a.shape} vs {b.shape}")
    *lead, B, n_loc = a.shape
    check_four_step_shape(n_loc * D, D)
    _require_row_pairs(B, "polymul_real_distributed")
    idx = jax.lax.axis_index(axis_name)
    z = a.astype(jnp.complex64) + 1j * b.astype(jnp.complex64)
    zz = fft_distributed(z, axis_name=axis_name, n_devices=D, ordered=False,
                         backend=backend)
    z2 = zz * zz
    e, o = _split_even_odd(z2)
    s = e + 1j * o
    w = e - 1j * o
    wm = collectives.ppermute(w, axis_name, _mirror_perm(D))
    flip = jnp.flip(wm, axis=-1)
    # Full-block mirror: device 0's reversal wraps (bin 0 is its own
    # mirror), everyone else's is the pure flip of the peer block.
    wrap = jnp.concatenate([flip[..., -1:], flip[..., :-1]], axis=-1)
    wr = jnp.where(idx == 0, wrap, flip)
    q = -0.25j * (s - jnp.conj(wr))
    c = fft_distributed(q, axis_name=axis_name, n_devices=D, inverse=True,
                        _in_zorder=True, backend=backend)
    out = _interleave_rows(jnp.real(c), jnp.imag(c))
    return out.astype(jnp.float32)


# ---------------------------------------------------------------------------
# shard_map builders
# ---------------------------------------------------------------------------

def _seq_spec(batch_axes: Sequence[str], axis_name: str) -> P:
    return P(tuple(batch_axes) if batch_axes else None, axis_name)


def _checked_shard_map(fn, mesh, *, axis_name, batch_axes, n_args,
                       n_from, real: bool = False, pad_pairs: bool = False):
    """shard_map ``fn`` over the sequence spec and wrap it with the global
    shape guard — the one place the call-time ``check_four_step_shape``
    lives for every make_sharded_* builder. ``n_from`` maps the first
    argument to the GLOBAL transform length.

    ``pad_pairs=True`` (the row-pairing real tiers) accepts ODD batches:
    the tail row gets a zeros partner appended before the shard_map and the
    extra row is sliced off the result. The Eq.-(10) split/pair algebra is
    linear, so pairing a real row with zeros recovers that row's spectrum /
    product exactly — the pad changes no served value. (The pad is applied
    to the GLOBAL batch; when the batch axis is itself sharded over
    ``batch_axes``, callers must keep the padded batch divisible as usual.)
    """
    D = mesh.shape[axis_name]
    spec = _seq_spec(batch_axes, axis_name)
    mapped = shard_map(fn, mesh=mesh, in_specs=(spec,) * n_args,
                       out_specs=spec, check_vma=False)

    def wrapped(*args):
        check_four_step_shape(n_from(args[0]), D, real=real)
        if pad_pairs and args[0].ndim >= 2 and args[0].shape[-2] % 2:
            b = args[0].shape[-2]
            pads = [(0, 0)] * (args[0].ndim - 2) + [(0, 1), (0, 0)]
            args = tuple(jnp.pad(a, pads) for a in args)
            return mapped(*args)[..., :b, :]
        return mapped(*args)
    return wrapped


def make_sharded_fft(mesh: jax.sharding.Mesh, *, axis_name: str = "model",
                     batch_axes: Sequence[str] = ("data",),
                     inverse: bool = False, ordered: bool = True,
                     backend: str | None = None):
    """Build a jit-able distributed FFT over ``mesh``: (B, n) -> (B, n).

    Batch is sharded over ``batch_axes``; the transform dimension over
    ``axis_name``. Raises ValueError at call time when D^2 does not divide
    the global n (see ``check_four_step_shape``).
    """
    D = mesh.shape[axis_name]
    fn = functools.partial(fft_distributed, axis_name=axis_name, n_devices=D,
                           inverse=inverse, ordered=ordered, backend=backend)
    return _checked_shard_map(fn, mesh, axis_name=axis_name,
                              batch_axes=batch_axes, n_args=1,
                              n_from=lambda x: x.shape[-1])


def make_sharded_polymul(mesh: jax.sharding.Mesh, *, axis_name: str = "model",
                         batch_axes: Sequence[str] = ("data",),
                         backend: str | None = None):
    """Distributed circular polymul: both transforms stay in Z-order, the
    pointwise product is local, and the final inverse restores natural order.
    Saves 2 all-to-alls per call vs. composing ordered transforms."""
    D = mesh.shape[axis_name]

    def local_fn(a, b):
        fa = fft_distributed(a, axis_name=axis_name, n_devices=D,
                             ordered=False, backend=backend)
        fb = fft_distributed(b, axis_name=axis_name, n_devices=D,
                             ordered=False, backend=backend)
        prod = fa * fb
        return fft_distributed(prod, axis_name=axis_name, n_devices=D,
                               inverse=True, _in_zorder=True, backend=backend)

    return _checked_shard_map(local_fn, mesh, axis_name=axis_name,
                              batch_axes=batch_axes, n_args=2,
                              n_from=lambda a: a.shape[-1])


def make_sharded_rfft(mesh: jax.sharding.Mesh, *, axis_name: str = "model",
                      batch_axes: Sequence[str] = ("data",),
                      ordered: bool = True, backend: str | None = None):
    """jit-able distributed rfft: real (B, n) -> packed complex (B, n/2).

    Rows pair two-for-one per device; an ODD global batch is padded with a
    zeros partner internally and sliced off the result (``pad_pairs``), so
    any B >= 1 serves. ``batch_axes`` shards should keep pairs together —
    the default contiguous-block data sharding does.
    """
    D = mesh.shape[axis_name]
    fn = functools.partial(rfft_distributed, axis_name=axis_name,
                           n_devices=D, ordered=ordered, backend=backend)
    return _checked_shard_map(fn, mesh, axis_name=axis_name,
                              batch_axes=batch_axes, n_args=1,
                              n_from=lambda x: x.shape[-1], real=ordered,
                              pad_pairs=True)


def make_sharded_irfft(mesh: jax.sharding.Mesh, *, axis_name: str = "model",
                       batch_axes: Sequence[str] = ("data",),
                       ordered: bool = True, backend: str | None = None):
    """jit-able inverse: packed complex (B, n/2) -> real (B, n); odd
    batches pad a zeros half-spectrum internally (see ``pad_pairs``)."""
    D = mesh.shape[axis_name]
    fn = functools.partial(irfft_distributed, axis_name=axis_name,
                           n_devices=D, ordered=ordered, backend=backend)
    return _checked_shard_map(fn, mesh, axis_name=axis_name,
                              batch_axes=batch_axes, n_args=1,
                              n_from=lambda p: 2 * p.shape[-1], real=ordered,
                              pad_pairs=True)


def make_sharded_polymul_real(mesh: jax.sharding.Mesh, *,
                              axis_name: str = "model",
                              batch_axes: Sequence[str] = ("data",),
                              backend: str | None = None):
    """Distributed real circular polymul with the collective-level paired
    inverse (see ``polymul_real_distributed``). ODD global batches are
    accepted: the tail product pairs with a zeros product internally and
    the pad row is sliced off the result (``pad_pairs``) — the serve tier
    no longer needs an even --batch."""
    D = mesh.shape[axis_name]
    fn = functools.partial(polymul_real_distributed, axis_name=axis_name,
                           n_devices=D, backend=backend)
    return _checked_shard_map(fn, mesh, axis_name=axis_name,
                              batch_axes=batch_axes, n_args=2,
                              n_from=lambda a: a.shape[-1], pad_pairs=True)


# ---------------------------------------------------------------------------
# Collective-traffic closed forms (ledger units)
# ---------------------------------------------------------------------------

def four_step_collective_stats(n: int, batch: int, n_devices: int, *,
                               op: str = "fft", ordered: bool = True,
                               itemsize: int = 8) -> dict:
    """Closed-form collective traffic of one traced call, in the byte
    ledger's unit (local-block bytes per collective, complex64 items).
    Pinned against the live ``dist.collectives`` ledger in
    tests/test_dist_real.py and benchmarks/run.py --smoke.

    ``batch`` counts REAL rows for rfft/irfft (pairs ride one transform)
    and products for polymul ops. The real tier's total is 3.5 block-units
    against the complex path's 6 per product (0.583x — the <= 0.6 gate).
    """
    blk = batch * (n // n_devices) * itemsize          # one full-width call
    if op in ("fft", "ifft"):
        a2a, a2a_bytes, pp, pp_bytes = (3 if ordered else 2), 0, 0, 0
        a2a_bytes = a2a * blk
    elif op == "polymul":
        a2a, a2a_bytes, pp, pp_bytes = 6, 6 * blk, 0, 0
    elif op in ("rfft", "irfft"):
        if batch % 2:
            raise ValueError(f"{op} batch must be even, got {batch}")
        half = blk // 2                                # the packed pair block
        # forward/inverse four-step on B/2 packed rows: 2 calls of `half`;
        # the (un)ordering all-to-all moves B packed half-spectra = `half`.
        a2a = 3 if ordered else 2
        a2a_bytes = a2a * half
        pp, pp_bytes = 1, half // 2                    # half-width mirror
    elif op == "polymul_real":
        if batch % 2:
            raise ValueError(f"polymul_real batch must be even, got {batch}")
        # 2 forward calls at full batch + 2 inverse calls at half batch.
        a2a, a2a_bytes = 4, 2 * blk + 2 * (blk // 2)
        pp, pp_bytes = 1, blk // 2                     # one W block per pair
    else:
        raise ValueError(f"unknown op {op!r}")
    return {"a2a_count": a2a, "a2a_bytes": a2a_bytes,
            "ppermute_count": pp, "ppermute_bytes": pp_bytes,
            "total_bytes": a2a_bytes + pp_bytes}


def batch_plan(mesh: jax.sharding.Mesh, batch: int, *,
               batch_axes: Sequence[str] = ("pod", "data"),
               transforms_per_device: int = 1) -> batching.CrossbarBatchPlan:
    """Map B transforms onto the mesh's batch axes (paper-§6 batching lifted
    to the pod level): per-device share, wave count, and the utilization the
    tail wave / mesh padding costs. ``transforms_per_device`` is how many
    transforms one device runs concurrently (1 for the XLA path)."""
    return batching.plan_crossbar_batch(
        batch, num_arrays=transforms_per_device, mesh=mesh,
        axes=tuple(batch_axes))
