"""Kernel/distribution planner for Fourier ops.

Given (batch, n, mesh) decide:
  * execution tier: single-device Pallas kernel (batch-sharded) vs.
    distributed four-step FFT (sequence-sharded over the model axis);
  * kernel config: radix (2 or 4), batch block (VMEM budget).

The decision mirrors the paper's configuration ladder (§4.3-4.5): the
r/2r-configurations are "fits in one array" (-> our single-kernel tier, batch
across crossbars ↔ batch across devices), the 2r-beta configuration is
"sequence spans multiple column units" (-> our four-step tier across devices,
with the all-to-all playing the role of the inter-unit column swaps).
"""
from __future__ import annotations

import dataclasses

from repro.kernels.fft import VMEM_BUDGET_BYTES, plan_batch_block


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    tier: str           # "local" | "distributed"
    radix: int          # 2 or 4
    block_b: int        # batch block per kernel invocation (local tier)
    seq_shards: int     # model-axis shards of the sequence (distributed tier)
    #: exact modular route: transforms dispatch to the NTT kernel
    #: (kernels.ntt, radix-2 Montgomery butterflies) instead of the float
    #: FFT — required for crypto polymul where results must be bit-exact
    #: mod q (docs/ntt.md).
    exact: bool = False
    #: real-Hermitian route: two-for-one packed rfft/irfft kernels and the
    #: paired-inverse real polymul (kernels.fft.rfft_planes /
    #: kernels.polymul.polymul_real_planes) — half the butterflies and HBM
    #: traffic of the complex tier on real input, with the doubled batch
    #: block the halved working set buys (docs/fourier.md).
    real: bool = False
    #: machine-readable cost breakdown from ``core.cost.workload_cost``
    #: when the plan was auto-chosen (``plan(..., workload=...)``); None
    #: for explicit-knob plans. Excluded from eq/hash so auto plans and
    #: hand-built plans with the same execution config compare equal
    #: (the serve engine keys buckets on plans).
    cost: dict | None = dataclasses.field(default=None, compare=False)

    def describe(self) -> str:
        if self.exact:
            kind = "NTT (exact mod-q)"
        elif self.real:
            kind = "real-packed FFT (two-for-one Hermitian)"
        else:
            kind = "FFT"
        if self.tier == "local":
            return (f"local Pallas {kind} kernel, radix-{self.radix}, "
                    f"batch block {self.block_b} (VMEM-resident)")
        return (f"four-step distributed {kind} over {self.seq_shards} "
                f"devices, radix-{self.radix} local stages")


# A single sequence must keep ~2 fp32 planes x live factor in VMEM.
_MAX_LOCAL_N = VMEM_BUDGET_BYTES // (2 * 4 * 4)   # = 256K points
# Exact tier: one uint32 residue plane, ~4 live copies in the fused polymul
# (operands + transforms) — twice the float threshold per byte of VMEM.
_MAX_LOCAL_N_EXACT = VMEM_BUDGET_BYTES // (4 * 4)  # = 512K points
# Real tier: one fp32 plane per point PER SEQUENCE, but the minimum
# schedulable unit is a PAIR of rows packed into one full complex row
# (the kernels require even blocks), so the longest local sequence matches
# the complex tier — the packing doubles the batch block, not the ceiling.
# (Unlike the exact tier, whose single-uint32-plane rows schedule at
# blk=1 and genuinely halve the per-point footprint.)
_MAX_LOCAL_N_REAL = _MAX_LOCAL_N                   # = 256K points


def plan(n: int, batch: int, *, model_shards: int = 1,
         exact: bool = False, real: bool = False,
         force_distributed: bool = False,
         workload: str | None = None,
         verified: bool = False, pim_ok: bool = True) -> FFTPlan:
    """Execution plan for a batch of n-point transforms.

    ``exact=True`` routes to the modular-NTT tier (uint32 residues, radix-2
    only — the Montgomery butterfly has no radix-4 shortcut worth the lane
    pressure): the local Pallas kernel (``kernels.ntt``) while a sequence
    fits VMEM, else the four-step distributed decomposition
    (``core.ntt.distributed``) with per-shard roots
    (``NTTParams.subparams``) and ledger-accounted all-to-alls — the plan
    comes back with ``seq_shards > 1`` and ``exact=True``.
    ``real=True`` routes real-coefficient workloads (the paper's polymul
    serving case) to the two-for-one packed tier: the rfft/irfft kernels and
    the paired-inverse ``polymul_real`` with the DOUBLED batch block
    (``plan_batch_block(n, real=True)``) the halved per-row footprint buys.
    The local-n ceiling matches the complex tier (the minimum block is a
    row pair = one full complex row). Mutually exclusive with ``exact``
    (residues are not packed).
    ``force_distributed=True`` pins the distributed tier even where the
    policy would keep the sequence local (serve's explicit --model-shards
    request) — shape validation still applies, so the returned plan is
    the one actually executable, not a hand-built record.
    ``workload=`` switches to AUTO mode (docs/planner.md): the cost model
    in ``core.cost`` scores every executable (tier, packing) candidate —
    local vs four-step, real vs complex packing, PIM vs XLA backend — and
    the predicted-cheapest one comes back with the full breakdown on
    ``FFTPlan.cost``. Explicit knobs still win: ``real=True`` pins the
    packing, ``force_distributed=True`` pins the tier, and the legacy
    no-workload call is untouched. A workload with no executable
    candidate raises ValueError naming every pruned candidate's
    constraint (VMEM ceiling, ``D^2 | n`` tiling, ``2*D^2 | n`` for the
    ordered real tier) instead of a bare error.
    ``verified=True`` (auto mode only) prices the ABFT integrity check
    (``core.cost.abft_check_cycles``) into every candidate on both
    backends; ``pim_ok=False`` plans with the PIM backend off the table —
    the circuit-breaker re-bind of a quarantined serve bucket
    (docs/fault_tolerance.md).
    Raises ValueError on non-power-of-two n so misuse fails loudly instead
    of silently mis-planning (asserts vanish under ``python -O``).
    """
    if n <= 0 or n & (n - 1):
        raise ValueError(f"n={n} must be a power of two")
    if batch < 0:
        raise ValueError(f"batch={batch} must be non-negative")
    if exact and real:
        raise ValueError("exact (mod-q) and real (Hermitian) tiers are "
                         "mutually exclusive")
    if force_distributed and model_shards == 1:
        raise ValueError("force_distributed needs model_shards > 1")
    if workload is not None:
        return _plan_auto(n, batch, workload, model_shards,
                          exact=exact, real=real,
                          force_distributed=force_distributed,
                          verified=verified, pim_ok=pim_ok)
    if exact:
        if not force_distributed and (n <= _MAX_LOCAL_N_EXACT
                                      or model_shards == 1):
            return FFTPlan(tier="local", radix=2,
                           block_b=plan_batch_block(n), seq_shards=1,
                           exact=True)
        # The four-step NTT tiles identically to the float path (D^2 | n).
        _check_dist_shape(n, model_shards, real=False)
        return FFTPlan(tier="distributed", radix=2, block_b=1,
                       seq_shards=model_shards, exact=True)
    # Local tier: radix-4 halves the sweep count when n allows it. The
    # DISTRIBUTED tiers run their local stages through the XLA Stockham
    # (kops.fft, radix 2), so their plans record radix=2 — the plan
    # describes what executes, not the local kernel's preference.
    radix = 4 if (n.bit_length() - 1) >= 2 else 2
    if real:
        if not force_distributed and (n <= _MAX_LOCAL_N_REAL
                                      or model_shards == 1):
            return FFTPlan(tier="local", radix=radix,
                           block_b=plan_batch_block(n, real=True),
                           seq_shards=1, real=True)
        # Distributed real tier: the four-step path runs the packed complex
        # transform on z = a + i b per row PAIR with the Hermitian split
        # performed per shard before the ordering all-to-all, so the
        # half-spectrum crosses the interconnect at half the complex width
        # (core/fft/distributed.py rfft_distributed; ~0.58x the complex
        # tier's collective bytes — docs/fourier.md §distributed).
        # Validated at the tier's common requirement (D^2 | n, the
        # transposes + twiddle tiling); the ordered-rfft half-width
        # all-to-all additionally needs 2*D^2 | n, enforced where it is an
        # op property, not a tier property: check_four_step_shape(real=
        # ordered) in the kernel-layer wrappers. polymul_real only needs
        # D^2 | n, so the plan must not reject shapes it can execute.
        _check_dist_shape(n, model_shards, real=False)
        return FFTPlan(tier="distributed", radix=2, block_b=1,
                       seq_shards=model_shards, real=True)
    if not force_distributed and (n <= _MAX_LOCAL_N or model_shards == 1):
        return FFTPlan(tier="local", radix=radix,
                       block_b=plan_batch_block(n), seq_shards=1)
    _check_dist_shape(n, model_shards, real=False)
    return FFTPlan(tier="distributed", radix=2, block_b=1,
                   seq_shards=model_shards)


def _plan_auto(n: int, batch: int, workload: str, model_shards: int, *,
               exact: bool, real: bool, force_distributed: bool,
               verified: bool = False, pim_ok: bool = True) -> FFTPlan:
    """Cost-model-driven tier choice (docs/planner.md).

    The candidate space is every (tier, packing) pair the XLA kernels can
    execute for ``workload``; ``core.cost.workload_cost`` scores each on
    both backends and this returns the predicted-cheapest as a normal
    executable ``FFTPlan`` with the breakdown attached. Explicit knobs
    narrow the space rather than being ignored: ``real=True`` keeps only
    real-packed candidates, ``force_distributed=True`` only distributed
    ones. ``exact=`` must agree with the workload — the modular route is
    a workload property (``polymul-mod``), not a packing choice.
    """
    from repro.core.cost import WORKLOADS, workload_cost
    if workload not in WORKLOADS:
        raise ValueError(f"unknown workload {workload!r}; "
                         f"expected one of {WORKLOADS}")
    wl_exact = workload == "polymul-mod"
    if exact and not wl_exact:
        raise ValueError(f"exact=True conflicts with workload="
                         f"{workload!r}: the exact mod-q route is the "
                         f"'polymul-mod' workload")
    if real and workload not in ("rfft", "polymul-real"):
        raise ValueError(f"real=True conflicts with workload="
                         f"{workload!r}: only 'rfft'/'polymul-real' "
                         f"have a real-packed route")
    tiers = (("distributed",) if force_distributed
             else ("local", "distributed"))
    packings = [True] if real else None
    breakdown = workload_cost(workload, n, batch, n_devices=model_shards,
                              tiers=tiers, packings=packings,
                              verified=verified, pim_ok=pim_ok)
    best = breakdown["best"]
    if best is None:
        lines = [
            f"  - tier={p['tier']}"
            f"{', real-packed' if p['real'] else ''}: {p['reason']}"
            for p in breakdown["pruned"]]
        raise ValueError(
            f"no executable tier for workload={workload!r} n={n} over "
            f"{model_shards} shard(s); every candidate was pruned:\n"
            + "\n".join(lines))
    if best["tier"] == "local":
        radix = (2 if wl_exact
                 else (4 if (n.bit_length() - 1) >= 2 else 2))
        block = (plan_batch_block(n, real=True) if best["real"]
                 else plan_batch_block(n))
        return FFTPlan(tier="local", radix=radix, block_b=block,
                       seq_shards=1, exact=wl_exact, real=best["real"],
                       cost=breakdown)
    return FFTPlan(tier="distributed", radix=2, block_b=1,
                   seq_shards=model_shards, exact=wl_exact,
                   real=best["real"], cost=breakdown)


def _check_dist_shape(n: int, model_shards: int, *, real: bool) -> None:
    """Reject shapes the four-step decomposition cannot tile.

    The distributed tier needs D^2 | n (2*D^2 | n for the real tier's
    half-width ordering all-to-all). Such a shape cannot be re-tiered
    locally either — the planner only reaches here when n exceeds the
    local VMEM ceiling — so mis-sized shard counts fail at plan time
    instead of surfacing as truncated twiddle blocks mid-trace
    (``core.fft.distributed.check_four_step_shape`` is the same guard at
    the kernel layer).
    """
    from repro.core.fft.distributed import check_four_step_shape
    try:
        check_four_step_shape(n, model_shards, real=real)
    except ValueError as e:
        raise ValueError(
            f"cannot plan a distributed {'real ' if real else ''}FFT for "
            f"n={n} over {model_shards} shards: {e}") from e
