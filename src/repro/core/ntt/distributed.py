"""Distributed four-step NTT across a mesh axis via shard_map — exact.

The modular counterpart of ``core.fft.distributed``: the transform length is
sharded n = n1·n2 over a mesh axis (default ``data`` — the exact tier's
sequence dimension rides the data axis, leaving ``model`` for the float
stack), with n1 = D devices:

  x viewed as M[j1, j2] (row-major, j = j1·n2 + j2), k = k1 + k2·n1
  1. all-to-all transpose: each device owns all j1 for a j2 slice
  2. local NTT_{n1} along j1 — root w^{n2} (per-shard roots via
     ``NTTParams.subparams``; q ≡ 1 mod 2n covers every sub-length)
  3. twiddle multiply by w^{j2·k1} (local, Montgomery form)
  4. all-to-all transpose: each device owns all j2 for a k1 row
  5. local NTT_{n2} along j2 — root w^{n1}
  X[k1 + k2·n1] = Z[k1, k2]; one more transpose restores natural order.

``ordered=False`` leaves the result in Z-order (k1-sharded, i.e. the
device-strided decimation X[idx::n1] lives on device idx): for polymul the
pointwise product is order-agnostic and the inverse transform consumes
Z-order directly, saving 2 all-to-alls per transform — the collective-level
analogue of the paper's §5 DFT·IDFT permutation cancellation, same as the
float path.

All local butterflies are the *same jnp Montgomery arithmetic the Pallas
kernel runs* (``kernels.ntt.ntt_stages`` — plain jnp, usable outside
pallas_call), so distributed == local is exact ``==``, never allclose.
Every all-to-all goes through ``dist.collectives.all_to_all``, so traced
traffic lands in the byte ledger; ``four_step_collective_stats`` is the
closed form tests pin against that ledger.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.ntt.ref import NTTParams
from repro.dist import collectives, sharding
from repro.dist.compat import shard_map
from repro.kernels import ntt as kntt

__all__ = [
    "four_step_collective_stats", "make_sharded_ntt",
    "make_sharded_ntt_polymul", "ntt_distributed",
]


def _mont_u32(params: NTTParams, values: np.ndarray) -> np.ndarray:
    return params.to_montgomery(values).astype(np.uint32)


def _local_ntt(x: jax.Array, sub: NTTParams, *, inverse: bool) -> jax.Array:
    """Unscaled last-axis transform of (..., m) with the sub-length roots."""
    w = kntt._master_table(sub, sub.w_inv if inverse else sub.w)
    shp = x.shape
    y = kntt.ntt_stages(x.reshape(-1, shp[-1]), w, n=shp[-1], q=sub.q,
                        qinv=sub.qinv)
    return y.reshape(shp)


def _local_ntt_axis2(x: jax.Array, sub: NTTParams, *,
                     inverse: bool) -> jax.Array:
    """Same, along axis -2 (the j1/k1 axis of the (n1, n2/D) block)."""
    return jnp.swapaxes(
        _local_ntt(jnp.swapaxes(x, -1, -2), sub, inverse=inverse), -1, -2)


@functools.lru_cache(maxsize=32)
def _step3_twiddle(params: NTTParams, n1: int, inverse: bool) -> np.ndarray:
    """Full (n1, n2) Montgomery table of w^{±j2·k1}; devices dynamic-slice
    their j2 columns at trace time. Cached as NUMPY (caching jnp values
    across traces would leak tracers out of shard_map)."""
    n, n2 = params.n, params.n // n1
    pw = params.powers(params.w_inv if inverse else params.w)
    idx = np.outer(np.arange(n1), np.arange(n2)) % n
    return _mont_u32(params, pw[idx])


@functools.lru_cache(maxsize=32)
def _edge_table(params: NTTParams, kind: str) -> np.ndarray:
    """(1, n) Montgomery tables sliced by device for twist/untwist/scale:
    ``twist``  — psi^j (negacyclic input twist),
    ``untwist`` — psi^{-j} · n^{-1} (negacyclic output untwist + scale),
    ``scale``  — n^{-1} broadcast (cyclic inverse scale).
    untwist/scale values come from ``kernels.ntt.untwist_table`` — the one
    definition the local kernel and RNS limb tables also use."""
    if kind == "twist":
        vals = params.powers(params.psi)
    elif kind in ("untwist", "scale"):
        vals = kntt.untwist_table(params, negacyclic=(kind == "untwist"))
    else:
        raise ValueError(kind)
    return _mont_u32(params, vals)[None, :]


def _device_slice(table: np.ndarray, idx, width: int, axis: int) -> jax.Array:
    return jax.lax.dynamic_slice_in_dim(jnp.asarray(table), idx * width,
                                        width, axis=axis)


def ntt_distributed(x: jax.Array, params: NTTParams, *,
                    axis_name: str = "data", n_devices: int,
                    inverse: bool = False, ordered: bool = True,
                    scale: bool = True, _in_zorder: bool = False
                    ) -> jax.Array:
    """Exact NTT of (..., n) residues with the last axis sharded over
    ``axis_name``; must be called INSIDE shard_map (``x`` is the local
    (..., n/D) uint32 block). ``scale=False`` on the inverse skips the
    n^{-1} multiply so a caller can fold it into its own output pass."""
    D = n_devices
    *lead, n_loc = x.shape
    n = n_loc * D
    assert n == params.n, f"n={n} != params.n={params.n}"
    n1, n2 = D, n_loc
    p1 = params.subparams(n1)
    p2 = params.subparams(n2)
    idx = jax.lax.axis_index(axis_name)
    x = x.astype(jnp.uint32)
    la = len(lead)

    if not inverse:
        # Device idx holds row j1 = idx of M: (..., 1, n2).
        m = x.reshape(*lead, 1, n2)
        # Step 1: transpose -> all j1 for a j2 slice: (..., n1, n2/D).
        m = collectives.all_to_all(m, axis_name, split_axis=la + 1,
                                   concat_axis=la, tiled=True)
        y = _local_ntt_axis2(m, p1, inverse=False)           # NTT over j1
        tw = _device_slice(_step3_twiddle(params, n1, False), idx,
                           n2 // D, axis=1)                  # (n1, n2/D)
        y = kntt._mont_mul(y, tw, params.q, params.qinv)
        # Step 4: transpose -> all j2 for k1 row idx: (..., 1, n2).
        y = collectives.all_to_all(y, axis_name, split_axis=la,
                                   concat_axis=la + 1, tiled=True)
        z = _local_ntt(y.reshape(*lead, n2), p2, inverse=False)
        if not ordered:
            return z                         # Z-order: device idx = X[idx::n1]
        # Natural order: device d gets X[d*n_loc:(d+1)*n_loc] = Z[:, k2 slice].
        z = z.reshape(*lead, 1, n2)
        z = collectives.all_to_all(z, axis_name, split_axis=la + 1,
                                   concat_axis=la, tiled=True)  # (n1, n2/D)
        z = jnp.swapaxes(z, -1, -2)          # (..., n2/D, n1): [k2_loc, k1]
        return z.reshape(*lead, n_loc)

    # Inverse: same pipeline with inverse roots; intt = NTT_{w^-1} / n.
    if not _in_zorder:
        # Natural chunk -> Z-order row: (..., n2/D, n1) view, transpose away.
        z = x.reshape(*lead, n2 // D, n1)
        z = jnp.swapaxes(z, -1, -2)                          # (n1, n2/D)
        z = collectives.all_to_all(z, axis_name, split_axis=la,
                                   concat_axis=la + 1, tiled=True)
        z = z.reshape(*lead, n2)             # Z[k1=idx, all k2]
    else:
        z = x
    w = _local_ntt(z, p2, inverse=True)                      # over k2
    w = w.reshape(*lead, 1, n2)
    w = collectives.all_to_all(w, axis_name, split_axis=la + 1,
                               concat_axis=la, tiled=True)   # (n1, n2/D)
    tw = _device_slice(_step3_twiddle(params, n1, True), idx,
                       n2 // D, axis=1)
    w = kntt._mont_mul(w, tw, params.q, params.qinv)
    m = _local_ntt_axis2(w, p1, inverse=True)                # over k1
    m = collectives.all_to_all(m, axis_name, split_axis=la,
                               concat_axis=la + 1, tiled=True)  # (..., 1, n2)
    out = m.reshape(*lead, n_loc)            # x[j1=idx, all j2]
    if scale:
        n_inv_mont = params.n_inv * (1 << 32) % params.q
        out = kntt._mont_mul(out, jnp.uint32(n_inv_mont), params.q,
                             params.qinv)
    return out


def _seq_spec(batch_axes: Sequence[str], axis_name: str) -> P:
    return P(tuple(batch_axes) if batch_axes else None, axis_name)


def make_sharded_ntt(mesh: jax.sharding.Mesh, params: NTTParams, *,
                     axis_name: str = "data", batch_axes: Sequence[str] = (),
                     inverse: bool = False, ordered: bool = True):
    """jit-able distributed NTT over ``mesh``: (B, n) residues -> (B, n).

    The sequence axis is sharded over ``axis_name``; the returned callable
    re-asserts that placement through ``dist.sharding.constrain`` (a no-op
    outside a mesh context) before entering shard_map.
    """
    D = mesh.shape[axis_name]
    spec = _seq_spec(batch_axes, axis_name)
    fn = functools.partial(ntt_distributed, params=params,
                           axis_name=axis_name, n_devices=D,
                           inverse=inverse, ordered=ordered)
    mapped = shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                       check_vma=False)

    def wrapped(x):
        x = sharding.constrain(x, *((None,) * (x.ndim - 1)), axis_name)
        return mapped(x)
    return wrapped


def make_sharded_ntt_polymul(mesh: jax.sharding.Mesh, params: NTTParams, *,
                             negacyclic: bool = True,
                             axis_name: str = "data",
                             batch_axes: Sequence[str] = ()):
    """Distributed exact polymul mod (x^n ± 1, q): both forward transforms
    stay in Z-order, the pointwise modmul is local, the inverse consumes
    Z-order, and the psi^{-j}·n^{-1} untwist rides the output multiply —
    6 all-to-alls instead of 9 (same cancellation as the float path)."""
    D = mesh.shape[axis_name]
    spec = _seq_spec(batch_axes, axis_name)
    n_loc = params.n // D
    q, qinv = params.q, params.qinv

    def local_fn(a, b):
        idx = jax.lax.axis_index(axis_name)
        a = a.astype(jnp.uint32)
        b = b.astype(jnp.uint32)
        if negacyclic:
            tw = _device_slice(_edge_table(params, "twist"), idx, n_loc,
                               axis=1)[0]
            a = kntt._mont_mul(a, tw, q, qinv)
            b = kntt._mont_mul(b, tw, q, qinv)
        fa = ntt_distributed(a, params, axis_name=axis_name, n_devices=D,
                             ordered=False)
        fb = ntt_distributed(b, params, axis_name=axis_name, n_devices=D,
                             ordered=False)
        r2_mont = jnp.uint32(params.r2)
        prod = kntt._mont_mul(kntt._mont_mul(fa, r2_mont, q, qinv), fb,
                              q, qinv)
        c = ntt_distributed(prod, params, axis_name=axis_name, n_devices=D,
                            inverse=True, _in_zorder=True, scale=False)
        un = _device_slice(
            _edge_table(params, "untwist" if negacyclic else "scale"), idx,
            n_loc, axis=1)[0]
        return kntt._mont_mul(c, un, q, qinv)

    mapped = shard_map(local_fn, mesh=mesh, in_specs=(spec, spec),
                       out_specs=spec, check_vma=False)

    def wrapped(a, b):
        a = sharding.constrain(a, *((None,) * (a.ndim - 1)), axis_name)
        b = sharding.constrain(b, *((None,) * (b.ndim - 1)), axis_name)
        return mapped(a, b)
    return wrapped


def four_step_collective_stats(n: int, batch: int, n_devices: int, *,
                               op: str = "ntt", ordered: bool = True,
                               itemsize: int = 4) -> dict:
    """Closed-form all-to-all traffic of one traced call, in the byte
    ledger's unit (input-block bytes per device per collective). Pinned
    against the live ledger in tests/test_dist_system.py."""
    counts = {
        ("ntt", True): 3, ("ntt", False): 2,
        ("intt", True): 3, ("intt", False): 2,
        ("polymul", True): 6, ("polymul", False): 6,
    }
    count = counts[(op, ordered)]
    per_call = batch * (n // n_devices) * itemsize
    return {"count": count, "bytes": count * per_call,
            "bytes_per_call": per_call}
