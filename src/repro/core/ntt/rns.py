"""RNS (residue number system) layer over the single-word NTT triple.

Real FHE moduli are 100+ bits, but the exact NTT tier (docs/ntt.md) is
deliberately single-word: every limb modulus q < 2^31 so residues fit one
uint32 lane.  This module composes the two: a target modulus Q (a power-of-
two bit budget or an explicit product of scheme moduli) is covered by k
pairwise-coprime 30-bit NTT-friendly limb primes, polynomial products run
per limb through the *existing* NTT stack — reference, Pallas kernel
(``kernels.ntt.rns_ntt_polymul``, all limbs in one launch), or PIM cost
model (``core.pim.ntt_pim.pim_rns_polymul``) — and the Chinese Remainder
Theorem reconstructs the exact integer result, which reduces mod Q.

Correctness bound: a negacyclic coefficient of a·b with |a_i|, |b_i| < Q is
an alternating sum of at most n products, so its magnitude is < n·Q².  The
limb set is chosen with M = prod q_i > 2·n·Q², which makes the centered CRT
lift exact; reducing that integer mod Q is then the true ring product in
Z_Q[x]/(x^n ± 1).  Q itself needs no structure at all (it may be even,
composite, or a product of scheme primes) — only the limbs must be coprime,
and distinct primes always are.

Two reconstruction paths, both from the same Garner mixed-radix digits
(digit arithmetic is entirely mod q_i < 2^30, so it vectorizes in uint64):

  * ``crt_reconstruct``      — python-int / object-dtype assembly, any k.
    The oracle path: exact for 100+ bit values, and what every test pins.
  * ``crt_reconstruct_u64``  — vectorized uint64 assembly, valid when
    M < 2^64 (k <= 2 thirty-bit limbs): the fast path for double-word Q.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import numpy as np

from repro.core.ntt.ref import (NTTParams, cyclic_polymul, is_prime,
                                negacyclic_polymul)

__all__ = [
    "RNSParams", "crt_reconstruct", "crt_reconstruct_u64", "crt_to_modulus",
    "garner_digits", "ntt_limb_primes", "random_poly", "rns_polymul",
    "rns_polymul_reference", "schoolbook_polymul_mod", "to_rns",
]


def ntt_limb_primes(n: int, bits: int = 30) -> Iterator[int]:
    """Descending primes q < 2^bits with q ≡ 1 (mod 2n): every yield is a
    valid single-word NTT modulus for length-n negacyclic transforms."""
    if n <= 0 or n & (n - 1):
        raise ValueError(f"n={n} must be a power of two")
    step = 2 * n
    q = ((1 << bits) - 2) // step * step + 1
    while q > step:
        if is_prime(q):
            yield q
        q -= step


@dataclasses.dataclass(frozen=True)
class RNSParams:
    """k coprime limb moduli covering a target modulus Q; hashable so the
    kernel layer can treat it as a static argument."""
    n: int
    modulus: int                       # Q: the ring modulus results reduce to
    limbs: tuple[NTTParams, ...]       # per-limb single-word NTT parameters

    @classmethod
    def make(cls, n: int, *, modulus: int | None = None,
             modulus_bits: int | None = None, bits: int = 30) -> "RNSParams":
        """Cover ``modulus`` (or a ``modulus_bits``-bit product of scheme
        primes) with enough limb primes that M > 2·n·Q² — the exact-centered-
        lift bound for negacyclic products of inputs in [0, Q)."""
        if (modulus is None) == (modulus_bits is None):
            raise ValueError("pass exactly one of modulus / modulus_bits")
        if modulus is None:
            if modulus_bits < 2:
                raise ValueError(f"modulus_bits={modulus_bits} too small")
            # Scheme-style Q: a product of NTT-friendly primes (what an RLWE
            # modulus chain looks like), >= the requested bit budget.
            q_prod = 1
            for p in ntt_limb_primes(n, bits):
                q_prod *= p
                if q_prod.bit_length() >= modulus_bits:
                    break
            modulus = q_prod
        if modulus < 2:
            raise ValueError(f"modulus={modulus} must be >= 2")
        bound = 2 * n * modulus * modulus
        limbs: list[int] = []
        m_prod = 1
        for p in ntt_limb_primes(n, bits):
            limbs.append(p)
            m_prod *= p
            if m_prod > bound:
                break
        if m_prod <= bound:
            raise ValueError(
                f"not enough {bits}-bit NTT primes for n={n}, "
                f"Q~2^{modulus.bit_length()}")
        return cls(n=n, modulus=modulus,
                   limbs=tuple(NTTParams.make(n, q=p) for p in limbs))

    @property
    def k(self) -> int:
        return len(self.limbs)

    @functools.cached_property
    def qs(self) -> tuple[int, ...]:
        return tuple(p.q for p in self.limbs)

    @functools.cached_property
    def limb_product(self) -> int:
        m = 1
        for q in self.qs:
            m *= q
        return m

    @functools.cached_property
    def garner_inv(self) -> tuple[int, ...]:
        """garner_inv[i] = (q_0 · ... · q_{i-1})^{-1} mod q_i (entry 0 unused)."""
        out = [0]
        prefix = 1
        for i in range(1, self.k):
            prefix = prefix * self.qs[i - 1] % self.limb_product
            out.append(pow(prefix % self.qs[i], -1, self.qs[i]))
        return tuple(out)


# ---------------------------------------------------------------------------
# Residue split / CRT reconstruction
# ---------------------------------------------------------------------------

def _as_int_object(x) -> np.ndarray:
    """Coerce to an object array of python ints; floats raise loudly (same
    contract as ``ref.as_residues`` — truncation would be a silent lie)."""
    a = np.asarray(x)
    if a.dtype.kind == "O":
        return a
    if a.dtype.kind not in "iu":
        raise TypeError(f"RNS needs integer input, got {a.dtype}")
    return a.astype(object)


def to_rns(x, rns: RNSParams) -> np.ndarray:
    """Split coefficients (..., n) into per-limb residues (k, ..., n) uint64.

    Negative coefficients wrap python-style per limb, so the CRT value of
    the stack is x mod M — consistent with the centered lift downstream.
    """
    a = _as_int_object(x)
    out = np.empty((rns.k,) + a.shape, np.uint64)
    for i, q in enumerate(rns.qs):
        out[i] = (a % q).astype(np.uint64)
    return out


def garner_digits(residues, rns: RNSParams) -> np.ndarray:
    """Mixed-radix (Garner) digits d with x = Σ d_i · q_0···q_{i-1}, d_i < q_i.

    Fully vectorized uint64: every intermediate is mod q_i < 2^30, so the
    Horner products stay below 2^60 — no python-int arithmetic anywhere.
    """
    res = np.asarray(residues, np.uint64)
    if res.shape[0] != rns.k:
        raise ValueError(f"expected {rns.k} limb planes, got {res.shape[0]}")
    d = np.empty_like(res)
    d[0] = res[0] % np.uint64(rns.qs[0])
    for i in range(1, rns.k):
        qi = np.uint64(rns.qs[i])
        # acc = (d_0 + d_1 q_0 + ... + d_{i-1} q_0..q_{i-2}) mod q_i, Horner
        # from the top digit down.
        acc = d[i - 1] % qi
        for j in range(i - 2, -1, -1):
            acc = (acc * np.uint64(rns.qs[j] % rns.qs[i]) + d[j]) % qi
        inv = np.uint64(rns.garner_inv[i])
        d[i] = (res[i] % qi + qi - acc) % qi * inv % qi
    return d


def crt_reconstruct(residues, rns: RNSParams) -> np.ndarray:
    """Exact CRT value in [0, M) as an object array of python ints — the
    oracle path, valid for any limb count."""
    digits = garner_digits(residues, rns)
    val = np.zeros(digits.shape[1:], object)
    weight = 1
    for i in range(rns.k):
        val = val + digits[i].astype(object) * weight
        weight *= rns.qs[i]
    return val


def crt_reconstruct_u64(residues, rns: RNSParams) -> np.ndarray:
    """Vectorized uint64 CRT value in [0, M); requires M < 2^64 (k <= 2
    thirty-bit limbs) — every partial sum is then < M and exact."""
    if rns.limb_product >> 64:
        raise ValueError(
            f"limb product is {rns.limb_product.bit_length()} bits; the "
            f"uint64 path needs M < 2^64 (use crt_reconstruct)")
    digits = garner_digits(residues, rns)
    val = np.zeros(digits.shape[1:], np.uint64)
    weight = np.uint64(1)
    for i in range(rns.k):
        val = val + digits[i] * weight
        weight = weight * np.uint64(rns.qs[i])
    return val


def crt_to_modulus(residues, rns: RNSParams) -> np.ndarray:
    """Centered CRT lift reduced into [0, Q): the exact ring coefficient.

    The lift maps [0, M) onto (-M/2, M/2]; with M > 2·n·Q² that interval
    contains the true (possibly negative) convolution coefficient, so the
    final ``% Q`` is exact integer arithmetic, not a modeling choice.
    """
    raw = crt_reconstruct(residues, rns)
    half = rns.limb_product // 2
    centered = np.where(raw > half, raw - rns.limb_product, raw)
    return centered % rns.modulus


# ---------------------------------------------------------------------------
# Polynomial products mod Q
# ---------------------------------------------------------------------------

def schoolbook_polymul_mod(a, b, modulus: int, *,
                           negacyclic: bool = True) -> np.ndarray:
    """O(n²) product mod (x^n ± 1, Q) in pure python big-int arithmetic —
    the independent oracle for the whole RNS stack (no CRT, no transforms)."""
    av = [int(v) % modulus for v in np.asarray(a, object).ravel()]
    bv = [int(v) % modulus for v in np.asarray(b, object).ravel()]
    n = len(av)
    if len(bv) != n:
        raise ValueError(f"length mismatch: {n} vs {len(bv)}")
    out = [0] * n
    for i in range(n):
        if not av[i]:
            continue
        for j in range(n):
            k = i + j
            t = av[i] * bv[j]
            if k < n:
                out[k] += t
            elif negacyclic:
                out[k - n] -= t
            else:
                out[k - n] += t
    return np.array([v % modulus for v in out], object)


def rns_polymul_reference(a, b, rns: RNSParams, *,
                          negacyclic: bool = True) -> np.ndarray:
    """Limb-parallel product through the numpy NTT reference + CRT: the
    mid-level differential point between the big-int schoolbook oracle and
    the fused Pallas kernel."""
    ar = to_rns(a, rns)
    br = to_rns(b, rns)
    fn = negacyclic_polymul if negacyclic else cyclic_polymul
    prods = np.stack([fn(ar[i], br[i], p)
                      for i, p in enumerate(rns.limbs)])
    return crt_to_modulus(prods, rns)


def rns_polymul(a, b, rns: RNSParams, *, negacyclic: bool = True,
                interpret: bool = True, block_b: int | None = None
                ) -> np.ndarray:
    """Exact product mod (x^n ± 1, Q) through the fused Pallas kernel: one
    launch for all k limbs (``kernels.ntt.rns_ntt_polymul``), then CRT.

    Accepts (n,) or (B, n) coefficient arrays (ints or object-dtype big
    ints); returns the same shape as object-dtype residues in [0, Q).
    """
    from repro.kernels.ntt import rns_ntt_polymul  # deferred: core -> kernels
    a_obj = _as_int_object(a)
    b_obj = _as_int_object(b)
    if a_obj.shape != b_obj.shape or a_obj.shape[-1] != rns.n:
        raise ValueError(f"bad shapes {a_obj.shape} / {b_obj.shape} "
                         f"for n={rns.n}")
    squeeze = a_obj.ndim == 1
    if squeeze:
        a_obj, b_obj = a_obj[None], b_obj[None]
    ar = to_rns(a_obj, rns).astype(np.uint32)       # residues < 2^30
    br = to_rns(b_obj, rns).astype(np.uint32)
    prods = np.asarray(rns_ntt_polymul(ar, br, rns, negacyclic=negacyclic,
                                       interpret=interpret, block_b=block_b))
    out = crt_to_modulus(prods.astype(np.uint64), rns)
    return out[0] if squeeze else out


def random_poly(rng: np.random.Generator, n: int, modulus: int) -> np.ndarray:
    """Uniform-ish coefficients in [0, Q) as an object array of python ints
    (assembled from 30-bit draws so 100+ bit Q is actually exercised)."""
    chunks = (modulus.bit_length() + 29) // 30
    vals = rng.integers(0, 1 << 30, size=(chunks, n), dtype=np.int64)
    out = np.zeros(n, object)
    for c in range(chunks):
        out = (out << 30) | vals[c].astype(object)
    return out % modulus
