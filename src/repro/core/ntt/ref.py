"""Exact number-theoretic transform (NTT) reference over a prime modulus.

The paper motivates O(log n) polynomial multiplication "for applications
such as cryptography" (§5), but crypto polymul must be exact: RLWE/FHE
needs negacyclic products mod q, which the float FFT path cannot deliver.
This module is the NTT counterpart of ``kernels/ref.py`` — the bit-exact
oracle that the Pallas kernel (``kernels/ntt.py``) and the PIM cost model
(``core/pim/ntt_pim.py``) are tested against.

Math conventions (matching py-fhe's ``util/ntt.py`` and NTT-PIM
[arXiv:2310.09715]):

  * q is an NTT-friendly prime, q ≡ 1 (mod 2n), q < 2^31 so a residue fits
    one uint32 word and 32x32-bit products fit uint64 exactly;
  * w = g^((q-1)/n) is a primitive n-th root of unity: the CYCLIC transform
    X[k] = sum_j x[j] w^{jk} diagonalizes multiplication mod x^n - 1;
  * psi = g^((q-1)/2n) with psi^2 = w twists the input (x[j] -> psi^j x[j])
    so the same cyclic transform computes the NEGACYCLIC product
    mod x^n + 1 — the RLWE ring — after the psi^{-j}/n untwist.

Everything here is vectorized numpy uint64: operands stay < q < 2^31, so
w*v products stay < 2^62 and every intermediate is exact.

Montgomery helpers (``R = 2^32`` fixed) live here too: the Pallas kernel
carries its twiddles in Montgomery form so a single REDC per butterfly
multiply suffices; the constants are plain Python ints computed once per
``NTTParams``.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

MONTGOMERY_R_BITS = 32
_R = 1 << MONTGOMERY_R_BITS

__all__ = [
    "MONTGOMERY_R_BITS", "NTTParams", "as_residues", "bit_reverse_indices",
    "choose_modulus", "cyclic_polymul", "intt", "is_prime",
    "negacyclic_polymul", "ntt", "primitive_root",
    "schoolbook_polymul", "root_of_unity",
]


# ---------------------------------------------------------------------------
# Number theory: primality, generators, roots of unity
# ---------------------------------------------------------------------------

def is_prime(n: int) -> bool:
    """Deterministic Miller-Rabin for n < 3.3 * 10^24 (fixed base set)."""
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def choose_modulus(n: int, bits: int = 30) -> int:
    """Largest prime q < 2^bits with q ≡ 1 (mod 2n) (bits <= 31 so the
    kernel's single-word Montgomery arithmetic applies)."""
    if n <= 0 or n & (n - 1):
        raise ValueError(f"n={n} must be a power of two")
    if not 2 * n < (1 << bits) <= (1 << 31):
        raise ValueError(f"bits={bits} out of range for n={n}")
    step = 2 * n
    q = ((1 << bits) - 2) // step * step + 1
    while q > step:
        if is_prime(q):
            return q
        q -= step
    raise ValueError(f"no NTT prime below 2^{bits} for n={n}")


def _factorize(n: int) -> list[int]:
    """Distinct prime factors by trial division (n < 2^31 here)."""
    fac, d = [], 2
    while d * d <= n:
        if n % d == 0:
            fac.append(d)
            while n % d == 0:
                n //= d
        d += 1 if d == 2 else 2
    if n > 1:
        fac.append(n)
    return fac


@functools.lru_cache(maxsize=None)
def primitive_root(q: int) -> int:
    """Smallest generator of (Z/q)^* for prime q."""
    if not is_prime(q):
        raise ValueError(f"q={q} is not prime")
    fac = _factorize(q - 1)
    g = 2
    while True:
        if all(pow(g, (q - 1) // p, q) != 1 for p in fac):
            return g
        g += 1


def root_of_unity(order: int, q: int) -> int:
    """A primitive ``order``-th root of unity mod q (order | q-1)."""
    if (q - 1) % order:
        raise ValueError(f"order {order} does not divide q-1 = {q - 1}")
    return pow(primitive_root(q), (q - 1) // order, q)


# ---------------------------------------------------------------------------
# Transform parameters
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NTTParams:
    """All per-(n, q) constants; hashable so jit can treat it as static.

    Montgomery constants use R = 2^32: ``qinv`` is -q^{-1} mod R (the REDC
    multiplier) and ``r2`` is R^2 mod q (domain-entry factor).
    """
    n: int
    q: int
    w: int          # primitive n-th root of unity
    w_inv: int
    psi: int        # primitive 2n-th root, psi^2 = w (negacyclic twist)
    psi_inv: int
    n_inv: int      # n^{-1} mod q
    qinv: int       # -q^{-1} mod 2^32
    r2: int         # 2^64 mod q

    @classmethod
    def make(cls, n: int, q: int | None = None, *,
             bits: int = 30) -> "NTTParams":
        if n <= 0 or n & (n - 1):
            raise ValueError(f"n={n} must be a power of two")
        if q is None:
            q = choose_modulus(n, bits=bits)
        if not is_prime(q) or (q - 1) % (2 * n) or q % 2 == 0 or q >= 1 << 31:
            raise ValueError(
                f"q={q} must be an odd prime ≡ 1 (mod 2n={2 * n}), < 2^31")
        psi = root_of_unity(2 * n, q)
        w = psi * psi % q
        return cls(n=n, q=q, w=w, w_inv=pow(w, -1, q),
                   psi=psi, psi_inv=pow(psi, -1, q), n_inv=pow(n, -1, q),
                   qinv=(-pow(q, -1, _R)) % _R, r2=_R * _R % q)

    def subparams(self, m: int) -> "NTTParams":
        """Parameters for the length-m sub-transform (m | n) over the SAME q.

        Roots are the originals raised to the (n/m)-th power — these are the
        per-shard twiddle roots of the four-step decomposition
        (``core.ntt.distributed``): psi has order 2n, so psi^(n/m) is a
        primitive 2m-th root and (psi^(n/m))^2 = w^(n/m) generates the
        length-m cyclic transform. q ≡ 1 (mod 2n) implies q ≡ 1 (mod 2m),
        so the result is a valid NTTParams without re-searching moduli.
        """
        if m <= 1 or self.n % m:
            raise ValueError(f"m={m} must divide n={self.n} and exceed 1")
        f = self.n // m
        psi = pow(self.psi, f, self.q)
        w = psi * psi % self.q
        return NTTParams(n=m, q=self.q, w=w, w_inv=pow(w, -1, self.q),
                         psi=psi, psi_inv=pow(psi, -1, self.q),
                         n_inv=pow(m, -1, self.q), qinv=self.qinv,
                         r2=self.r2)

    # -- twiddle tables (numpy, normal domain) ------------------------------
    def powers(self, base: int) -> np.ndarray:
        """[base^0, base^1, ..., base^(n-1)] mod q as uint64."""
        out = np.empty(self.n, np.uint64)
        acc = 1
        for i in range(self.n):
            out[i] = acc
            acc = acc * base % self.q
        return out

    def to_montgomery(self, x: np.ndarray) -> np.ndarray:
        """x * R mod q elementwise (x < q < 2^31, so x*R < 2^63: exact)."""
        return (np.asarray(x, np.uint64) << np.uint64(MONTGOMERY_R_BITS)) \
            % np.uint64(self.q)


def bit_reverse_indices(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


# ---------------------------------------------------------------------------
# Transforms (vectorized over leading batch dims)
# ---------------------------------------------------------------------------

def as_residues(x, q: int) -> np.ndarray:
    """Coerce integer coefficients to residues in [0, q) as uint64.

    Floats are rejected loudly — silently truncating un-quantized data
    would defeat the whole point of the exact path. Negative coefficients
    reduce Python-style ((-1) % q == q - 1), the RLWE convention.
    """
    a = np.asarray(x)
    if a.dtype.kind not in "iu":
        raise TypeError(f"NTT needs integer input, got {a.dtype}")
    return (a.astype(np.int64) % q).astype(np.uint64)


def _ntt_core(x: np.ndarray, params: NTTParams, root: int) -> np.ndarray:
    """Iterative DIT butterflies after bit reversal, batched over x[..., n].

    Same loop structure as ``fft_pim._fft_groups`` / py-fhe's ``ntt`` —
    log2 n stages of span-m butterflies with stride-(n/m) twiddles.
    """
    n, q = params.n, np.uint64(params.q)
    y = x[..., bit_reverse_indices(n)].copy()
    pw = params.powers(root)
    for s in range(n.bit_length() - 1):
        m = 2 << s
        half = m >> 1
        blocks = y.reshape(*y.shape[:-1], n // m, m)
        u = blocks[..., :half]
        v = blocks[..., half:]
        tw = pw[(n // m) * np.arange(half)]
        t = (tw * v) % q
        blocks[..., :half], blocks[..., half:] = (u + t) % q, (u + q - t) % q
    return y


def ntt(x, params: NTTParams) -> np.ndarray:
    """Forward cyclic NTT of x[..., n]: X[k] = sum_j x[j] w^{jk} mod q."""
    return _ntt_core(as_residues(x, params.q), params, params.w)


def intt(x, params: NTTParams) -> np.ndarray:
    """Inverse cyclic NTT: intt(ntt(x)) == x exactly."""
    y = _ntt_core(as_residues(x, params.q), params, params.w_inv)
    return (y * np.uint64(params.n_inv)) % np.uint64(params.q)


# ---------------------------------------------------------------------------
# Polynomial products
# ---------------------------------------------------------------------------

def cyclic_polymul(a, b, params: NTTParams) -> np.ndarray:
    """a * b mod (x^n - 1, q): the convolution theorem, exactly."""
    q = np.uint64(params.q)
    return intt((ntt(a, params) * ntt(b, params)) % q, params)


def negacyclic_polymul(a, b, params: NTTParams) -> np.ndarray:
    """a * b mod (x^n + 1, q) — the RLWE ring — via the psi twist."""
    q = np.uint64(params.q)
    psi_pow = params.powers(params.psi)
    at = (as_residues(a, params.q) * psi_pow) % q
    bt = (as_residues(b, params.q) * psi_pow) % q
    ct = intt((ntt(at, params) * ntt(bt, params)) % q, params)
    return (ct * params.powers(params.psi_inv)) % q


def schoolbook_polymul(a, b, q: int, *, negacyclic: bool = True) -> np.ndarray:
    """O(n^2) coefficient product mod (x^n ± 1, q): the independent oracle
    the transform stack is tested against (no roots of unity involved)."""
    a = as_residues(a, q)
    b = as_residues(b, q)
    n = a.shape[-1]
    if a.ndim == 1:
        a = a[None]
        b = b[None]
        squeeze = True
    else:
        squeeze = False
    out = np.zeros_like(a)
    qq = np.uint64(q)
    for i in range(n):
        for j in range(n):
            k = i + j
            term = (a[..., i] * b[..., j]) % qq
            if k < n:
                out[..., k] = (out[..., k] + term) % qq
            elif negacyclic:
                out[..., k - n] = (out[..., k - n] + qq - term) % qq
            else:
                out[..., k - n] = (out[..., k - n] + term) % qq
    return out[0] if squeeze else out
