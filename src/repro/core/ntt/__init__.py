"""Exact modular transforms: the NTT counterpart of ``repro.core.fft``.

Public surface:
  NTTParams / choose_modulus / root_of_unity     (parameter selection)
  ntt / intt / cyclic_polymul / negacyclic_polymul  (exact reference)
  schoolbook_polymul                             (independent O(n^2) oracle)

The production kernel lives in ``repro.kernels.ntt``; the PIM cost model in
``repro.core.pim.ntt_pim``; semantics and modulus-selection rules are
documented in docs/ntt.md.
"""
from repro.core.ntt.ref import (NTTParams, as_residues, bit_reverse_indices,
                                choose_modulus, cyclic_polymul, intt,
                                is_prime, negacyclic_polymul, ntt,
                                primitive_root, root_of_unity,
                                schoolbook_polymul)

__all__ = [
    "NTTParams", "as_residues", "bit_reverse_indices", "choose_modulus",
    "cyclic_polymul", "intt", "is_prime", "negacyclic_polymul", "ntt",
    "primitive_root", "root_of_unity", "schoolbook_polymul",
]
