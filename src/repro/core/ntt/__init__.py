"""Exact modular transforms: the NTT counterpart of ``repro.core.fft``.

Public surface:
  NTTParams / choose_modulus / root_of_unity     (parameter selection;
      NTTParams.subparams gives the four-step per-shard roots)
  ntt / intt / cyclic_polymul / negacyclic_polymul  (exact reference)
  schoolbook_polymul                             (independent O(n^2) oracle)
  RNSParams / rns_polymul / crt_to_modulus       (multi-limb RNS/CRT layer
      for 100+ bit moduli; big-int oracle in rns.schoolbook_polymul_mod)
  make_sharded_ntt / make_sharded_ntt_polymul    (distributed four-step NTT)

The production kernels live in ``repro.kernels.ntt`` (including the
limb-batched ``rns_ntt_polymul``); the PIM cost model in
``repro.core.pim.ntt_pim``; semantics, modulus-selection and limb-selection
rules are documented in docs/ntt.md.
"""
from repro.core.ntt.ref import (NTTParams, as_residues, bit_reverse_indices,
                                choose_modulus, cyclic_polymul, intt,
                                is_prime, negacyclic_polymul, ntt,
                                primitive_root, root_of_unity,
                                schoolbook_polymul)
from repro.core.ntt.rns import (RNSParams, crt_reconstruct,
                                crt_reconstruct_u64, crt_to_modulus,
                                garner_digits, ntt_limb_primes, rns_polymul,
                                rns_polymul_reference, schoolbook_polymul_mod,
                                to_rns)
from repro.core.ntt.distributed import (four_step_collective_stats,
                                        make_sharded_ntt,
                                        make_sharded_ntt_polymul,
                                        ntt_distributed)

__all__ = [
    "NTTParams", "as_residues", "bit_reverse_indices", "choose_modulus",
    "cyclic_polymul", "intt", "is_prime", "negacyclic_polymul", "ntt",
    "primitive_root", "root_of_unity", "schoolbook_polymul",
    "RNSParams", "crt_reconstruct", "crt_reconstruct_u64", "crt_to_modulus",
    "garner_digits", "ntt_limb_primes", "rns_polymul",
    "rns_polymul_reference", "schoolbook_polymul_mod", "to_rns",
    "four_step_collective_stats", "make_sharded_ntt",
    "make_sharded_ntt_polymul", "ntt_distributed",
]
