"""Logical (value-level) crossbar simulator with cycle/energy counters.

The paper verifies FourierPIM on a cycle-accurate simulator that "logically
models a memristive crossbar array and performs the sequence of operations
that correspond to the proposed algorithms" (§6). This module is that
simulator, reproduced at the same abstraction level:

  * values are tracked numerically (a crossbar is an (rows, word-slots)
    complex array) — correctness of the FFT/polymul mapping is checked
    against ``numpy.fft`` on random inputs, exactly like the paper checks
    against baseline implementations;
  * every vectored operation charges latency cycles and gate executions per
    the AritPIM cost model (aritpim.py), which drive the throughput/energy
    numbers in the benchmarks;
  * the bit-level gate sequences themselves are NOT re-simulated per value —
    they are memristor-circuit facts imported as costs (their own validation
    is AritPIM's [12]); a narrow bit-exact NOR-adder check lives in
    tests/test_pim.py to pin the cost model's structural assumptions.

Cost conventions (see DESIGN.md §PIM):
  column op  (bitline voltages): 1 gate/row/cycle, all rows in parallel.
  row op     (wordline voltages): whole row in 1 gate-step/cycle, rows serial.
  partitions: p independent column-units may fire gates concurrently.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.pim import aritpim
from repro.core.pim.device_model import PIMConfig


@dataclasses.dataclass
class Counters:
    cycles: int = 0
    gates: int = 0

    def energy_j(self, cfg: PIMConfig) -> float:
        return self.gates * cfg.gate_energy_j

    def latency_s(self, cfg: PIMConfig) -> float:
        return self.cycles / cfg.clock_hz


class CrossbarSim:
    """One crossbar: (rows x word-slot) values + cost counters.

    Works for both number domains: complex floats (FloatSpec, the paper's
    FFT) and modular residues (IntSpec, the exact NTT) — the spec only
    enters through ``aritpim.op_cycles`` and the storage word width.

    Every charge appends a ``(tag, cycles)`` record to ``self.log`` so tests
    can assert *ordering* contracts (e.g. the input bit-reversal permutation
    is charged before the first butterfly in every layout), not just totals.
    """

    def __init__(self, cfg: PIMConfig, spec, *, faults=None,
                 array_id: int = 0):
        self.cfg = cfg
        self.spec = spec
        self.word_bits = aritpim.storage_word_bits(spec)
        self.slots = cfg.crossbar_cols // self.word_bits
        self.values = np.zeros((cfg.crossbar_rows, self.slots), np.complex128)
        self.ctr = Counters()
        self.log: list[tuple[str, int]] = []
        # Fault hook (core/pim/faults.py): resolved ONCE at construction so
        # the common fault-free path costs a single ``is None`` check per
        # butterfly — zero overhead when disabled.
        self.array_id = array_id
        self.faults = (faults.for_array(array_id)
                       if faults is not None else None)
        self._fault_rng = (faults.rng_for(array_id, salt=1)
                           if self.faults is not None else None)

    # -- cost charging ------------------------------------------------------
    def charge_column_op(self, op: str, active_rows: int, serial: int = 1):
        c = aritpim.op_cycles(op, self.spec) * serial
        self.ctr.cycles += c
        self.ctr.gates += c * active_rows
        self.log.append((op, c))

    def charge_row_ops(self, n_rows: int, cycles_per_row: int = 2,
                       tag: str = "row"):
        """Serial row-granularity moves (copy=2 NOT cycles, swap=6)."""
        self.ctr.cycles += n_rows * cycles_per_row
        self.ctr.gates += n_rows * cycles_per_row * self.word_bits
        self.log.append((tag, n_rows * cycles_per_row))

    def charge_twiddle_writes(self, n_values: int):
        """Constants written by the periphery (paper footnote 3): one row
        write per value, parallel across crossbars, negligible energy."""
        self.ctr.cycles += n_values
        self.ctr.gates += n_values * self.word_bits
        self.log.append(("twiddle", n_values))

    # -- value-level ops (verified numerically) -----------------------------
    def load(self, x: np.ndarray, slot0: int = 0):
        """Store a sequence into slots (snake over rows within each slot
        pair); no cost — DMA into memory is outside the kernel, as in the
        paper's batched setup."""
        r = self.cfg.crossbar_rows
        x = np.asarray(x, np.complex128)
        cols = math.ceil(len(x) / r)
        assert slot0 + cols <= self.slots, "sequence does not fit"
        for c in range(cols):
            chunk = x[c * r:(c + 1) * r]
            self.values[:len(chunk), slot0 + c] = chunk
        return cols

    def butterfly_rows(self, u: np.ndarray, v: np.ndarray, w: np.ndarray,
                       active_rows: int, serial_units: int = 1):
        """Vectored in-place butterfly on value vectors (u,v,w aligned rows).

        Returns (u + w v, u - w v); charges one butterfly per serial unit
        group (paper §4.2: O(1) vector ops regardless of row count).
        """
        t = w * v
        self.charge_column_op("butterfly", active_rows, serial=serial_units)
        hi, lo = u + t, u - t
        if self.faults is not None:
            hi, lo = self._inject_float(hi, lo)
        return hi, lo

    def butterfly_rows_mod(self, u: np.ndarray, v: np.ndarray, w: np.ndarray,
                           q: int, active_rows: int, serial_units: int = 1):
        """Modular butterfly (u + w v, u - w v) mod q on uint64 residue
        vectors; one ``ntt_butterfly``-costed vectored op per serial unit.
        Exact: all operands < q < 2^31, products < 2^62 fit uint64."""
        qq = np.uint64(q)
        t = (w * v) % qq
        self.charge_column_op("butterfly", active_rows, serial=serial_units)
        hi, lo = (u + t) % qq, (u + qq - t) % qq
        if self.faults is not None:
            hi, lo = self._inject_mod(hi, lo, qq)
        return hi, lo

    # -- fault injection (core/pim/faults.py; ledger entries cost 0) --------
    def _fault_log(self, kind: str) -> None:
        self.log.append((f"fault:{kind}:a{self.array_id}", 0))

    def _transient_fires(self) -> bool:
        """Per-op transient coin: p = 1 - (1-rate)^gates for the gates the
        butterfly just charged (the last log entry's cycles x its rows are
        folded into one op-level draw — bit-level gates are costs here,
        not simulated state, so the flip lands on one stored value)."""
        f = self.faults
        if f.bitflip_per_gate <= 0.0:
            return False
        gates = self.log[-1][1] * max(1, self.cfg.crossbar_rows // 2)
        p = 1.0 - (1.0 - f.bitflip_per_gate) ** gates
        return bool(self._fault_rng.random() < p)

    def _inject_float(self, hi: np.ndarray, lo: np.ndarray):
        f = self.faults
        hv, lv = hi.reshape(-1), lo.reshape(-1)
        if f.dead:
            hv[:] = 0.0
            lv[:] = 0.0
            self._fault_log("dead")
            return hi, lo
        for pos, val in zip(f.stuck_pos, f.stuck_val):
            tgt = hv if (pos >> 1) % 2 == 0 else lv
            forced = 1.0 if val else 0.0
            i = pos % tgt.size
            if tgt[i] != forced:
                tgt[i] = forced
                self._fault_log("stuck")
        if self._transient_fires():
            tgt = hv if self._fault_rng.random() < 0.5 else lv
            i = int(self._fault_rng.integers(0, tgt.size))
            tgt[i] *= 2.0           # exponent-bit flip: magnitude doubles
            self._fault_log("flip")
        return hi, lo

    def _inject_mod(self, hi: np.ndarray, lo: np.ndarray, qq: np.uint64):
        f = self.faults
        hv, lv = hi.reshape(-1), lo.reshape(-1)
        if f.dead:
            hv[:] = np.uint64(0)
            lv[:] = np.uint64(0)
            self._fault_log("dead")
            return hi, lo
        for pos, val, bit in zip(f.stuck_pos, f.stuck_val, f.stuck_bit):
            tgt = hv if (pos >> 1) % 2 == 0 else lv
            i = pos % tgt.size
            mask = np.uint64(1 << bit)
            forced = ((tgt[i] | mask) if val
                      else (tgt[i] & ~mask)) % qq
            if forced != tgt[i]:
                tgt[i] = forced
                self._fault_log("stuck")
        if self._transient_fires():
            tgt = hv if self._fault_rng.random() < 0.5 else lv
            i = int(self._fault_rng.integers(0, tgt.size))
            bit = int(self._fault_rng.integers(0, 20))
            tgt[i] = (tgt[i] ^ np.uint64(1 << bit)) % qq
            self._fault_log("flip")
        return hi, lo
