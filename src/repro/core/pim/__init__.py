"""Faithful reproduction of FourierPIM on its own terms: the logical
crossbar simulator, AritPIM cost model, r/2r/2r-beta FFT mappings,
convolution-theorem polymul, and the cuFFT baseline models (paper §6)."""
from repro.core.pim.aritpim import (FP16, FP32, INT16, INT32, FloatSpec,
                                    IntSpec, butterfly_cycles,
                                    complex_word_bits, mod_add_cycles,
                                    mod_mul_cycles, ntt_butterfly_cycles,
                                    op_cycles)
from repro.core.pim.crossbar import Counters, CrossbarSim
from repro.core.pim.device_model import (A100, FOURIERPIM_8, FOURIERPIM_40,
                                         FULL_COMPLEX_BITS,
                                         HALF_COMPLEX_BITS, GPUConfig,
                                         PIMConfig, RTX3070, with_partitions)
from repro.core.pim.fft_pim import (PIMFFTResult, PIMRFFTResult, fft_2r,
                                    fft_2rbeta, fft_energy_j_per_op,
                                    fft_latency_cycles,
                                    fft_throughput_per_s, pim_fft, pim_rfft,
                                    r_fft, realpack_unpack_cycles,
                                    rfft_latency_cycles,
                                    rfft_throughput_per_s)
from repro.core.pim.polymul_pim import (PIMPolymulResult, pim_polymul,
                                        pim_polymul_real,
                                        polymul_energy_j_per_op,
                                        polymul_latency_cycles,
                                        polymul_real_batch_latency_cycles,
                                        polymul_real_pair_latency_cycles,
                                        polymul_throughput_per_s)
from repro.core.pim.ntt_pim import (PIMDistNTTResult, PIMNTTResult,
                                    PIMRNSResult, batched_ntt_stats,
                                    ntt_2r, ntt_2rbeta,
                                    ntt_distributed_a2a_bytes,
                                    ntt_distributed_latency_cycles,
                                    ntt_energy_j_per_op,
                                    ntt_latency_cycles,
                                    ntt_polymul_latency_cycles,
                                    ntt_throughput_per_s, pim_ntt,
                                    pim_ntt_distributed, pim_ntt_polymul,
                                    pim_rns_polymul, r_ntt,
                                    rns_polymul_latency_cycles,
                                    rns_polymul_wave_stats)
from repro.core.pim import gpu_model

__all__ = [
    "FP16", "FP32", "INT16", "INT32", "FloatSpec", "IntSpec",
    "butterfly_cycles", "complex_word_bits", "mod_add_cycles",
    "mod_mul_cycles", "ntt_butterfly_cycles", "op_cycles", "Counters",
    "CrossbarSim", "A100", "FOURIERPIM_8", "FOURIERPIM_40",
    "FULL_COMPLEX_BITS", "HALF_COMPLEX_BITS", "GPUConfig", "PIMConfig",
    "RTX3070", "with_partitions", "PIMFFTResult", "PIMRFFTResult", "fft_2r",
    "fft_2rbeta", "fft_energy_j_per_op", "fft_latency_cycles",
    "fft_throughput_per_s", "pim_fft", "pim_rfft", "r_fft",
    "realpack_unpack_cycles", "rfft_latency_cycles", "rfft_throughput_per_s",
    "PIMPolymulResult", "pim_polymul",
    "pim_polymul_real", "polymul_energy_j_per_op", "polymul_latency_cycles",
    "polymul_real_batch_latency_cycles", "polymul_real_pair_latency_cycles",
    "polymul_throughput_per_s", "PIMDistNTTResult", "PIMNTTResult",
    "PIMRNSResult", "batched_ntt_stats", "ntt_2r", "ntt_2rbeta",
    "ntt_distributed_a2a_bytes", "ntt_distributed_latency_cycles",
    "ntt_energy_j_per_op", "ntt_latency_cycles",
    "ntt_polymul_latency_cycles", "ntt_throughput_per_s", "pim_ntt",
    "pim_ntt_distributed", "pim_ntt_polymul", "pim_rns_polymul", "r_ntt",
    "rns_polymul_latency_cycles", "rns_polymul_wave_stats", "gpu_model",
]
