"""cuFFT baseline model (paper §6, Table 1; roofline of Fig. 1).

The paper's own roofline (Fig. 1) shows cuFFT pinned against the memory
bandwidth roof on the RTX 3070 — FFT arithmetic intensity is ~log2(n)/8
FLOP/byte, far below the machine balance point. We therefore model cuFFT
throughput as streaming-bandwidth bound:

    t_fft = passes(n) * 2 * n * word_bytes / (BW * efficiency)

with `passes` = 1 while the transform fits a threadblock's shared memory
(cuFFT's single-pass regime for these sizes) and the multi-pass fallback
beyond — which reproduces the paper's footnote-8 regime change at n=16K
full precision on the 3070.

Energy = board power * time (the paper measures power with nvidia-smi).

Polynomial multiplication on the GPU (cuFFT + pointwise): 3 transforms of
the 2n-padded sequences plus one pointwise-multiply pass, each memory bound
— the paper's §6 explanation for why its polymul ratios beat its FFT ratios.
Real polymul uses the same Eq. (10) packing (2 transform-equivalents).
"""
from __future__ import annotations

from repro.core.pim.device_model import GPUConfig


def fft_time_s(n: int, gpu: GPUConfig, word_bytes: int) -> float:
    passes = gpu.fft_passes(n, word_bytes)
    traffic = passes * 2 * n * word_bytes
    return traffic / (gpu.mem_bw_bytes * gpu.bw_efficiency)


def fft_throughput_per_s(n: int, gpu: GPUConfig, word_bytes: int) -> float:
    return 1.0 / fft_time_s(n, gpu, word_bytes)


def fft_energy_j_per_op(n: int, gpu: GPUConfig, word_bytes: int) -> float:
    return gpu.board_power_w * fft_time_s(n, gpu, word_bytes)


def _pointwise_time_s(n: int, gpu: GPUConfig, word_bytes: int) -> float:
    # read two operands + write product, streaming
    return 3 * n * word_bytes / (gpu.mem_bw_bytes * gpu.bw_efficiency)


def polymul_time_s(n: int, gpu: GPUConfig, word_bytes: int,
                   *, real: bool = False) -> float:
    """Polymul at transform dimension n (inputs of degree n/2 zero-padded to
    n, paper footnote 4 — benchmark dimensions index the transform size so
    PIM and GPU run identical transforms).

    complex: FFT(a), FFT(b), pointwise, IFFT = 3 transforms + 1 pointwise.
    real:    Eq. (10) packing = 2 transform-equivalents + unpack + pointwise.
    """
    n_transforms = 2 if real else 3
    t = n_transforms * fft_time_s(n, gpu, word_bytes)
    t += _pointwise_time_s(n, gpu, word_bytes)
    if real:
        t += _pointwise_time_s(n, gpu, word_bytes)  # unpack pass
    return t


def polymul_throughput_per_s(n: int, gpu: GPUConfig, word_bytes: int,
                             *, real: bool = False) -> float:
    return 1.0 / polymul_time_s(n, gpu, word_bytes, real=real)


def polymul_energy_j_per_op(n: int, gpu: GPUConfig, word_bytes: int,
                            *, real: bool = False) -> float:
    return gpu.board_power_w * polymul_time_s(n, gpu, word_bytes, real=real)
