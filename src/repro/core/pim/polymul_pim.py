"""In-memory polynomial multiplication (paper §5) on the crossbar simulator.

Pipeline (convolution theorem, Eq. (9)):
  (1) FFT of each polynomial's coefficients — WITHOUT the input bit-reversal
      permutations: across DFT.IDFT they cancel (paper §5), so neither the
      forward nor the inverse transform charges them;
  (2) element-wise complex product — one vectored cmul, serial over the
      beta column-units (ceil(beta/p) with partitions);
  (3) inverse FFT with the 1/n scaling absorbed as an exponent decrement.

Real-coefficient variant (Eq. (10)): both forward transforms fold into ONE
complex FFT of z = a + i b; the unpack uses the paper's in-memory tricks —
conjugate = imag sign-bit flip, multiply-by-i = plane swap + sign flip,
divide-by-2 = exponent decrement, Z_{n-k} = order reversal via swaps. Area
also halves (one packed sequence instead of two), which doubles the batch —
both effects feed the paper's observation that real-polymul ratios beat the
FFT ratios.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.pim import aritpim
from repro.core.pim.crossbar import Counters, CrossbarSim
from repro.core.pim.device_model import PIMConfig
from repro.core.pim.fft_pim import (PIMFFTResult, fft_latency_cycles,
                                    pim_fft)


def _unpack_cycles(cfg: PIMConfig, spec: aritpim.FloatSpec) -> int:
    """Eq. (10) unpack: reversal + conj + 2 cadds + mul-by-i + exponent
    decrements, charged with the paper's §5 cost dictionary."""
    word = aritpim.complex_word_bits(spec)
    cycles = 0
    cycles += (cfg.crossbar_rows // 2) * 6        # order reversal (row swaps)
    cycles += 2                                   # conjugate: sign-bit NOT
    cycles += 2 * aritpim.complex_add_cycles(spec)  # (Zrev* +- Z)
    cycles += aritpim.swap_cycles(word // 2) + 2  # multiply by i
    cycles += 2 * 2                               # /2: exponent decrements
    return cycles


@dataclasses.dataclass(frozen=True)
class PIMPolymulResult:
    output: np.ndarray
    counters: Counters


def pim_polymul(a: np.ndarray, b: np.ndarray, cfg: PIMConfig,
                spec: aritpim.FloatSpec) -> PIMPolymulResult:
    """Circular product (length n) on the simulator, complex coefficients."""
    n = len(a)
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    fa = pim_fft(np.asarray(a), cfg, spec, charge_perm=False)
    fb = pim_fft(np.asarray(b), cfg, spec, charge_perm=False)
    sim = CrossbarSim(cfg, spec)
    prod = fa.output * fb.output
    sim.charge_column_op("cmul", cfg.crossbar_rows, serial=serial)
    inv = pim_fft(prod, cfg, spec, inverse=True, charge_perm=False)
    ctr = Counters(
        cycles=fa.counters.cycles + fb.counters.cycles + sim.ctr.cycles
        + inv.counters.cycles,
        gates=fa.counters.gates + fb.counters.gates + sim.ctr.gates
        + inv.counters.gates)
    return PIMPolymulResult(output=inv.output, counters=ctr)


def pim_polymul_real(a: np.ndarray, b: np.ndarray, cfg: PIMConfig,
                     spec: aritpim.FloatSpec) -> PIMPolymulResult:
    """Circular product of REAL polys via Eq. (10): one packed forward FFT."""
    n = len(a)
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    z = np.asarray(a, np.float64) + 1j * np.asarray(b, np.float64)
    fz = pim_fft(z, cfg, spec, charge_perm=False)
    sim = CrossbarSim(cfg, spec)
    zf = fz.output
    zrev = np.roll(zf[::-1], 1)
    fa = 0.5 * (np.conj(zrev) + zf)
    fb = 0.5j * (np.conj(zrev) - zf)
    sim.ctr.cycles += _unpack_cycles(cfg, spec) * serial
    sim.ctr.gates += _unpack_cycles(cfg, spec) * serial * cfg.crossbar_rows
    prod = fa * fb
    sim.charge_column_op("cmul", cfg.crossbar_rows, serial=serial)
    inv = pim_fft(prod, cfg, spec, inverse=True, charge_perm=False)
    ctr = Counters(
        cycles=fz.counters.cycles + sim.ctr.cycles + inv.counters.cycles,
        gates=fz.counters.gates + sim.ctr.gates + inv.counters.gates)
    return PIMPolymulResult(output=inv.output.real, counters=ctr)


# ---------------------------------------------------------------------------
# Closed forms + throughput/energy (benchmarks)
# ---------------------------------------------------------------------------

def polymul_latency_cycles(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                           *, real: bool = False) -> int:
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    fwd = fft_latency_cycles(n, cfg, spec, charge_perm=False)
    inv = fft_latency_cycles(n, cfg, spec, charge_perm=False, inverse=True)
    total = (1 if real else 2) * fwd + inv
    total += aritpim.complex_mul_cycles(spec) * serial
    if real:
        total += _unpack_cycles(cfg, spec) * serial
    return total


def polymul_area_words(real: bool) -> int:
    """Operand words per element: complex needs a and b resident (2), real
    packs both into one complex sequence (1)."""
    return 1 if real else 2


def polymul_throughput_per_s(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                             *, real: bool = False) -> float:
    word = aritpim.complex_word_bits(spec)
    lat = polymul_latency_cycles(n, cfg, spec, real=real) / cfg.clock_hz
    r = cfg.crossbar_rows
    beta = max(1, n // (2 * r))
    data_cols = polymul_area_words(real) * 2 * beta * word
    scratch = cfg.temp_words * word * cfg.partitions
    area = max(1.0, (data_cols + scratch) / cfg.crossbar_cols)
    batch = int(cfg.num_crossbars / area)
    return batch * cfg.concurrency / lat


def polymul_energy_j_per_op(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                            *, real: bool = False) -> float:
    rng = np.random.default_rng(0)
    if real:
        a = rng.standard_normal(n)
        b = rng.standard_normal(n)
        res = pim_polymul_real(a, b, cfg, spec)
    else:
        a = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        b = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        res = pim_polymul(a, b, cfg, spec)
    return res.counters.energy_j(cfg)
