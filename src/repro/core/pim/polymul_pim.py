"""In-memory polynomial multiplication (paper §5) on the crossbar simulator.

Pipeline (convolution theorem, Eq. (9)):
  (1) FFT of each polynomial's coefficients — WITHOUT the input bit-reversal
      permutations: across DFT.IDFT they cancel (paper §5), so neither the
      forward nor the inverse transform charges them;
  (2) element-wise complex product — one vectored cmul, serial over the
      beta column-units (ceil(beta/p) with partitions);
  (3) inverse FFT with the 1/n scaling absorbed as an exponent decrement.

Real-coefficient variant (Eq. (10)): both forward transforms fold into ONE
complex FFT of z = a + i b; the unpack uses the paper's in-memory tricks —
conjugate = imag sign-bit flip, multiply-by-i = plane swap + sign flip,
divide-by-2 = exponent decrement, Z_{n-k} = order reversal via swaps. Area
also halves (one packed sequence instead of two), which doubles the batch —
both effects feed the paper's observation that real-polymul ratios beat the
FFT ratios.

Paired inverse (this reproduction's batched extension of Eq. (10), mirrored
by kernels/polymul.py): the product spectrum of two real polynomials is
Hermitian, so TWO products pack into one inverse transform as
Q = P_0 + i P_1 — per product that is 1 forward + 1/2 inverse = 1.5
transform-equivalents vs the complex path's 3, the ~2x the serve bench and
the BENCH_fourier.json gate pin at <= 0.65x simulated cycles.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.pim import aritpim
from repro.core.pim.crossbar import Counters, CrossbarSim
from repro.core.pim.device_model import PIMConfig
from repro.core.pim.fft_pim import (PIMFFTResult, _hermitian_split,
                                    fft_latency_cycles, pim_fft,
                                    realpack_unpack_cycles)

# Back-compat alias: the unpack charge moved to fft_pim so pim_rfft and the
# polymul paths share one definition.
_unpack_cycles = realpack_unpack_cycles


@dataclasses.dataclass(frozen=True)
class PIMPolymulResult:
    output: np.ndarray
    counters: Counters


def pim_polymul(a: np.ndarray, b: np.ndarray, cfg: PIMConfig,
                spec: aritpim.FloatSpec, *, faults=None,
                array_id: int = 0) -> PIMPolymulResult:
    """Circular product (length n) on the simulator, complex coefficients."""
    n = len(a)
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    fa = pim_fft(np.asarray(a), cfg, spec, charge_perm=False,
                 faults=faults, array_id=array_id)
    fb = pim_fft(np.asarray(b), cfg, spec, charge_perm=False,
                 faults=faults, array_id=array_id)
    sim = CrossbarSim(cfg, spec)
    prod = fa.output * fb.output
    sim.charge_column_op("cmul", cfg.crossbar_rows, serial=serial)
    inv = pim_fft(prod, cfg, spec, inverse=True, charge_perm=False,
                  faults=faults, array_id=array_id)
    ctr = Counters(
        cycles=fa.counters.cycles + fb.counters.cycles + sim.ctr.cycles
        + inv.counters.cycles,
        gates=fa.counters.gates + fb.counters.gates + sim.ctr.gates
        + inv.counters.gates)
    return PIMPolymulResult(output=inv.output, counters=ctr)


def _real_forward_product(a: np.ndarray, b: np.ndarray, cfg: PIMConfig,
                          spec: aritpim.FloatSpec, serial: int,
                          faults=None,
                          array_id: int = 0) -> tuple[np.ndarray, Counters]:
    """Shared front half of the real paths: packed forward FFT of
    z = a + i b, Hermitian unpack, pointwise product — returns the product
    spectrum and its counters (no inverse transform)."""
    z = np.asarray(a, np.float64) + 1j * np.asarray(b, np.float64)
    fz = pim_fft(z, cfg, spec, charge_perm=False,
                 faults=faults, array_id=array_id)
    sim = CrossbarSim(cfg, spec)
    fa, fb = _hermitian_split(fz.output)
    unpack = realpack_unpack_cycles(cfg, spec)
    sim.ctr.cycles += unpack * serial
    sim.ctr.gates += unpack * serial * cfg.crossbar_rows
    prod = fa * fb
    sim.charge_column_op("cmul", cfg.crossbar_rows, serial=serial)
    ctr = Counters(cycles=fz.counters.cycles + sim.ctr.cycles,
                   gates=fz.counters.gates + sim.ctr.gates)
    return prod, ctr


def _pack_pair_cycles(cfg: PIMConfig, spec: aritpim.FloatSpec) -> int:
    """Charge for packing Q = P_0 + i P_1 before the shared inverse:
    multiply-by-i (half-word swap + sign flip) plus one complex add."""
    word = aritpim.complex_word_bits(spec)
    return (aritpim.swap_cycles(word // 2) + 2
            + aritpim.complex_add_cycles(spec))


def pim_polymul_real(a: np.ndarray, b: np.ndarray, cfg: PIMConfig,
                     spec: aritpim.FloatSpec, *, faults=None,
                     array_id: int = 0) -> PIMPolymulResult:
    """Circular product of REAL polys via Eq. (10): one packed forward FFT
    per product, and — for batched inputs of shape (B, n) — one inverse
    transform per PAIR of products (Q = P_0 + i P_1; both product spectra
    are Hermitian, so Re/Im of IFFT(Q) are the two results).

    1-D inputs keep the legacy single-product pipeline (its own forward AND
    inverse); (B, n) inputs run ceil(B/2) inverse transforms. Counter parity
    for both shapes is pinned in tests/test_pim.py.
    """
    a = np.asarray(a)
    b = np.asarray(b)
    assert a.shape == b.shape
    n = a.shape[-1]
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    if a.ndim == 1:
        prod, ctr = _real_forward_product(a, b, cfg, spec, serial,
                                          faults=faults, array_id=array_id)
        inv = pim_fft(prod, cfg, spec, inverse=True, charge_perm=False,
                      faults=faults, array_id=array_id)
        return PIMPolymulResult(
            output=inv.output.real,
            counters=Counters(cycles=ctr.cycles + inv.counters.cycles,
                              gates=ctr.gates + inv.counters.gates))
    assert a.ndim == 2, f"expected (n,) or (B, n), got {a.shape}"
    B = a.shape[0]
    out = np.empty((B, n), np.float64)
    total = Counters()
    for j in range(0, B - 1, 2):
        p0, c0 = _real_forward_product(a[j], b[j], cfg, spec, serial,
                                       faults=faults, array_id=array_id)
        p1, c1 = _real_forward_product(a[j + 1], b[j + 1], cfg, spec, serial,
                                       faults=faults, array_id=array_id)
        sim = CrossbarSim(cfg, spec)
        pack = _pack_pair_cycles(cfg, spec)
        sim.ctr.cycles += pack * serial
        sim.ctr.gates += pack * serial * cfg.crossbar_rows
        q = p0 + 1j * p1
        inv = pim_fft(q, cfg, spec, inverse=True, charge_perm=False,
                      faults=faults, array_id=array_id)
        out[j] = inv.output.real
        out[j + 1] = inv.output.imag
        total.cycles += (c0.cycles + c1.cycles + sim.ctr.cycles
                         + inv.counters.cycles)
        total.gates += (c0.gates + c1.gates + sim.ctr.gates
                        + inv.counters.gates)
    if B % 2:
        res = pim_polymul_real(a[-1], b[-1], cfg, spec,
                               faults=faults, array_id=array_id)
        out[-1] = res.output
        total.cycles += res.counters.cycles
        total.gates += res.counters.gates
    return PIMPolymulResult(output=out, counters=total)


# ---------------------------------------------------------------------------
# Closed forms + throughput/energy (benchmarks)
# ---------------------------------------------------------------------------

def polymul_latency_cycles(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                           *, real: bool = False) -> int:
    """Single-product closed form: ``real=True`` is the legacy unpaired
    Eq. (10) pipeline (1 fwd + 1 inv). The production real path amortizes
    the inverse across pairs — see ``polymul_real_pair_latency_cycles``."""
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    fwd = fft_latency_cycles(n, cfg, spec, charge_perm=False)
    inv = fft_latency_cycles(n, cfg, spec, charge_perm=False, inverse=True)
    total = (1 if real else 2) * fwd + inv
    total += aritpim.complex_mul_cycles(spec) * serial
    if real:
        total += realpack_unpack_cycles(cfg, spec) * serial
    return total


def polymul_real_pair_latency_cycles(n: int, cfg: PIMConfig,
                                     spec: aritpim.FloatSpec) -> int:
    """Closed form for TWO real products through the paired-inverse path:
    2 packed forwards + 2 unpacks + 2 pointwise cmuls + the Q = P_0 + i P_1
    pack + ONE inverse. Per product this is ~1.5 transform-equivalents; the
    ratio ``pair / (2 * complex)`` is the <= 0.65 gate in
    benchmarks/run.py --smoke (BENCH_fourier.json) and tests/test_pim.py.
    Asserted equal to ``pim_polymul_real`` counters on (2, n) inputs."""
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    fwd = fft_latency_cycles(n, cfg, spec, charge_perm=False)
    inv = fft_latency_cycles(n, cfg, spec, charge_perm=False, inverse=True)
    return (2 * fwd + inv
            + 2 * realpack_unpack_cycles(cfg, spec) * serial
            + 2 * aritpim.complex_mul_cycles(spec) * serial
            + _pack_pair_cycles(cfg, spec) * serial)


def polymul_real_batch_latency_cycles(n: int, batch: int, cfg: PIMConfig,
                                      spec: aritpim.FloatSpec) -> int:
    """Closed form for a (batch, n) call to ``pim_polymul_real``: full
    pairs ride the shared inverse, an odd tail product falls back to the
    unpaired pipeline."""
    pairs, tail = divmod(batch, 2)
    total = pairs * polymul_real_pair_latency_cycles(n, cfg, spec)
    if tail:
        total += polymul_latency_cycles(n, cfg, spec, real=True)
    return total


def polymul_area_words(real: bool) -> int:
    """Operand words per element: complex needs a and b resident (2), real
    packs both into one complex sequence (1)."""
    return 1 if real else 2


def polymul_throughput_per_s(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                             *, real: bool = False) -> float:
    """Steady-state products/s. The real path amortizes the paired inverse
    (pair latency / 2 per product) on top of its halved operand area — the
    two effects behind the paper's real-polymul ratios exceeding its FFT
    ratios."""
    word = aritpim.complex_word_bits(spec)
    if real:
        lat = (polymul_real_pair_latency_cycles(n, cfg, spec) / 2
               / cfg.clock_hz)
    else:
        lat = polymul_latency_cycles(n, cfg, spec, real=real) / cfg.clock_hz
    r = cfg.crossbar_rows
    beta = max(1, n // (2 * r))
    data_cols = polymul_area_words(real) * 2 * beta * word
    scratch = cfg.temp_words * word * cfg.partitions
    area = max(1.0, (data_cols + scratch) / cfg.crossbar_cols)
    batch = int(cfg.num_crossbars / area)
    return batch * cfg.concurrency / lat


def polymul_energy_j_per_op(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                            *, real: bool = False) -> float:
    rng = np.random.default_rng(0)
    if real:
        a = rng.standard_normal(n)
        b = rng.standard_normal(n)
        res = pim_polymul_real(a, b, cfg, spec)
    else:
        a = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        b = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        res = pim_polymul(a, b, cfg, spec)
    return res.counters.energy_j(cfg)
