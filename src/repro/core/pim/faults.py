"""Seeded, deterministic device-fault models for the crossbar simulator.

Real memristor arrays are not the perfect machine ``CrossbarSim`` models:
cells get stuck at 0/1 (endurance wear, forming failures), gate ops
suffer transient bit flips, and whole arrays die. This module is the
fault INJECTION side of the repo's ABFT story (docs/fault_tolerance.md):
a :class:`FaultModel` describes a fleet-level fault population; the sim
resolves its own array's slice of it (:meth:`FaultModel.for_array`) and
corrupts butterfly outputs behind a zero-overhead-when-disabled hook
(``crossbar.CrossbarSim``), appending ``("fault:<kind>:a<id>", 0)``
ledger entries to the charge log so tests can assert exactly which array
misbehaved and how often.

Everything is seeded and replayable: the same ``(seed, array_id)`` pair
always yields the same stuck cells, and transients draw from a generator
seeded per array — re-executing the same op sequence reproduces the same
corruption, which is what lets the chaos tests pin "corruption is always
detected" instead of sampling it.

Value-level fidelity (same abstraction as the sim itself): a stuck cell
forces one vector element to a fixed value (0 for SA0; 1 for SA1 in the
float domain, a forced word bit in the modular domain), a transient flip
perturbs one element's stored word (an exponent-bit flip doubles a float;
an xor of a low bit shifts a residue by ±1) — bit-level gate sequences
are costs, not re-simulated state, so faults land on values.

Recovery (``launch/engine.py``): a circuit breaker that gives up on an
array calls :meth:`FaultModel.quarantine` — the logical array id remaps
to a spare PHYSICAL array beyond the faulty population, so subsequent
``for_array`` lookups come back clean. Spares are finite
(:class:`SparesExhausted`), like on real dies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: Bit lanes a stuck/transient fault may land on in the modular domain:
#: kept below the 2^30 modulus width so a forced bit stays a plausible
#: residue perturbation rather than a guaranteed out-of-range value.
_FAULT_BIT_LANES = 24


class SparesExhausted(RuntimeError):
    """Quarantine requested but every spare array is already mapped."""


@dataclasses.dataclass(frozen=True)
class ArrayFaults:
    """One physical array's resolved fault state (what the sim consumes).

    ``stuck_pos`` are position SEEDS, reduced mod the live vector length
    at injection time — the sim's vectored ops span different row counts
    per stage, and a fixed cell must keep hitting the same relative slot
    deterministically across all of them.
    """
    array_id: int
    dead: bool
    stuck_pos: tuple[int, ...]
    stuck_val: tuple[int, ...]      # 0 = SA0, 1 = SA1, per cell
    stuck_bit: tuple[int, ...]      # forced bit lane (modular domain)
    bitflip_per_gate: float

    @property
    def permanent(self) -> bool:
        """True when this array corrupts EVERY op (dead / stuck cells) —
        the failure class that must trip the engine's circuit breaker
        rather than be retried away."""
        return self.dead or bool(self.stuck_pos)


class FaultModel:
    """A seeded fleet-level fault population over ``n_arrays`` + spares.

    Mutable on purpose: quarantine state (logical -> spare remap) is the
    one piece of recovery state that must survive across re-executions,
    so it lives here rather than in any single sim instance.
    """

    def __init__(self, *, seed: int = 0, stuck_per_array: int = 0,
                 bitflip_per_gate: float = 0.0,
                 dead_arrays: tuple[int, ...] = (),
                 n_arrays: int = 16, spares: int = 4):
        if n_arrays < 1:
            raise ValueError(f"n_arrays={n_arrays} must be >= 1")
        if stuck_per_array < 0:
            raise ValueError(f"stuck_per_array={stuck_per_array} < 0")
        if not 0.0 <= bitflip_per_gate <= 1.0:
            raise ValueError(
                f"bitflip_per_gate={bitflip_per_gate} not a probability")
        if spares < 0:
            raise ValueError(f"spares={spares} < 0")
        bad = [a for a in dead_arrays if not 0 <= a < n_arrays]
        if bad:
            raise ValueError(f"dead_arrays {bad} outside [0, {n_arrays})")
        self.seed = seed
        self.stuck_per_array = stuck_per_array
        self.bitflip_per_gate = float(bitflip_per_gate)
        self.dead_arrays = tuple(dead_arrays)
        self.n_arrays = n_arrays
        self.spares = spares
        self._quarantined: dict[int, int] = {}
        self._spares_used = 0

    # -- quarantine / spare remap ------------------------------------------
    def physical(self, array_id: int) -> int:
        """Logical -> physical array id (identity until quarantined)."""
        return self._quarantined.get(array_id, array_id)

    def is_quarantined(self, array_id: int) -> bool:
        return array_id in self._quarantined

    @property
    def quarantined(self) -> dict[int, int]:
        return dict(self._quarantined)

    def quarantine(self, array_id: int) -> int:
        """Remap a faulty logical array onto the next spare; idempotent.

        Spares live beyond the faulty population (ids >= ``n_arrays``),
        so a quarantined array resolves clean in :meth:`for_array`.
        """
        if array_id in self._quarantined:
            return self._quarantined[array_id]
        if self._spares_used >= self.spares:
            raise SparesExhausted(
                f"array {array_id}: all {self.spares} spare arrays are "
                f"already mapped ({sorted(self._quarantined)})")
        spare = self.n_arrays + self._spares_used
        self._spares_used += 1
        self._quarantined[array_id] = spare
        return spare

    # -- per-array resolution ----------------------------------------------
    def for_array(self, array_id: int):
        """Resolve the fault state a sim bound to ``array_id`` sees, or
        None when that array is clean (the zero-overhead fast path: the
        sim holds None and its op hooks cost one identity check)."""
        phys = self.physical(array_id)
        if phys >= self.n_arrays:
            return None             # spare: clean by construction
        dead = phys in self.dead_arrays
        stuck_pos: tuple[int, ...] = ()
        stuck_val: tuple[int, ...] = ()
        stuck_bit: tuple[int, ...] = ()
        if self.stuck_per_array:
            rng = np.random.default_rng([self.seed, phys])
            stuck_pos = tuple(
                int(v) for v in rng.integers(0, 1 << 30,
                                             self.stuck_per_array))
            stuck_val = tuple(
                int(v) for v in rng.integers(0, 2, self.stuck_per_array))
            stuck_bit = tuple(
                int(v) for v in rng.integers(0, _FAULT_BIT_LANES,
                                             self.stuck_per_array))
        if not dead and not stuck_pos and self.bitflip_per_gate <= 0.0:
            return None
        return ArrayFaults(array_id=array_id, dead=dead,
                           stuck_pos=stuck_pos, stuck_val=stuck_val,
                           stuck_bit=stuck_bit,
                           bitflip_per_gate=self.bitflip_per_gate)

    def rng_for(self, array_id: int, salt: int = 0) -> np.random.Generator:
        """Deterministic transient-fault stream for one array: seeded by
        (model seed, PHYSICAL id, salt), so a quarantined array's spare
        draws a different — still replayable — stream."""
        return np.random.default_rng([self.seed, self.physical(array_id),
                                      salt])

    def __repr__(self) -> str:
        return (f"FaultModel(seed={self.seed}, "
                f"stuck_per_array={self.stuck_per_array}, "
                f"bitflip_per_gate={self.bitflip_per_gate}, "
                f"dead_arrays={self.dead_arrays}, "
                f"n_arrays={self.n_arrays}, spares={self.spares}, "
                f"quarantined={self._quarantined})")
