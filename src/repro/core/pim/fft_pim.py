"""In-memory FFT algorithms (paper §4): r-, 2r-, and 2r-beta configurations.

Each algorithm runs on the logical crossbar simulator: the butterfly values
are computed numerically (and checked against numpy.fft in the tests) while
cycle/gate counters accumulate per the AritPIM cost model. Closed-form
latency/energy expressions — used by the large-n benchmarks — are derived
from the same per-group structure and asserted equal to the simulator's
counters in tests/test_pim.py.

Structural model per group g (of log2 n groups), following §4.3-4.5:

  r-FFT   (n = r):   align half the sequence (1 column-parallel word copy +
                     r/2 serial row copies), butterfly on r/2 rows, move back.
  2r-FFT  (n = 2r):  butterfly on all r rows (full utilization); transition =
                     one in-place pair swap: within-row (column-parallel,
                     3N cycles) when the partner shares the row, otherwise
                     r/2 serial row swaps.
  2rb-FFT (n = 2rb): beta column-units execute the group's butterflies
                     serially (ceil(beta/p) with p partitions [25]); unit
                     transitions add column-parallel copies between units.

Twiddle constants are written by the periphery each group (footnote 3),
charged as r/2 row writes.

The input bit-reversal permutation is charged as serial row swaps for FFT
(and skipped for polymul where the permutations cancel, §5) — with Stockham
there is no analogue; this is the memristive layout's own cost.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.pim import aritpim
from repro.core.pim.crossbar import Counters, CrossbarSim
from repro.core.pim.device_model import PIMConfig


def _bit_reverse_perm(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def _perm_swap_count(n: int) -> int:
    """Number of 2-cycles in the bit-reversal permutation."""
    rev = _bit_reverse_perm(n)
    return int(np.sum(rev > np.arange(n)))


@dataclasses.dataclass(frozen=True)
class PIMFFTResult:
    output: np.ndarray
    counters: Counters
    #: ordered (tag, cycles) charge records from the simulator, for
    #: counter-ordering assertions (see CrossbarSim.log).
    log: tuple = ()


def _twiddles(n: int, inverse: bool) -> np.ndarray:
    sign = 1.0 if inverse else -1.0
    return np.exp(sign * 2j * np.pi * np.arange(n // 2) / n)


def _fft_groups(sim: CrossbarSim, x: np.ndarray, *, inverse: bool,
                serial_units: int, active_rows: int,
                transition_fn) -> np.ndarray:
    """Shared group loop: iterative DIT butterflies after bit reversal.

    ``transition_fn(stage)`` charges the inter-group data movement of the
    specific configuration. Butterfly values verified numerically.
    """
    n = len(x)
    stages = n.bit_length() - 1
    y = x[_bit_reverse_perm(n)].astype(np.complex128)
    for s in range(stages):
        m = 2 << s            # butterfly span
        half = m >> 1
        # gather pairs (j, j + half) within blocks of m
        idx = np.arange(n).reshape(n // m, m)
        top = idx[:, :half].ravel()
        bot = idx[:, half:].ravel()
        w = np.tile(_twiddles(m, inverse), n // m)
        sim.charge_twiddle_writes(sim.cfg.crossbar_rows // 2)
        transition_fn(s)
        u, v = sim.butterfly_rows(y[top], y[bot], w, active_rows,
                                  serial_units=serial_units)
        y[top], y[bot] = u, v
    if inverse:
        # 1/n scaling: exponent decrement per element (paper §5 trick) —
        # column-parallel copy-scale, one word op.
        sim.charge_column_op("copy", active_rows)
        y = y / n
    return y


def r_fft(x: np.ndarray, cfg: PIMConfig, spec: aritpim.FloatSpec,
          *, inverse: bool = False, charge_perm: bool = True,
          faults=None, array_id: int = 0) -> PIMFFTResult:
    """r-configuration (§4.3): n = crossbar rows, one element per row."""
    n = len(x)
    assert n == cfg.crossbar_rows, f"r-FFT needs n == rows ({cfg.crossbar_rows})"
    sim = CrossbarSim(cfg, spec, faults=faults, array_id=array_id)
    sim.load(x)
    if charge_perm:
        sim.charge_row_ops(_perm_swap_count(n), cycles_per_row=6, tag="perm")

    def transition(stage):
        # shift half right (column-parallel word copy) + r/2 rows up, then
        # back after the butterfly: 2x.
        sim.charge_column_op("copy", n // 2)
        sim.charge_row_ops(n // 2, cycles_per_row=2)
        sim.charge_column_op("copy", n // 2)
        sim.charge_row_ops(n // 2, cycles_per_row=2)

    y = _fft_groups(sim, x, inverse=inverse, serial_units=1,
                    active_rows=n // 2, transition_fn=transition)
    return PIMFFTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def fft_2r(x: np.ndarray, cfg: PIMConfig, spec: aritpim.FloatSpec,
           *, inverse: bool = False, charge_perm: bool = True,
           faults=None, array_id: int = 0) -> PIMFFTResult:
    """2r-configuration (§4.4): two elements per row (snake), full-row use."""
    n = len(x)
    r = cfg.crossbar_rows
    assert n == 2 * r, f"2r-FFT needs n == 2*rows ({2 * r})"
    sim = CrossbarSim(cfg, spec, faults=faults, array_id=array_id)
    sim.load(x)
    if charge_perm:
        sim.charge_row_ops(_perm_swap_count(n), cycles_per_row=6, tag="perm")

    def transition(stage):
        if stage == 0:
            return  # snake layout already pairs stage-0 partners in-row
        # in-place pair swap (Fig. 4d): one column-parallel word swap plus
        # r/2 serial row swaps for the cross-row half of the pairs.
        sim.charge_column_op("swap", r)
        sim.charge_row_ops(r // 2, cycles_per_row=6)

    y = _fft_groups(sim, x, inverse=inverse, serial_units=1,
                    active_rows=r, transition_fn=transition)
    return PIMFFTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def fft_2rbeta(x: np.ndarray, cfg: PIMConfig, spec: aritpim.FloatSpec,
               *, inverse: bool = False, charge_perm: bool = True,
               faults=None, array_id: int = 0) -> PIMFFTResult:
    """2r-beta configuration (§4.5): 2*beta elements per row across beta
    column-units; butterflies serial over units, ceil(beta/p) with
    partitions [25]."""
    n = len(x)
    r = cfg.crossbar_rows
    beta = n // (2 * r)
    assert n == 2 * r * beta and beta >= 1, f"n={n} not a 2r*beta multiple"
    word = aritpim.complex_word_bits(spec)
    need = cfg.crossbars_per_fft(n, word)
    assert need <= 1.0 + 1e-9 or beta <= cfg.crossbar_cols // (2 * word), \
        f"n={n} exceeds crossbar width (footnote 7)"
    sim = CrossbarSim(cfg, spec, faults=faults, array_id=array_id)
    serial = math.ceil(beta / cfg.partitions)
    if charge_perm:
        # Input bit-reversal happens BEFORE the group loop, exactly as in
        # the r/2r configurations (it permutes the in-array layout, bounded
        # by one array's 2r elements); an earlier revision charged it after
        # the groups, which kept the totals right but broke any
        # counter-ordering invariant (tests/test_pim_ntt.py pins this).
        sim.charge_row_ops(_perm_swap_count(min(n, 2 * r)), cycles_per_row=6,
                           tag="perm")

    def transition(stage):
        if stage == 0:
            return
        sim.charge_column_op("swap", r)          # within-row pair swaps
        sim.charge_row_ops(r // 2, cycles_per_row=6)  # cross-row half
        if stage >= int(math.log2(2 * r)):
            # pairs now span units: column-parallel inter-unit word copies,
            # serialized over units (partitions parallelize them too).
            sim.charge_column_op("copy", r,
                                 serial=math.ceil(beta / cfg.partitions))

    y = _fft_groups(sim, x, inverse=inverse, serial_units=serial,
                    active_rows=r, transition_fn=transition)
    return PIMFFTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def pim_fft(x: np.ndarray, cfg: PIMConfig, spec: aritpim.FloatSpec,
            *, inverse: bool = False, charge_perm: bool = True,
            faults=None, array_id: int = 0) -> PIMFFTResult:
    """Dispatch to the layout the paper uses for this n (§6: 2K..16K -> 2r,
    2r*2, 2r*4, 2r*8)."""
    n = len(x)
    r = cfg.crossbar_rows
    if n == r:
        return r_fft(x, cfg, spec, inverse=inverse, charge_perm=charge_perm,
                     faults=faults, array_id=array_id)
    return fft_2rbeta(x, cfg, spec, inverse=inverse, charge_perm=charge_perm,
                      faults=faults, array_id=array_id)


# ---------------------------------------------------------------------------
# Real-input path (paper Eq. (10)): two real sequences per complex FFT.
# ---------------------------------------------------------------------------

def realpack_unpack_cycles(cfg: PIMConfig, spec: aritpim.FloatSpec) -> int:
    """Eq. (10) Hermitian unpack, per serial unit: order reversal + conj +
    2 complex adds + multiply-by-i + exponent decrements, charged with the
    paper's §5 in-memory trick costs (conjugate = imag sign-bit flip,
    multiply by i = half-word swap + sign flip, /2 = exponent decrement).
    THE single definition — ``pim_rfft`` and the real polymul paths in
    ``polymul_pim`` all charge it from here."""
    word = aritpim.complex_word_bits(spec)
    cycles = 0
    cycles += (cfg.crossbar_rows // 2) * 6        # order reversal (row swaps)
    cycles += 2                                   # conjugate: sign-bit NOT
    cycles += 2 * aritpim.complex_add_cycles(spec)  # (Zrev* +- Z)
    cycles += aritpim.swap_cycles(word // 2) + 2  # multiply by i
    cycles += 2 * 2                               # /2: exponent decrements
    return cycles


def _hermitian_split(zf: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Numerical Eq. (10) split of Z = FFT(x + i y) into (X, Y)."""
    zrev = np.roll(zf[::-1], 1)
    return 0.5 * (np.conj(zrev) + zf), 0.5j * (np.conj(zrev) - zf)


@dataclasses.dataclass(frozen=True)
class PIMRFFTResult:
    #: (2, n//2 + 1) complex half-spectra of the two packed real sequences
    spectra: np.ndarray
    counters: Counters


def pim_rfft(x: np.ndarray, y: np.ndarray, cfg: PIMConfig,
             spec: aritpim.FloatSpec, *, charge_perm: bool = True,
             faults=None, array_id: int = 0) -> PIMRFFTResult:
    """Half-spectra of TWO real sequences via ONE packed complex FFT.

    The crossbar holds z = x + i y (the imag plane stores the second
    sequence instead of zeros — area per transform halves, so the batched
    throughput doubles on top of the shared butterflies). The Hermitian
    unpack runs in-memory with the §5 tricks; only n/2+1 bins per sequence
    are kept. Counter parity with ``rfft_latency_cycles`` is pinned in
    tests/test_pim.py.
    """
    n = len(x)
    assert len(y) == n
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    z = np.asarray(x, np.float64) + 1j * np.asarray(y, np.float64)
    fz = pim_fft(z, cfg, spec, charge_perm=charge_perm,
                 faults=faults, array_id=array_id)
    sim = CrossbarSim(cfg, spec)
    unpack = realpack_unpack_cycles(cfg, spec)
    sim.ctr.cycles += unpack * serial
    sim.ctr.gates += unpack * serial * cfg.crossbar_rows
    fa, fb = _hermitian_split(fz.output)
    half = n // 2 + 1
    spectra = np.stack([fa[:half], fb[:half]])
    ctr = Counters(cycles=fz.counters.cycles + sim.ctr.cycles,
                   gates=fz.counters.gates + sim.ctr.gates)
    return PIMRFFTResult(spectra=spectra, counters=ctr)


def rfft_latency_cycles(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                        *, charge_perm: bool = True) -> int:
    """Closed form for ``pim_rfft`` (two sequences per run): one complex
    transform plus the Hermitian unpack, serialized over the beta units."""
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    return (fft_latency_cycles(n, cfg, spec, charge_perm=charge_perm)
            + realpack_unpack_cycles(cfg, spec) * serial)


def rfft_throughput_per_s(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec
                          ) -> float:
    """Real-sequence transforms per second: each schedule slot carries TWO
    sequences in one packed complex word — the ~2x the paper's real-polymul
    ratios build on, verified against ``fft_throughput_per_s`` in tests."""
    lat = rfft_latency_cycles(n, cfg, spec) / cfg.clock_hz
    word = aritpim.complex_word_bits(spec)
    return 2 * cfg.batch_capacity(n, word) * cfg.concurrency / lat


# ---------------------------------------------------------------------------
# Distributed real-Hermitian path: four-step FFT across crossbar arrays
# (paper §7's multi-crossbar future work, real-input serving tier).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PIMDistRFFTResult:
    #: (2, n//2 + 1) complex half-spectra of the two packed real sequences
    spectra: np.ndarray
    #: per-shard cycle/gate counters (each shard == the closed form)
    shard_counters: tuple
    #: inter-array transpose traffic, the TPU ledger's all-to-all analogue
    a2a_bytes: int
    #: conjugate-bin mirror route (the half-block ppermute), in bytes
    permute_bytes: int


def _phase_a_fft(sim: CrossbarSim, block: np.ndarray, n1: int,
                 active_rows: int) -> np.ndarray:
    """Length-n1 FFT down the column dimension of a (n1, w) block with
    r-layout alignment moves per stage — the float twin of the NTT model's
    phase A (``ntt_pim._phase_a_ntt``)."""
    y = block[_bit_reverse_perm(n1)].astype(np.complex128)
    for s in range(n1.bit_length() - 1):
        m = 2 << s
        half = m >> 1
        idx = np.arange(n1).reshape(n1 // m, m)
        top = idx[:, :half].ravel()
        bot = idx[:, half:].ravel()
        w = np.tile(_twiddles(m, False), n1 // m)[:, None]
        sim.charge_twiddle_writes(active_rows)
        sim.charge_column_op("copy", active_rows)
        sim.charge_row_ops(active_rows, cycles_per_row=2)
        sim.charge_column_op("copy", active_rows)
        sim.charge_row_ops(active_rows, cycles_per_row=2)
        u, v = sim.butterfly_rows(y[top], y[bot], w, active_rows)
        y[top], y[bot] = u, v
    return y


def pim_rfft_distributed(x: np.ndarray, y: np.ndarray, n_shards: int,
                         cfg: PIMConfig, spec: aritpim.FloatSpec
                         ) -> PIMDistRFFTResult:
    """Half-spectra of TWO real sequences via ONE four-step packed complex
    FFT across ``n_shards`` crossbar arrays.

    n = n1 * n2 with n1 = D shards and n2 = n / D = crossbar rows. The
    Hermitian split (Eq. (10)) is charged PER SHARD (``realpack_unpack_
    cycles``: the within-shard order reversal, conjugate, adds and
    half-scales) and the cross-shard conjugate-bin route — Z-order bin
    k = idx + D*k2 mirrors onto shard (D - idx) mod D — is a half-block
    periphery move charged as BYTES (``permute_bytes``), the same unit as
    the two inter-array transposes (``a2a_bytes``). Matches np.fft.rfft
    numerically and the closed forms ``rfft_distributed_latency_cycles`` /
    ``rfft_distributed_a2a_bytes`` (tests/test_pim.py); total moved bytes
    land at ~0.58x the complex distributed path's per real sequence.
    """
    n = len(x)
    D = n_shards
    if D < 2 or D & (D - 1):
        raise ValueError(f"n_shards={D} must be a power of two >= 2")
    n2 = n // D
    if n2 != cfg.crossbar_rows:
        # ValueError, not assert: a wrong-geometry cost model under
        # ``python -O`` would silently report counters for the wrong shape.
        raise ValueError(f"four-step PIM wants n/D == rows "
                         f"({cfg.crossbar_rows}), got {n2}")
    if len(y) != n:
        raise ValueError(f"sequence lengths differ: {n} vs {len(y)}")
    z = np.asarray(x, np.float64) + 1j * np.asarray(y, np.float64)
    sims = [CrossbarSim(cfg, spec) for _ in range(D)]
    M = z.reshape(D, n2)                               # row j1
    wcol = n2 // D
    # Step 1 transpose: shard s owns all j1 for j2 slice s.
    blocks = [M[:, s * wcol:(s + 1) * wcol].copy() for s in range(D)]
    for s, sim in enumerate(sims):
        yv = _phase_a_fft(sim, blocks[s], D, active_rows=n2 // 2)
        # Step 3: twiddle w^{j2 k1} with GLOBAL j2, exact integer exponents
        # reduced mod n (the same fix as core/fft/distributed.py) — one
        # column-parallel complex multiply over the shard's working set.
        j2 = np.arange(s * wcol, (s + 1) * wcol, dtype=np.int64)
        k1 = np.arange(D, dtype=np.int64)[:, None]
        tw = np.exp(-2j * np.pi * ((k1 * j2[None, :]) % n) / n)
        blocks[s] = yv * tw
        sim.charge_column_op("cmul", cfg.crossbar_rows)
    # Step 4 transpose: shard s owns row k1 = s, all j2.
    Y = np.concatenate(blocks, axis=1)                 # (D=k1, n2=j2)
    Z = np.empty((D, n2), np.complex128)
    for s, sim in enumerate(sims):
        def transition(stage):
            sim.charge_column_op("copy", n2 // 2)
            sim.charge_row_ops(n2 // 2, cycles_per_row=2)
            sim.charge_column_op("copy", n2 // 2)
            sim.charge_row_ops(n2 // 2, cycles_per_row=2)
        # Phase-B input bit-reversal, before the group loop (r-config).
        sim.charge_row_ops(_perm_swap_count(n2), cycles_per_row=6,
                           tag="perm")
        Z[s] = _fft_groups(sim, Y[s], inverse=False, serial_units=1,
                           active_rows=n2 // 2, transition_fn=transition)
        # Per-shard Eq. (10) split: reversal/conjugate/adds/half-scales on
        # the shard's own block (the cross-shard mirror is permute_bytes).
        unpack = realpack_unpack_cycles(cfg, spec)
        sim.ctr.cycles += unpack
        sim.ctr.gates += unpack * cfg.crossbar_rows
    # Z-order assembly X[k1 + k2 n1] = Z[k1, k2] (host-side view), then the
    # numerical split — the per-shard charges above already costed it.
    fz = Z.T.reshape(n)
    fa, fb = _hermitian_split(fz)
    half = n // 2 + 1
    return PIMDistRFFTResult(
        spectra=np.stack([fa[:half], fb[:half]]),
        shard_counters=tuple(s.ctr for s in sims),
        a2a_bytes=rfft_distributed_a2a_bytes(n, spec),
        permute_bytes=rfft_distributed_permute_bytes(n, spec))


def fft_distributed_latency_cycles(n: int, n_shards: int, cfg: PIMConfig,
                                   spec: aritpim.FloatSpec) -> int:
    """Closed-form per-shard cycles of the four-step complex FFT (== every
    shard's counter in ``pim_rfft_distributed`` before the split charge):
    log2(D) r-layout column stages, one twiddle cmul, then a full r-config
    FFT of length n/D."""
    D = n_shards
    n2 = n // D
    r = cfg.crossbar_rows
    assert n2 == r, (n, D, r)
    stage_a = (r // 2                                  # twiddle writes
               + 2 * aritpim.op_cycles("copy", spec) + 2 * (r // 2) * 2
               + aritpim.op_cycles("butterfly", spec))
    phase_a = (D.bit_length() - 1) * stage_a
    twiddle = aritpim.op_cycles("cmul", spec)
    phase_b = fft_latency_cycles(n2, cfg, spec, charge_perm=True)
    return phase_a + twiddle + phase_b


def rfft_distributed_latency_cycles(n: int, n_shards: int, cfg: PIMConfig,
                                    spec: aritpim.FloatSpec) -> int:
    """Per-shard cycles including the Eq. (10) split (two real sequences
    ride the run, as in ``pim_rfft``)."""
    return (fft_distributed_latency_cycles(n, n_shards, cfg, spec)
            + realpack_unpack_cycles(cfg, spec))


def _word_bytes(spec) -> int:
    return aritpim.storage_word_bits(spec) // 8


def fft_distributed_a2a_bytes(n: int, spec, *, ordered: bool = True) -> int:
    """Inter-array transpose traffic of the four-step complex FFT, per
    transform: two in-fabric transposes plus (``ordered``) the Z-order ->
    natural reorder, each moving every complex word once. Unlike the NTT
    model (which leaves Z-order assembly as a host view), the serving tier
    returns natural order, so the ordering transpose is charged — the same
    convention as the TPU ledger's ``four_step_collective_stats``."""
    return (3 if ordered else 2) * n * _word_bytes(spec)


def rfft_distributed_a2a_bytes(n: int, spec) -> int:
    """Transpose traffic of the packed real four-step (TWO real sequences):
    two full-width transposes of the packed transform plus the ordering
    move of the two packed half-spectra (2 x n/2 = n words) — the
    half-spectrum never crosses at full complex width."""
    return (2 * n + n) * _word_bytes(spec)


def rfft_distributed_permute_bytes(n: int, spec) -> int:
    """The conjugate-bin mirror route: each shard ships the upper half of
    its Z-order block to its mirror peer — n/2 words total."""
    return (n // 2) * _word_bytes(spec)


# ---------------------------------------------------------------------------
# Closed forms (benchmarks at scale; asserted == simulator in tests)
# ---------------------------------------------------------------------------

def fft_latency_cycles(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec,
                       *, charge_perm: bool = True,
                       inverse: bool = False) -> int:
    r = cfg.crossbar_rows
    beta = max(1, n // (2 * r))
    stages = n.bit_length() - 1
    bfly = aritpim.butterfly_cycles(spec)
    word = aritpim.complex_word_bits(spec)
    serial = math.ceil(beta / cfg.partitions)
    total = 0
    if charge_perm:
        total += _perm_swap_count(min(n, 2 * r)) * 6
    for s in range(stages):
        total += r // 2                     # twiddle writes
        total += bfly * serial              # butterflies
        if n == r:                          # r-config moves
            total += 2 * aritpim.copy_cycles(word) + 2 * (n // 2) * 2
        elif s > 0:                         # 2r / 2rb transitions
            total += aritpim.swap_cycles(word) + (r // 2) * 6
            if n > 2 * r and s >= int(math.log2(2 * r)):
                total += aritpim.copy_cycles(word) * serial
    if inverse:
        total += aritpim.copy_cycles(word)  # 1/n exponent-decrement pass
    return total


def fft_throughput_per_s(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec
                         ) -> float:
    """Batched throughput: all crossbars run the same schedule in parallel
    (paper: batch size = number of crossbars, net of scratch area)."""
    word = aritpim.complex_word_bits(spec)
    lat = fft_latency_cycles(n, cfg, spec) / cfg.clock_hz
    return cfg.batch_capacity(n, word) * cfg.concurrency / lat


def batched_fft_stats(n: int, batch: int | None, cfg: PIMConfig,
                      spec: aritpim.FloatSpec, *, mesh=None) -> dict:
    """Schedule a batch of B n-point FFTs onto the crossbar arrays (and,
    when ``mesh`` is given, across its (pod, data) axes first) via
    ``repro.dist.batching``; report waves, per-array utilization, end-to-end
    latency and achieved throughput.

    At ``batch == num_arrays`` (one full wave) the achieved throughput
    equals ``fft_throughput_per_s`` — the paper's §6 steady-state; smaller
    or non-dividing batches surface the idle-array cost instead of silently
    assuming perfect packing.
    """
    from repro.dist import batching
    word = aritpim.complex_word_bits(spec)
    num_arrays = max(1, int(cfg.batch_capacity(n, word) * cfg.concurrency))
    if batch is None:        # one full wave everywhere: the steady state
        n_dev = (batching.shard_batch(0, mesh).n_devices
                 if mesh is not None else 1)
        batch = num_arrays * n_dev
    plan = batching.plan_crossbar_batch(batch, num_arrays=num_arrays,
                                        mesh=mesh)
    wave_latency_s = fft_latency_cycles(n, cfg, spec) / cfg.clock_hz
    return {
        **plan.report(),
        "n": n,
        "wave_latency_s": wave_latency_s,
        "latency_s": plan.latency(wave_latency_s),
        "throughput_per_s": plan.throughput(wave_latency_s),
    }


def fft_energy_j_per_op(n: int, cfg: PIMConfig, spec: aritpim.FloatSpec
                        ) -> float:
    """Energy per FFT: gate executions dominate; derived from the simulator
    counter structure (gates ~= cycles * active rows for column ops)."""
    x = np.random.default_rng(0).standard_normal(n).astype(np.complex128)
    res = pim_fft(x, cfg, spec)
    return res.counters.energy_j(cfg)
