"""In-memory NUMBER-THEORETIC transform on the crossbar simulator.

The exact counterpart of ``fft_pim.py``: the same r / 2r / 2r-beta layout
algebra (paper §4.3-4.5 — the configurations describe data MOVEMENT, which
is domain-independent), with the complex floating-point butterfly replaced
by a fixed-point modular one costed per AritPIM's integer sequences
(``aritpim.IntSpec``; NTT-PIM [arXiv:2310.09715] maps the identical
structure). Values are tracked exactly in uint64 residues and verified
against ``core.ntt.ref``; cycle/gate counters accumulate per vectored op,
and the closed forms below are asserted equal to the simulator's counters
in tests/test_pim_ntt.py — the same parity contract tests/test_pim.py
enforces for the float FFT.

Differences from the float pipeline, all from the arithmetic domain:

  * butterfly: 1 Barrett modmul + 2 modadds on w-bit words (no IEEE
    special-case overhead), vs 4 fmul + 6 fadd on 2x(1+e+m) bits;
  * inverse scaling: 1/n is a genuine modmul by n^{-1} mod q, not an
    exponent decrement (there is no exponent);
  * negacyclic twist (RLWE, mod x^n + 1): one column-parallel modmul per
    operand before the forward transforms and one after the inverse, with
    the 1/n fold-in — the §5 permutation-cancellation analogue is charged
    the same way (DIT/DIF pairing cancels the bit-reversals, so polymul
    transforms skip the permutation cost).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.ntt.ref import NTTParams, as_residues
from repro.core.pim import aritpim
from repro.core.pim.crossbar import Counters, CrossbarSim
from repro.core.pim.device_model import PIMConfig
from repro.core.pim.fft_pim import _bit_reverse_perm, _perm_swap_count


@dataclasses.dataclass(frozen=True)
class PIMNTTResult:
    output: np.ndarray
    counters: Counters
    #: ordered (tag, cycles) charge records (see CrossbarSim.log).
    log: tuple = ()


_residues = as_residues    # same contract as the reference: floats raise


def _ntt_groups(sim: CrossbarSim, x: np.ndarray, params: NTTParams, *,
                inverse: bool, serial_units: int, active_rows: int,
                transition_fn) -> np.ndarray:
    """Shared group loop: iterative DIT modular butterflies after bit
    reversal — structurally identical to ``fft_pim._fft_groups``."""
    n = params.n
    q = np.uint64(params.q)
    y = _residues(x, params.q)[_bit_reverse_perm(n)]
    pw = params.powers(params.w_inv if inverse else params.w)
    for s in range(n.bit_length() - 1):
        m = 2 << s            # butterfly span
        half = m >> 1
        idx = np.arange(n).reshape(n // m, m)
        top = idx[:, :half].ravel()
        bot = idx[:, half:].ravel()
        w = np.tile(pw[(n // m) * np.arange(half)], n // m)
        sim.charge_twiddle_writes(sim.cfg.crossbar_rows // 2)
        transition_fn(s)
        u, v = sim.butterfly_rows_mod(y[top], y[bot], w, params.q,
                                      active_rows,
                                      serial_units=serial_units)
        y[top], y[bot] = u, v
    if inverse:
        # 1/n scaling: a real modmul by n^{-1} mod q (no exponent trick).
        sim.charge_column_op("modmul", active_rows)
        y = (y * np.uint64(params.n_inv)) % q
    return y


def r_ntt(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
          spec: aritpim.IntSpec, *, inverse: bool = False,
          charge_perm: bool = True, faults=None,
          array_id: int = 0) -> PIMNTTResult:
    """r-configuration: n = crossbar rows, one residue per row."""
    n = params.n
    assert n == cfg.crossbar_rows, f"r-NTT needs n == rows ({cfg.crossbar_rows})"
    sim = CrossbarSim(cfg, spec, faults=faults, array_id=array_id)
    sim.load(_residues(x, params.q).astype(np.float64))
    if charge_perm:
        sim.charge_row_ops(_perm_swap_count(n), cycles_per_row=6, tag="perm")

    def transition(stage):
        sim.charge_column_op("copy", n // 2)
        sim.charge_row_ops(n // 2, cycles_per_row=2)
        sim.charge_column_op("copy", n // 2)
        sim.charge_row_ops(n // 2, cycles_per_row=2)

    y = _ntt_groups(sim, x, params, inverse=inverse, serial_units=1,
                    active_rows=n // 2, transition_fn=transition)
    return PIMNTTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def ntt_2r(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
           spec: aritpim.IntSpec, *, inverse: bool = False,
           charge_perm: bool = True, faults=None,
           array_id: int = 0) -> PIMNTTResult:
    """2r-configuration: two residues per row (snake), full-row use."""
    n = params.n
    r = cfg.crossbar_rows
    assert n == 2 * r, f"2r-NTT needs n == 2*rows ({2 * r})"
    sim = CrossbarSim(cfg, spec, faults=faults, array_id=array_id)
    sim.load(_residues(x, params.q).astype(np.float64))
    if charge_perm:
        sim.charge_row_ops(_perm_swap_count(n), cycles_per_row=6, tag="perm")

    def transition(stage):
        if stage == 0:
            return
        sim.charge_column_op("swap", r)
        sim.charge_row_ops(r // 2, cycles_per_row=6)

    y = _ntt_groups(sim, x, params, inverse=inverse, serial_units=1,
                    active_rows=r, transition_fn=transition)
    return PIMNTTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def ntt_2rbeta(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
               spec: aritpim.IntSpec, *, inverse: bool = False,
               charge_perm: bool = True, faults=None,
               array_id: int = 0) -> PIMNTTResult:
    """2r-beta configuration: 2*beta residues per row across beta
    column-units; butterflies serial over units, ceil(beta/p) with
    partitions."""
    n = params.n
    r = cfg.crossbar_rows
    beta = n // (2 * r)
    assert n == 2 * r * beta and beta >= 1, f"n={n} not a 2r*beta multiple"
    word = spec.word_bits
    assert 2 * beta * word <= cfg.crossbar_cols, \
        f"n={n} exceeds crossbar width"
    sim = CrossbarSim(cfg, spec, faults=faults, array_id=array_id)
    serial = math.ceil(beta / cfg.partitions)
    if charge_perm:
        # Charged BEFORE the group loop, same placement as r/2r (the
        # fft_2rbeta ordering fix rides the same contract).
        sim.charge_row_ops(_perm_swap_count(min(n, 2 * r)), cycles_per_row=6,
                           tag="perm")

    def transition(stage):
        if stage == 0:
            return
        sim.charge_column_op("swap", r)
        sim.charge_row_ops(r // 2, cycles_per_row=6)
        if stage >= int(math.log2(2 * r)):
            sim.charge_column_op("copy", r,
                                 serial=math.ceil(beta / cfg.partitions))

    y = _ntt_groups(sim, x, params, inverse=inverse, serial_units=serial,
                    active_rows=r, transition_fn=transition)
    return PIMNTTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def pim_ntt(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
            spec: aritpim.IntSpec, *, inverse: bool = False,
            charge_perm: bool = True, faults=None,
            array_id: int = 0) -> PIMNTTResult:
    """Dispatch to the layout for this n, mirroring ``fft_pim.pim_fft``."""
    if params.n == cfg.crossbar_rows:
        return r_ntt(x, params, cfg, spec, inverse=inverse,
                     charge_perm=charge_perm, faults=faults,
                     array_id=array_id)
    return ntt_2rbeta(x, params, cfg, spec, inverse=inverse,
                      charge_perm=charge_perm, faults=faults,
                      array_id=array_id)


def pim_ntt_polymul(a: np.ndarray, b: np.ndarray, params: NTTParams,
                    cfg: PIMConfig, spec: aritpim.IntSpec, *,
                    negacyclic: bool = True, faults=None,
                    array_id: int = 0) -> PIMNTTResult:
    """Exact polynomial product mod (x^n ± 1, q) on the simulator.

    Negacyclic: psi-twist both operands (2 modmuls), transform without the
    cancelled permutations, pointwise modmul, inverse transform, untwist
    (1 modmul, the 1/n already charged by the inverse path)."""
    n = params.n
    q = np.uint64(params.q)
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    sim = CrossbarSim(cfg, spec)
    at = _residues(a, params.q)
    bt = _residues(b, params.q)
    if negacyclic:
        psi_pow = params.powers(params.psi)
        at = (at * psi_pow) % q
        bt = (bt * psi_pow) % q
        sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
        sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
    fa = pim_ntt(at, params, cfg, spec, charge_perm=False,
                 faults=faults, array_id=array_id)
    fb = pim_ntt(bt, params, cfg, spec, charge_perm=False,
                 faults=faults, array_id=array_id)
    prod = (fa.output * fb.output) % q
    sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
    inv = pim_ntt(prod, params, cfg, spec, inverse=True, charge_perm=False,
                  faults=faults, array_id=array_id)
    out = inv.output
    if negacyclic:
        out = (out * params.powers(params.psi_inv)) % q
        sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
    ctr = Counters(
        cycles=fa.counters.cycles + fb.counters.cycles + inv.counters.cycles
        + sim.ctr.cycles,
        gates=fa.counters.gates + fb.counters.gates + inv.counters.gates
        + sim.ctr.gates)
    # Concatenated ledger (transforms, then the pointwise/twist charges):
    # fault entries from the sub-transforms survive into the composite
    # result, so callers can audit which array misbehaved.
    return PIMNTTResult(output=out, counters=ctr,
                        log=tuple(fa.log) + tuple(fb.log) + tuple(inv.log)
                        + tuple(sim.log))


# ---------------------------------------------------------------------------
# Closed forms (asserted == simulator in tests/test_pim_ntt.py)
# ---------------------------------------------------------------------------

def ntt_latency_cycles(n: int, cfg: PIMConfig, spec: aritpim.IntSpec,
                       *, charge_perm: bool = True,
                       inverse: bool = False) -> int:
    r = cfg.crossbar_rows
    beta = max(1, n // (2 * r))
    stages = n.bit_length() - 1
    bfly = aritpim.ntt_butterfly_cycles(spec)
    word = spec.word_bits
    serial = math.ceil(beta / cfg.partitions)
    total = 0
    if charge_perm:
        total += _perm_swap_count(min(n, 2 * r)) * 6
    for s in range(stages):
        total += r // 2                     # twiddle writes
        total += bfly * serial              # butterflies
        if n == r:                          # r-config moves
            total += 2 * aritpim.copy_cycles(word) + 2 * (n // 2) * 2
        elif s > 0:                         # 2r / 2rb transitions
            total += aritpim.swap_cycles(word) + (r // 2) * 6
            if n > 2 * r and s >= int(math.log2(2 * r)):
                total += aritpim.copy_cycles(word) * serial
    if inverse:
        total += aritpim.mod_mul_cycles(spec)   # 1/n modmul pass
    return total


def ntt_polymul_latency_cycles(n: int, cfg: PIMConfig,
                               spec: aritpim.IntSpec, *,
                               negacyclic: bool = True) -> int:
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    fwd = ntt_latency_cycles(n, cfg, spec, charge_perm=False)
    inv = ntt_latency_cycles(n, cfg, spec, charge_perm=False, inverse=True)
    pointwise = aritpim.mod_mul_cycles(spec) * serial
    twists = 3 * aritpim.mod_mul_cycles(spec) * serial if negacyclic else 0
    return 2 * fwd + inv + pointwise + twists


def ntt_throughput_per_s(n: int, cfg: PIMConfig, spec: aritpim.IntSpec
                         ) -> float:
    """Batched throughput: one NTT per crossbar, all arrays in parallel.
    A w-bit residue word is half the complex float word, so per-array
    capacity roughly doubles vs the float FFT at equal n."""
    lat = ntt_latency_cycles(n, cfg, spec) / cfg.clock_hz
    return cfg.batch_capacity(n, spec.word_bits) * cfg.concurrency / lat


def _arrays_per_device(n: int, cfg: PIMConfig,
                       spec: aritpim.IntSpec) -> int:
    """Concurrent n-point modular transforms one device can run: memory
    capacity discounted by controller issue concurrency. The one definition
    every NTT wave plan uses (batched stats, RNS limb scheduling) — the
    sim-side plans and the closed-form stats must not each re-derive it."""
    return max(1, int(cfg.batch_capacity(n, spec.word_bits)
                      * cfg.concurrency))


def batched_ntt_stats(n: int, batch: int | None, cfg: PIMConfig,
                      spec: aritpim.IntSpec, *, mesh=None) -> dict:
    """Schedule a batch of B n-point NTTs through the same
    ``repro.dist.batching`` wave scheduler as ``batched_fft_stats``."""
    from repro.dist import batching
    num_arrays = _arrays_per_device(n, cfg, spec)
    if batch is None:        # one full wave everywhere: the steady state
        n_dev = (batching.shard_batch(0, mesh).n_devices
                 if mesh is not None else 1)
        batch = num_arrays * n_dev
    plan = batching.plan_crossbar_batch(batch, num_arrays=num_arrays,
                                        mesh=mesh)
    wave_latency_s = ntt_latency_cycles(n, cfg, spec) / cfg.clock_hz
    return {
        **plan.report(),
        "n": n,
        "wave_latency_s": wave_latency_s,
        "latency_s": plan.latency(wave_latency_s),
        "throughput_per_s": plan.throughput(wave_latency_s),
    }


def ntt_energy_j_per_op(n: int, cfg: PIMConfig, spec: aritpim.IntSpec,
                        *, q: int | None = None) -> float:
    params = NTTParams.make(n, q)
    x = np.random.default_rng(0).integers(0, params.q, size=n)
    res = pim_ntt(x, params, cfg, spec)
    return res.counters.energy_j(cfg)


# ---------------------------------------------------------------------------
# RNS: k-limb polymul, limbs scheduled as waves over the crossbar pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PIMRNSResult:
    """Per-limb products + CRT result + summed cost counters + limb plan."""
    outputs: np.ndarray          # (k, n) uint64 per-limb residue products
    result: np.ndarray           # (n,) object: exact coefficients mod Q
    counters: Counters           # total work: sum over limbs
    plan: object                 # CrossbarBatchPlan of limbs onto arrays


def pim_rns_polymul(a, b, rns, cfg: PIMConfig, spec: aritpim.IntSpec, *,
                    negacyclic: bool = True, mesh=None,
                    faults=None) -> PIMRNSResult:
    """Multi-limb exact polymul mod Q on the simulator: each limb is one
    independent single-word ``pim_ntt_polymul`` (limbs are embarrassingly
    parallel — one limb per crossbar), scheduled as waves through
    ``dist.batching`` like any other transform batch. Counters are the SUM
    of the per-limb simulators (total work); wave latency comes from the
    plan (``rns_polymul_wave_stats`` is the closed form)."""
    from repro.core.ntt.rns import crt_to_modulus, to_rns
    ar = to_rns(a, rns)
    br = to_rns(b, rns)
    outs = np.empty((rns.k, rns.n), np.uint64)
    cycles = gates = 0
    for i, params in enumerate(rns.limbs):
        # Limb i runs on (logical) array i — the wave schedule's placement
        # — so a fault model can hit individual limbs deterministically.
        res = pim_ntt_polymul(ar[i], br[i], params, cfg, spec,
                              negacyclic=negacyclic, faults=faults,
                              array_id=i)
        outs[i] = res.output
        cycles += res.counters.cycles
        gates += res.counters.gates
    stats = rns_polymul_wave_stats(rns.n, rns.k, cfg, spec,
                                   negacyclic=negacyclic, mesh=mesh)
    return PIMRNSResult(outputs=outs, result=crt_to_modulus(outs, rns),
                        counters=Counters(cycles=cycles, gates=gates),
                        plan=stats["plan"])


def rns_polymul_latency_cycles(n: int, k: int, cfg: PIMConfig,
                               spec: aritpim.IntSpec, *,
                               negacyclic: bool = True) -> int:
    """Total simulator cycles of a k-limb RNS polymul: exactly k times the
    single-word fused polymul (asserted == summed counters in tests)."""
    return k * ntt_polymul_latency_cycles(n, cfg, spec,
                                          negacyclic=negacyclic)


def rns_polymul_wave_stats(n: int, k: int, cfg: PIMConfig,
                           spec: aritpim.IntSpec, *, negacyclic: bool = True,
                           mesh=None) -> dict:
    """Wall-clock view of the limb schedule: k limbs over the crossbar pool
    run in ``waves`` wavefronts of one fused polymul each."""
    from repro.dist import batching
    plan = batching.plan_crossbar_batch(
        k, num_arrays=_arrays_per_device(n, cfg, spec), mesh=mesh)
    wave_latency_s = (ntt_polymul_latency_cycles(n, cfg, spec,
                                                 negacyclic=negacyclic)
                      / cfg.clock_hz)
    return {
        **plan.report(),
        "plan": plan,
        "n": n,
        "limbs": k,
        "wave_latency_s": wave_latency_s,
        "latency_s": plan.latency(wave_latency_s),
        "throughput_per_s": plan.throughput(wave_latency_s),
        "total_cycles": rns_polymul_latency_cycles(n, k, cfg, spec,
                                                   negacyclic=negacyclic),
    }


# ---------------------------------------------------------------------------
# Distributed four-step NTT (n = n1 * n2 over D crossbar shards)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PIMDistNTTResult:
    """Four-step NTT across D shards: values + per-shard counters + bytes."""
    output: np.ndarray                 # (n,) uint64, natural order
    shard_counters: tuple              # one Counters per shard (all equal)
    a2a_bytes: int                     # inter-array transpose traffic, total
    logs: tuple = ()                   # per-shard (tag, cycles) charge logs

    @property
    def latency_cycles(self) -> int:
        """Shards run in parallel: latency is one shard's cycles (symmetric
        by construction, asserted in tests)."""
        return max(c.cycles for c in self.shard_counters)


def _phase_a_ntt(sim: CrossbarSim, block: np.ndarray, p1: NTTParams,
                 active_rows: int) -> np.ndarray:
    """Step-2 column transforms: NTT_{n1} along axis 0 of the (n1, n2/D)
    shard block, every column in parallel (one vectored butterfly per
    stage). Charged exactly like an r-layout stage: twiddle writes + two
    copy/row-move pairs + one butterfly op. The bit-reversal of the n1
    rows is NOT charged — the step-1 transpose delivery order absorbs it
    (the inter-array move places rows wherever the algorithm wants)."""
    q = np.uint64(p1.q)
    n1 = p1.n
    y = block[_bit_reverse_perm(n1)].copy()
    pw = p1.powers(p1.w)
    for s in range(n1.bit_length() - 1):
        m = 2 << s
        half = m >> 1
        idx = np.arange(n1).reshape(n1 // m, m)
        top = idx[:, :half].ravel()
        bot = idx[:, half:].ravel()
        w = np.tile(pw[(n1 // m) * np.arange(half)], n1 // m)[:, None]
        sim.charge_twiddle_writes(active_rows)
        sim.charge_column_op("copy", active_rows)
        sim.charge_row_ops(active_rows, cycles_per_row=2)
        sim.charge_column_op("copy", active_rows)
        sim.charge_row_ops(active_rows, cycles_per_row=2)
        u, v = sim.butterfly_rows_mod(y[top], y[bot], w, p1.q, active_rows)
        y[top], y[bot] = u, v
    assert (y < q).all()
    return y


def pim_ntt_distributed(x: np.ndarray, params: NTTParams, n_shards: int,
                        cfg: PIMConfig, spec: aritpim.IntSpec
                        ) -> PIMDistNTTResult:
    """Four-step NTT across ``n_shards`` crossbar arrays, value-exact.

    n = n1 * n2 with n1 = D shards and n2 = n / D = crossbar rows (each
    shard's working set is exactly one column of the array). Per-shard
    roots come from ``NTTParams.subparams``; the two inter-array transposes
    are periphery moves charged as BYTES (``a2a_bytes``, the ledger unit of
    the TPU path), not cycles. Matches ``ref.ntt`` exactly and the closed
    forms ``ntt_distributed_latency_cycles`` / ``ntt_distributed_a2a_bytes``
    (tests/test_pim_ntt.py).
    """
    n = params.n
    D = n_shards
    if D < 2 or D & (D - 1):
        raise ValueError(f"n_shards={D} must be a power of two >= 2")
    n2 = n // D
    assert n2 == cfg.crossbar_rows, \
        f"four-step PIM wants n/D == rows ({cfg.crossbar_rows}), got {n2}"
    p1 = params.subparams(D)
    p2 = params.subparams(n2)
    q = np.uint64(params.q)
    sims = [CrossbarSim(cfg, spec) for _ in range(D)]
    M = _residues(x, params.q).reshape(D, n2)          # row j1
    wcol = n2 // D
    # Step 1 transpose: shard s owns all j1 for j2 slice s.
    blocks = [M[:, s * wcol:(s + 1) * wcol].copy() for s in range(D)]
    pw = params.powers(params.w)
    for s, sim in enumerate(sims):
        y = _phase_a_ntt(sim, blocks[s], p1, active_rows=n2 // 2)
        # Step 3: twiddle w^{j2 k1} with GLOBAL j2 — one column-parallel
        # modmul over the shard's full working set.
        j2 = np.arange(s * wcol, (s + 1) * wcol)
        k1 = np.arange(D)[:, None]
        tw = pw[(k1 * j2[None, :]) % n]
        blocks[s] = (y * tw) % q
        sim.charge_column_op("modmul", cfg.crossbar_rows)
    # Step 4 transpose: shard s owns row k1 = s, all j2.
    Y = np.concatenate(blocks, axis=1)                 # (D=k1, n2=j2)
    Z = np.empty((D, n2), np.uint64)
    for s, sim in enumerate(sims):
        def transition(stage):
            sim.charge_column_op("copy", n2 // 2)
            sim.charge_row_ops(n2 // 2, cycles_per_row=2)
            sim.charge_column_op("copy", n2 // 2)
            sim.charge_row_ops(n2 // 2, cycles_per_row=2)
        sim.charge_row_ops(_perm_swap_count(n2), cycles_per_row=6,
                           tag="perm")
        Z[s] = _ntt_groups(sim, Y[s], p2, inverse=False, serial_units=1,
                           active_rows=n2 // 2, transition_fn=transition)
    # X[k1 + k2 n1] = Z[k1, k2]: natural-order assembly (host-side view).
    out = Z.T.reshape(n)
    return PIMDistNTTResult(
        output=out,
        shard_counters=tuple(s.ctr for s in sims),
        a2a_bytes=ntt_distributed_a2a_bytes(n, D, spec),
        logs=tuple(tuple(s.log) for s in sims))


def ntt_distributed_latency_cycles(n: int, n_shards: int, cfg: PIMConfig,
                                   spec: aritpim.IntSpec) -> int:
    """Closed-form per-shard cycles of the four-step NTT (== every shard's
    simulator counter): log2(D) r-layout stages for the column transforms,
    one twiddle modmul, then a full r-layout NTT of length n/D."""
    D = n_shards
    n2 = n // D
    r = cfg.crossbar_rows
    assert n2 == r, (n, D, r)
    word = spec.word_bits
    stage_a = (r // 2                                  # twiddle writes
               + 2 * aritpim.copy_cycles(word) + 2 * (r // 2) * 2
               + aritpim.ntt_butterfly_cycles(spec))
    phase_a = (D.bit_length() - 1) * stage_a
    twiddle = aritpim.mod_mul_cycles(spec)
    phase_b = ntt_latency_cycles(n2, cfg, spec, charge_perm=True)
    return phase_a + twiddle + phase_b


def ntt_distributed_a2a_bytes(n: int, n_shards: int,
                              spec: aritpim.IntSpec) -> int:
    """Inter-array transpose traffic of the four-step NTT: two all-to-all
    transposes, each moving every residue word once (same accounting unit
    as ``core.ntt.distributed.four_step_collective_stats``)."""
    del n_shards  # traffic is layout-independent: every word moves twice
    return 2 * n * (aritpim.storage_word_bits(spec) // 8)
