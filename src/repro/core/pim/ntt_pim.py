"""In-memory NUMBER-THEORETIC transform on the crossbar simulator.

The exact counterpart of ``fft_pim.py``: the same r / 2r / 2r-beta layout
algebra (paper §4.3-4.5 — the configurations describe data MOVEMENT, which
is domain-independent), with the complex floating-point butterfly replaced
by a fixed-point modular one costed per AritPIM's integer sequences
(``aritpim.IntSpec``; NTT-PIM [arXiv:2310.09715] maps the identical
structure). Values are tracked exactly in uint64 residues and verified
against ``core.ntt.ref``; cycle/gate counters accumulate per vectored op,
and the closed forms below are asserted equal to the simulator's counters
in tests/test_pim_ntt.py — the same parity contract tests/test_pim.py
enforces for the float FFT.

Differences from the float pipeline, all from the arithmetic domain:

  * butterfly: 1 Barrett modmul + 2 modadds on w-bit words (no IEEE
    special-case overhead), vs 4 fmul + 6 fadd on 2x(1+e+m) bits;
  * inverse scaling: 1/n is a genuine modmul by n^{-1} mod q, not an
    exponent decrement (there is no exponent);
  * negacyclic twist (RLWE, mod x^n + 1): one column-parallel modmul per
    operand before the forward transforms and one after the inverse, with
    the 1/n fold-in — the §5 permutation-cancellation analogue is charged
    the same way (DIT/DIF pairing cancels the bit-reversals, so polymul
    transforms skip the permutation cost).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.ntt.ref import NTTParams, as_residues
from repro.core.pim import aritpim
from repro.core.pim.crossbar import Counters, CrossbarSim
from repro.core.pim.device_model import PIMConfig
from repro.core.pim.fft_pim import _bit_reverse_perm, _perm_swap_count


@dataclasses.dataclass(frozen=True)
class PIMNTTResult:
    output: np.ndarray
    counters: Counters
    #: ordered (tag, cycles) charge records (see CrossbarSim.log).
    log: tuple = ()


_residues = as_residues    # same contract as the reference: floats raise


def _ntt_groups(sim: CrossbarSim, x: np.ndarray, params: NTTParams, *,
                inverse: bool, serial_units: int, active_rows: int,
                transition_fn) -> np.ndarray:
    """Shared group loop: iterative DIT modular butterflies after bit
    reversal — structurally identical to ``fft_pim._fft_groups``."""
    n = params.n
    q = np.uint64(params.q)
    y = _residues(x, params.q)[_bit_reverse_perm(n)]
    pw = params.powers(params.w_inv if inverse else params.w)
    for s in range(n.bit_length() - 1):
        m = 2 << s            # butterfly span
        half = m >> 1
        idx = np.arange(n).reshape(n // m, m)
        top = idx[:, :half].ravel()
        bot = idx[:, half:].ravel()
        w = np.tile(pw[(n // m) * np.arange(half)], n // m)
        sim.charge_twiddle_writes(sim.cfg.crossbar_rows // 2)
        transition_fn(s)
        u, v = sim.butterfly_rows_mod(y[top], y[bot], w, params.q,
                                      active_rows,
                                      serial_units=serial_units)
        y[top], y[bot] = u, v
    if inverse:
        # 1/n scaling: a real modmul by n^{-1} mod q (no exponent trick).
        sim.charge_column_op("modmul", active_rows)
        y = (y * np.uint64(params.n_inv)) % q
    return y


def r_ntt(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
          spec: aritpim.IntSpec, *, inverse: bool = False,
          charge_perm: bool = True) -> PIMNTTResult:
    """r-configuration: n = crossbar rows, one residue per row."""
    n = params.n
    assert n == cfg.crossbar_rows, f"r-NTT needs n == rows ({cfg.crossbar_rows})"
    sim = CrossbarSim(cfg, spec)
    sim.load(_residues(x, params.q).astype(np.float64))
    if charge_perm:
        sim.charge_row_ops(_perm_swap_count(n), cycles_per_row=6, tag="perm")

    def transition(stage):
        sim.charge_column_op("copy", n // 2)
        sim.charge_row_ops(n // 2, cycles_per_row=2)
        sim.charge_column_op("copy", n // 2)
        sim.charge_row_ops(n // 2, cycles_per_row=2)

    y = _ntt_groups(sim, x, params, inverse=inverse, serial_units=1,
                    active_rows=n // 2, transition_fn=transition)
    return PIMNTTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def ntt_2r(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
           spec: aritpim.IntSpec, *, inverse: bool = False,
           charge_perm: bool = True) -> PIMNTTResult:
    """2r-configuration: two residues per row (snake), full-row use."""
    n = params.n
    r = cfg.crossbar_rows
    assert n == 2 * r, f"2r-NTT needs n == 2*rows ({2 * r})"
    sim = CrossbarSim(cfg, spec)
    sim.load(_residues(x, params.q).astype(np.float64))
    if charge_perm:
        sim.charge_row_ops(_perm_swap_count(n), cycles_per_row=6, tag="perm")

    def transition(stage):
        if stage == 0:
            return
        sim.charge_column_op("swap", r)
        sim.charge_row_ops(r // 2, cycles_per_row=6)

    y = _ntt_groups(sim, x, params, inverse=inverse, serial_units=1,
                    active_rows=r, transition_fn=transition)
    return PIMNTTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def ntt_2rbeta(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
               spec: aritpim.IntSpec, *, inverse: bool = False,
               charge_perm: bool = True) -> PIMNTTResult:
    """2r-beta configuration: 2*beta residues per row across beta
    column-units; butterflies serial over units, ceil(beta/p) with
    partitions."""
    n = params.n
    r = cfg.crossbar_rows
    beta = n // (2 * r)
    assert n == 2 * r * beta and beta >= 1, f"n={n} not a 2r*beta multiple"
    word = spec.word_bits
    assert 2 * beta * word <= cfg.crossbar_cols, \
        f"n={n} exceeds crossbar width"
    sim = CrossbarSim(cfg, spec)
    serial = math.ceil(beta / cfg.partitions)
    if charge_perm:
        # Charged BEFORE the group loop, same placement as r/2r (the
        # fft_2rbeta ordering fix rides the same contract).
        sim.charge_row_ops(_perm_swap_count(min(n, 2 * r)), cycles_per_row=6,
                           tag="perm")

    def transition(stage):
        if stage == 0:
            return
        sim.charge_column_op("swap", r)
        sim.charge_row_ops(r // 2, cycles_per_row=6)
        if stage >= int(math.log2(2 * r)):
            sim.charge_column_op("copy", r,
                                 serial=math.ceil(beta / cfg.partitions))

    y = _ntt_groups(sim, x, params, inverse=inverse, serial_units=serial,
                    active_rows=r, transition_fn=transition)
    return PIMNTTResult(output=y, counters=sim.ctr, log=tuple(sim.log))


def pim_ntt(x: np.ndarray, params: NTTParams, cfg: PIMConfig,
            spec: aritpim.IntSpec, *, inverse: bool = False,
            charge_perm: bool = True) -> PIMNTTResult:
    """Dispatch to the layout for this n, mirroring ``fft_pim.pim_fft``."""
    if params.n == cfg.crossbar_rows:
        return r_ntt(x, params, cfg, spec, inverse=inverse,
                     charge_perm=charge_perm)
    return ntt_2rbeta(x, params, cfg, spec, inverse=inverse,
                      charge_perm=charge_perm)


def pim_ntt_polymul(a: np.ndarray, b: np.ndarray, params: NTTParams,
                    cfg: PIMConfig, spec: aritpim.IntSpec, *,
                    negacyclic: bool = True) -> PIMNTTResult:
    """Exact polynomial product mod (x^n ± 1, q) on the simulator.

    Negacyclic: psi-twist both operands (2 modmuls), transform without the
    cancelled permutations, pointwise modmul, inverse transform, untwist
    (1 modmul, the 1/n already charged by the inverse path)."""
    n = params.n
    q = np.uint64(params.q)
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    sim = CrossbarSim(cfg, spec)
    at = _residues(a, params.q)
    bt = _residues(b, params.q)
    if negacyclic:
        psi_pow = params.powers(params.psi)
        at = (at * psi_pow) % q
        bt = (bt * psi_pow) % q
        sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
        sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
    fa = pim_ntt(at, params, cfg, spec, charge_perm=False)
    fb = pim_ntt(bt, params, cfg, spec, charge_perm=False)
    prod = (fa.output * fb.output) % q
    sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
    inv = pim_ntt(prod, params, cfg, spec, inverse=True, charge_perm=False)
    out = inv.output
    if negacyclic:
        out = (out * params.powers(params.psi_inv)) % q
        sim.charge_column_op("modmul", cfg.crossbar_rows, serial=serial)
    ctr = Counters(
        cycles=fa.counters.cycles + fb.counters.cycles + inv.counters.cycles
        + sim.ctr.cycles,
        gates=fa.counters.gates + fb.counters.gates + inv.counters.gates
        + sim.ctr.gates)
    return PIMNTTResult(output=out, counters=ctr)


# ---------------------------------------------------------------------------
# Closed forms (asserted == simulator in tests/test_pim_ntt.py)
# ---------------------------------------------------------------------------

def ntt_latency_cycles(n: int, cfg: PIMConfig, spec: aritpim.IntSpec,
                       *, charge_perm: bool = True,
                       inverse: bool = False) -> int:
    r = cfg.crossbar_rows
    beta = max(1, n // (2 * r))
    stages = n.bit_length() - 1
    bfly = aritpim.ntt_butterfly_cycles(spec)
    word = spec.word_bits
    serial = math.ceil(beta / cfg.partitions)
    total = 0
    if charge_perm:
        total += _perm_swap_count(min(n, 2 * r)) * 6
    for s in range(stages):
        total += r // 2                     # twiddle writes
        total += bfly * serial              # butterflies
        if n == r:                          # r-config moves
            total += 2 * aritpim.copy_cycles(word) + 2 * (n // 2) * 2
        elif s > 0:                         # 2r / 2rb transitions
            total += aritpim.swap_cycles(word) + (r // 2) * 6
            if n > 2 * r and s >= int(math.log2(2 * r)):
                total += aritpim.copy_cycles(word) * serial
    if inverse:
        total += aritpim.mod_mul_cycles(spec)   # 1/n modmul pass
    return total


def ntt_polymul_latency_cycles(n: int, cfg: PIMConfig,
                               spec: aritpim.IntSpec, *,
                               negacyclic: bool = True) -> int:
    beta = max(1, n // (2 * cfg.crossbar_rows))
    serial = math.ceil(beta / cfg.partitions)
    fwd = ntt_latency_cycles(n, cfg, spec, charge_perm=False)
    inv = ntt_latency_cycles(n, cfg, spec, charge_perm=False, inverse=True)
    pointwise = aritpim.mod_mul_cycles(spec) * serial
    twists = 3 * aritpim.mod_mul_cycles(spec) * serial if negacyclic else 0
    return 2 * fwd + inv + pointwise + twists


def ntt_throughput_per_s(n: int, cfg: PIMConfig, spec: aritpim.IntSpec
                         ) -> float:
    """Batched throughput: one NTT per crossbar, all arrays in parallel.
    A w-bit residue word is half the complex float word, so per-array
    capacity roughly doubles vs the float FFT at equal n."""
    lat = ntt_latency_cycles(n, cfg, spec) / cfg.clock_hz
    return cfg.batch_capacity(n, spec.word_bits) * cfg.concurrency / lat


def batched_ntt_stats(n: int, batch: int | None, cfg: PIMConfig,
                      spec: aritpim.IntSpec, *, mesh=None) -> dict:
    """Schedule a batch of B n-point NTTs through the same
    ``repro.dist.batching`` wave scheduler as ``batched_fft_stats``."""
    from repro.dist import batching
    num_arrays = max(1, int(cfg.batch_capacity(n, spec.word_bits)
                            * cfg.concurrency))
    if batch is None:        # one full wave everywhere: the steady state
        n_dev = (batching.shard_batch(0, mesh).n_devices
                 if mesh is not None else 1)
        batch = num_arrays * n_dev
    plan = batching.plan_crossbar_batch(batch, num_arrays=num_arrays,
                                        mesh=mesh)
    wave_latency_s = ntt_latency_cycles(n, cfg, spec) / cfg.clock_hz
    return {
        **plan.report(),
        "n": n,
        "wave_latency_s": wave_latency_s,
        "latency_s": plan.latency(wave_latency_s),
        "throughput_per_s": plan.throughput(wave_latency_s),
    }


def ntt_energy_j_per_op(n: int, cfg: PIMConfig, spec: aritpim.IntSpec,
                        *, q: int | None = None) -> float:
    params = NTTParams.make(n, q)
    x = np.random.default_rng(0).integers(0, params.q, size=n)
    res = pim_ntt(x, params, cfg, spec)
    return res.counters.energy_j(cfg)
