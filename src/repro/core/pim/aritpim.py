"""AritPIM-style cost model: cycles & gate counts for element-parallel
bit-serial arithmetic on a memristive crossbar (paper §2.2, [12]).

The paper adopts AritPIM's algorithms verbatim; we reproduce their cost
structure as closed forms in the bit widths. One stateful logic gate (MAGIC
NOR) executes per cycle per row, in parallel across all rows of a crossbar
(and across all crossbars), so

    latency_cycles  = gate sequence length          (per vectored op)
    gates_executed  = cycles * active_rows          (per crossbar)
    energy          = gates_executed * gate_energy

Gate-sequence lengths (documented derivations; constants are the knobs the
reproduction calibrates, see EXPERIMENTS.md §Repro-calibration):

  fixed add (N bits)      9N + 1        MAGIC full-adder: 9 NOR/bit, serial carry
  fixed mul (N bits)      12N^2 + 3N    shift-and-add: N partial products,
                                        each AND row (3 gates/bit) + add
  copy (N bits)           2N            double-NOT per bit
  swap (N bits)           3N            three NOT-copies via a temp column
  float add (E, M)        2*barrel + 9(M+4) + 9E + 9M + 2M
                          barrel = 3 (M+2) ceil(log2 (M+2))   (align + renorm)
  float mul (E, M)        12 (M+1)^2 + 9E + 3M + 9M           (mantissa product
                          dominates; exponent add, normalize, round)

Complex arithmetic (paper §4.1, rectangular form):
  cadd = 2 float adds;  cmul = 4 float muls + 2 float adds (Eq. (8)).
Butterfly (paper §4.2): u +- w v = 1 cmul + 2 cadd = 4 fmul + 6 fadd.

Modular (fixed-point) arithmetic — the exact-NTT counterpart (NTT-PIM
[arXiv:2310.09715] maps the same butterfly structure onto integer residues):
  mod add   a+b mod q:   fixed add + compare-subtract-q select
                         = 2 (9N+1) + 2
  mod mul   a*b mod q:   Barrett reduction on the 2N-bit product:
                         t = a*b, qhat = (t * mu) >> 2N, r = t - qhat*q,
                         then <=2 conditional subtracts
                         = 3 fixed muls + 2 fixed adds + 4 select cycles
  NTT butterfly (u, v) -> (u + w v, u - w v) mod q = 1 mod mul + 2 mod adds
— the same shape as the complex butterfly with fmul/fadd swapped for their
integer versions and no FLOAT_FIXED_OVERHEAD (no IEEE special cases).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class FloatSpec:
    """IEEE-style float layout used for each real component."""
    exp_bits: int
    man_bits: int

    @property
    def total_bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits


FP32 = FloatSpec(exp_bits=8, man_bits=23)
FP16 = FloatSpec(exp_bits=5, man_bits=10)

#: paper §6: full precision complex = 2 x fp32, half = 2 x fp16
SPEC_BY_PRECISION = {"full": FP32, "half": FP16}


@dataclasses.dataclass(frozen=True)
class IntSpec:
    """Unsigned fixed-point residue layout for the modular NTT: one
    ``word_bits``-wide residue in [0, q) per element (q < 2^(word_bits-1)
    so the conditional-subtract trick needs no extra carry column)."""
    word_bits: int


INT32 = IntSpec(word_bits=32)
INT16 = IntSpec(word_bits=16)

#: modular-NTT words: 32-bit residues carry the ~30-bit RLWE moduli the
#: kernels target; 16-bit serves toy/teaching moduli.
INT_SPEC_BY_WIDTH = {32: INT32, 16: INT16}


def fixed_add_cycles(n_bits: int) -> int:
    return 9 * n_bits + 1


def fixed_mul_cycles(n_bits: int) -> int:
    return 12 * n_bits * n_bits + 3 * n_bits


def copy_cycles(n_bits: int) -> int:
    return 2 * n_bits


def swap_cycles(n_bits: int) -> int:
    return 3 * n_bits


def _barrel_shift_cycles(m: int) -> int:
    return 3 * m * max(1, math.ceil(math.log2(max(2, m))))


#: Width-independent gate-sequence overhead per float op: IEEE special-case
#: handling (NaN/inf/subnormal/zero detection, sign logic, exponent
#: saturation) that AritPIM's sequences carry regardless of mantissa width.
#: This term is why half precision does not speed PIM up by the full
#: quadratic mantissa factor (observable in the paper's half/full ratios).
FLOAT_FIXED_OVERHEAD = 350


def float_add_cycles(spec: FloatSpec) -> int:
    m, e = spec.man_bits, spec.exp_bits
    barrel = _barrel_shift_cycles(m + 2)
    return (2 * barrel            # align + renormalize shifts
            + 9 * (m + 4)         # mantissa add (guard/round/sticky bits)
            + 9 * e               # exponent difference / adjust
            + 9 * m               # rounding add
            + 2 * m               # pack/copy
            + FLOAT_FIXED_OVERHEAD)


def float_mul_cycles(spec: FloatSpec) -> int:
    m, e = spec.man_bits, spec.exp_bits
    return (12 * (m + 1) ** 2     # mantissa partial-product accumulation
            + 9 * e               # exponent add
            + 3 * m               # normalize (1-bit shift + sticky)
            + 9 * m               # rounding add
            + FLOAT_FIXED_OVERHEAD)


def complex_add_cycles(spec: FloatSpec) -> int:
    return 2 * float_add_cycles(spec)


def complex_mul_cycles(spec: FloatSpec) -> int:
    """(a+bi)(a'+b'i) per Eq. (8): 4 real muls + 2 real adds."""
    return 4 * float_mul_cycles(spec) + 2 * float_add_cycles(spec)


def butterfly_cycles(spec: FloatSpec) -> int:
    """In-place vectored butterfly (u, v) -> (u + w v, u - w v), §4.2."""
    return complex_mul_cycles(spec) + 2 * complex_add_cycles(spec)


def complex_word_bits(spec: FloatSpec) -> int:
    return 2 * spec.total_bits


# -- modular fixed-point ops (IntSpec) --------------------------------------

def mod_add_cycles(spec: IntSpec) -> int:
    """a + b mod q: fixed add, then compare/subtract-q select."""
    return 2 * fixed_add_cycles(spec.word_bits) + 2


def mod_mul_cycles(spec: IntSpec) -> int:
    """a * b mod q via Barrett: product + two reduction muls + 2 subtracts
    + select cycles (see module docstring)."""
    w = spec.word_bits
    return 3 * fixed_mul_cycles(w) + 2 * fixed_add_cycles(w) + 4


def ntt_butterfly_cycles(spec: IntSpec) -> int:
    """In-place modular butterfly (u, v) -> (u + w v, u - w v) mod q."""
    return mod_mul_cycles(spec) + 2 * mod_add_cycles(spec)


def storage_word_bits(spec) -> int:
    """Per-element storage on the crossbar: a complex float word for
    FloatSpec, a single residue word for IntSpec."""
    if isinstance(spec, IntSpec):
        return spec.word_bits
    return complex_word_bits(spec)


# Convenience table used by benchmarks / tests. The op names are shared
# between the float-FFT and modular-NTT layers ("butterfly"/"copy"/"swap")
# so the crossbar simulator and the group loops are spec-agnostic.
def op_cycles(op: str, spec) -> int:
    if isinstance(spec, IntSpec):
        return {
            "modadd": mod_add_cycles(spec),
            "modmul": mod_mul_cycles(spec),
            "butterfly": ntt_butterfly_cycles(spec),
            "copy": copy_cycles(storage_word_bits(spec)),
            "swap": swap_cycles(storage_word_bits(spec)),
        }[op]
    return {
        "fadd": float_add_cycles(spec),
        "fmul": float_mul_cycles(spec),
        "cadd": complex_add_cycles(spec),
        "cmul": complex_mul_cycles(spec),
        "butterfly": butterfly_cycles(spec),
        "copy": copy_cycles(complex_word_bits(spec)),
        "swap": swap_cycles(complex_word_bits(spec)),
    }[op]
