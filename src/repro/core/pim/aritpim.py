"""AritPIM-style cost model: cycles & gate counts for element-parallel
bit-serial arithmetic on a memristive crossbar (paper §2.2, [12]).

The paper adopts AritPIM's algorithms verbatim; we reproduce their cost
structure as closed forms in the bit widths. One stateful logic gate (MAGIC
NOR) executes per cycle per row, in parallel across all rows of a crossbar
(and across all crossbars), so

    latency_cycles  = gate sequence length          (per vectored op)
    gates_executed  = cycles * active_rows          (per crossbar)
    energy          = gates_executed * gate_energy

Gate-sequence lengths (documented derivations; constants are the knobs the
reproduction calibrates, see EXPERIMENTS.md §Repro-calibration):

  fixed add (N bits)      9N + 1        MAGIC full-adder: 9 NOR/bit, serial carry
  fixed mul (N bits)      12N^2 + 3N    shift-and-add: N partial products,
                                        each AND row (3 gates/bit) + add
  copy (N bits)           2N            double-NOT per bit
  swap (N bits)           3N            three NOT-copies via a temp column
  float add (E, M)        2*barrel + 9(M+4) + 9E + 9M + 2M
                          barrel = 3 (M+2) ceil(log2 (M+2))   (align + renorm)
  float mul (E, M)        12 (M+1)^2 + 9E + 3M + 9M           (mantissa product
                          dominates; exponent add, normalize, round)

Complex arithmetic (paper §4.1, rectangular form):
  cadd = 2 float adds;  cmul = 4 float muls + 2 float adds (Eq. (8)).
Butterfly (paper §4.2): u +- w v = 1 cmul + 2 cadd = 4 fmul + 6 fadd.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class FloatSpec:
    """IEEE-style float layout used for each real component."""
    exp_bits: int
    man_bits: int

    @property
    def total_bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits


FP32 = FloatSpec(exp_bits=8, man_bits=23)
FP16 = FloatSpec(exp_bits=5, man_bits=10)

#: paper §6: full precision complex = 2 x fp32, half = 2 x fp16
SPEC_BY_PRECISION = {"full": FP32, "half": FP16}


def fixed_add_cycles(n_bits: int) -> int:
    return 9 * n_bits + 1


def fixed_mul_cycles(n_bits: int) -> int:
    return 12 * n_bits * n_bits + 3 * n_bits


def copy_cycles(n_bits: int) -> int:
    return 2 * n_bits


def swap_cycles(n_bits: int) -> int:
    return 3 * n_bits


def _barrel_shift_cycles(m: int) -> int:
    return 3 * m * max(1, math.ceil(math.log2(max(2, m))))


#: Width-independent gate-sequence overhead per float op: IEEE special-case
#: handling (NaN/inf/subnormal/zero detection, sign logic, exponent
#: saturation) that AritPIM's sequences carry regardless of mantissa width.
#: This term is why half precision does not speed PIM up by the full
#: quadratic mantissa factor (observable in the paper's half/full ratios).
FLOAT_FIXED_OVERHEAD = 350


def float_add_cycles(spec: FloatSpec) -> int:
    m, e = spec.man_bits, spec.exp_bits
    barrel = _barrel_shift_cycles(m + 2)
    return (2 * barrel            # align + renormalize shifts
            + 9 * (m + 4)         # mantissa add (guard/round/sticky bits)
            + 9 * e               # exponent difference / adjust
            + 9 * m               # rounding add
            + 2 * m               # pack/copy
            + FLOAT_FIXED_OVERHEAD)


def float_mul_cycles(spec: FloatSpec) -> int:
    m, e = spec.man_bits, spec.exp_bits
    return (12 * (m + 1) ** 2     # mantissa partial-product accumulation
            + 9 * e               # exponent add
            + 3 * m               # normalize (1-bit shift + sticky)
            + 9 * m               # rounding add
            + FLOAT_FIXED_OVERHEAD)


def complex_add_cycles(spec: FloatSpec) -> int:
    return 2 * float_add_cycles(spec)


def complex_mul_cycles(spec: FloatSpec) -> int:
    """(a+bi)(a'+b'i) per Eq. (8): 4 real muls + 2 real adds."""
    return 4 * float_mul_cycles(spec) + 2 * float_add_cycles(spec)


def butterfly_cycles(spec: FloatSpec) -> int:
    """In-place vectored butterfly (u, v) -> (u + w v, u - w v), §4.2."""
    return complex_mul_cycles(spec) + 2 * complex_add_cycles(spec)


def complex_word_bits(spec: FloatSpec) -> int:
    return 2 * spec.total_bits


# Convenience table used by benchmarks / tests.
def op_cycles(op: str, spec: FloatSpec) -> int:
    return {
        "fadd": float_add_cycles(spec),
        "fmul": float_mul_cycles(spec),
        "cadd": complex_add_cycles(spec),
        "cmul": complex_mul_cycles(spec),
        "butterfly": butterfly_cycles(spec),
        "copy": copy_cycles(complex_word_bits(spec)),
        "swap": swap_cycles(complex_word_bits(spec)),
    }[op]
