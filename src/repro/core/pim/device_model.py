"""Device models for the faithful FourierPIM reproduction (paper Table 1).

The paper evaluates on a cycle-accurate simulator parameterized by the RACER
architecture and the Bitlet model; we reproduce those parameters here. GPU
baselines are the two cards the paper measures cuFFT on.

Provenance of constants:
  * crossbar 1024x1024, clock 333.3 MHz, 6.4 fJ/gate, 8/40 GB, <=4
    partitions: paper Table 1 (RACER [5] / Bitlet [22] / PartitionPIM [25]).
  * GPU memory bandwidths / sizes: paper Table 1.
  * GPU board power: vendor TDP (RTX 3070: 220 W, A100-40GB: 400 W (SXM)).
    The paper measured power with nvidia-smi; TDP is the stand-in and the
    achieved-fraction knob below absorbs the difference (see EXPERIMENTS.md
    §Repro-calibration).
  * cuFFT efficiency: cuFFT is memory-bound at these sizes (paper Fig. 1);
    we model achieved bandwidth as a fraction of peak and a number of
    HBM round-trip passes per transform — both recorded explicitly.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class PIMConfig:
    name: str
    memory_bytes: int
    crossbar_rows: int = 1024
    crossbar_cols: int = 1024
    clock_hz: float = 333.3e6
    gate_energy_j: float = 6.4e-15
    partitions: int = 1              # parallel column-units per array (<=4)
    # Working-column model: AritPIM-style bit-serial arithmetic needs scratch
    # columns for carries / partial products / the twiddle constant. We
    # charge `temp_words` N-bit words of scratch per column-unit (shared
    # across the unit's butterfly). See DESIGN.md §PIM-area.
    temp_words: int = 4
    # Controller issue concurrency (Bitlet: the controller's micro-op issue
    # bandwidth bounds how many crossbars execute concurrently). Single
    # calibration constant of the reproduction, fit once so the
    # full-precision FFT throughput ratio vs the RTX 3070 matches the
    # paper's reported 5x (EXPERIMENTS.md §Repro-calibration); everything
    # else (trends, precision scaling, energy, polymul advantage) is left
    # to fall out of the structural model.
    concurrency: float = 0.75

    @property
    def num_crossbars(self) -> int:
        bits = self.crossbar_rows * self.crossbar_cols
        return int(self.memory_bytes * 8 // bits)

    def crossbars_per_fft(self, n: int, word_bits: int) -> float:
        """Fractional crossbar area of one n-point FFT (data + scratch).

        Data: snake layout, 2*beta words per row over r rows (n = 2 r beta);
        scratch: temp_words per active unit (x partitions). The paper's
        footnote 7 (dimension restricted by intermediate memristor area)
        falls out of this accounting: e.g. full-precision n=8K admits at
        most 2 partitions (512 data + 512 scratch columns), and n=16K
        (1024 data columns) spills scratch into a neighbouring array.
        """
        r = self.crossbar_rows
        beta = max(1, n // (2 * r))
        data_cols = 2 * beta * word_bits
        scratch_cols = self.temp_words * word_bits * self.partitions
        return (data_cols + scratch_cols) / self.crossbar_cols

    def valid_config(self, n: int, word_bits: int) -> bool:
        """Data must fit one crossbar's columns (multi-crossbar FFT is the
        paper's future work); scratch may spill to a paired array."""
        r = self.crossbar_rows
        beta = max(1, n // (2 * r))
        return 2 * beta * word_bits <= self.crossbar_cols

    def batch_capacity(self, n: int, word_bits: int) -> int:
        """Batched problems held by the memory. One FFT per crossbar (ops
        within an array are serial), discounted when scratch spills."""
        area = max(1.0, self.crossbars_per_fft(n, word_bits))
        return int(self.num_crossbars / area)


@dataclasses.dataclass(frozen=True)
class GPUConfig:
    name: str
    memory_bytes: int
    mem_bw_bytes: float
    board_power_w: float
    # Achieved fraction of peak bandwidth for cuFFT's streaming passes.
    # Fig. 1 of the paper shows cuFFT pinned to the memory roof; large
    # batched streaming workloads achieve 80-90% of peak.
    bw_efficiency: float = 0.90
    # cuFFT executes transforms that fit a threadblock's shared memory in a
    # single HBM pass (read + write); larger ones use the two-step
    # decomposition (sqrt(n)-sized smem sub-FFTs -> 2 passes, enough for any
    # n up to (smem/bytes)^2). The paper's footnote 8 observes exactly this
    # regime change at n=16K full precision ("a different linear trend").
    smem_bytes: int = 100 * 1024     # RTX 3070 (Ampere consumer): 100 KB/SM

    def fft_passes(self, n: int, word_bytes: int) -> int:
        return 1 if n * word_bytes <= self.smem_bytes else 2


FOURIERPIM_8 = PIMConfig(name="FourierPIM-8", memory_bytes=8 << 30)
FOURIERPIM_40 = PIMConfig(name="FourierPIM-40", memory_bytes=40 << 30)


def with_partitions(cfg: PIMConfig, p: int) -> PIMConfig:
    return dataclasses.replace(cfg, partitions=p,
                               name=f"{cfg.name}-p{p}")


RTX3070 = GPUConfig(name="RTX3070", memory_bytes=8 << 30,
                    mem_bw_bytes=448e9, board_power_w=220.0)
A100 = GPUConfig(name="A100", memory_bytes=40 << 30,
                 mem_bw_bytes=1555e9, board_power_w=400.0,
                 smem_bytes=164 * 1024)   # A100: 164 KB usable smem/SM

# Word widths, paper §6: full precision = 64-bit complex (2 x fp32),
# half precision = 32-bit complex (2 x fp16).
FULL_COMPLEX_BITS = 64
HALF_COMPLEX_BITS = 32
