"""Algorithm-based fault tolerance (ABFT) checks for the Fourier ops.

Every serveable workload gets a CHEAP integrity check — O(n) work against
the O(n log n) transform — that validates a delivered result against its
request payload without recomputing the op:

  fft           Parseval: sum |X_k|^2 == n * sum |x_j|^2.
  rfft          Half-spectrum Parseval: |X_0|^2 + |X_{n/2}|^2
                + 2 * sum_{0<k<n/2} |X_k|^2 == n * sum x_j^2
                (the Hermitian half carries the full energy).
  polymul[-real]  Evaluate-at-one: a circular product satisfies
                r(1) = a(1) * b(1) (the DC identity of the convolution
                theorem), checked as a toleranced residual.
  polymul-mod   Evaluate-at-psi, EXACT: the negacyclic product satisfies
                r(x) = a(x) b(x) mod (x^n + 1, q), and psi (NTTParams.psi,
                psi^n = -1 mod q) is a root of x^n + 1 — so
                r(psi) = a(psi) b(psi) mod q, bit-for-bit. (x = 1 is NOT a
                root of x^n + 1: the cyclic eval-at-one identity does not
                transfer to the negacyclic ring.)
  polymul-mod (RNS)  The same eval-at-psi per PRIME FACTOR p of Q: the
                result rows are already reduced mod Q, so the working-limb
                residues are gone, but r = a b mod (x^n + 1, Q) reduces
                mod every p | Q and each factor has its own 2n-th root
                psi_p. Scheme-style Q (built by ``RNSParams.make(
                modulus_bits=...)``) is a product of the limb primes, so
                the factors are recovered from ``rns.limbs`` directly; a
                modulus that does not factor over its limbs raises
                :class:`ABFTUnsupportedModulus` at bind time, not at
                check time.

Guarantee (docs/fault_tolerance.md): a point check is a homomorphism from
the DELIVERED coefficients — any corruption of a delivered value moves
r(psi) by delta * psi^j != 0 mod q (modular: always detected) or moves the
checked sums (float: detected above the residual tolerance). It is a check
on what the client receives, not a tamper-proof audit of transform
internals: corruption injected in the frequency domain that cancels out of
the checked functional (e.g. a lone spectral bin != 0 under eval-at-one)
is only caught when it reaches the delivered coefficients — which is the
event that matters for serving.

Cost model: every check has a closed-form crossbar cycle cost
(:func:`check_cycles`) and a charging twin (:func:`charge_check`) built
from ONE schedule (:func:`_schedule`) — the column-parallel layout: the
per-element multiplies are vectored column ops over the resident rows, the
sum is a log-depth reduction tree, never a serial Horner sweep (a serial
eval would cost ~n modmuls and dwarf the transform it is checking).
``core.cost.abft_check_cycles`` re-exports the closed form so
``plan(..., verified=True)`` prices the overhead, and the
counter-parity gate (tests/test_abft.py) pins charged == closed-form.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

from repro.core.pim import aritpim
from repro.core.pim.device_model import PIMConfig

#: Registry-name -> check-name map (the verdict taxonomy, docs).
CHECKS = {
    "fft": "parseval",
    "rfft": "parseval-half",
    "polymul": "eval-at-one",
    "polymul-real": "eval-at-one",
    "polymul-mod": "eval-at-psi",
}

#: Default relative-residual tolerance for the float checks — matches the
#: serve layer's oracle tolerance (launch/ops.py ``_float_verify``).
FLOAT_TOL = 1e-3


class ABFTUnsupportedModulus(ValueError):
    """RNS modulus Q does not factor over its own limb primes — the
    per-factor eval-at-psi check has no valid evaluation points. Raised
    at verified-bind time so an unverifiable route never starts serving
    with a check that cannot run."""


@dataclasses.dataclass(frozen=True)
class IntegrityVerdict:
    """Uniform outcome of one batch-level integrity check."""
    ok: bool
    check: str                          # CHECKS[...] name
    residual: float = 0.0               # worst relative residual (float)
    tol: float = 0.0                    # threshold applied (0 = exact)
    failed_rows: tuple[int, ...] = ()   # batch rows that failed
    detail: str = ""

    def __bool__(self) -> bool:
        return self.ok


def _rows(x, dtype=None) -> np.ndarray:
    a = np.asarray(x) if dtype is None else np.asarray(x, dtype)
    return a if a.ndim == 2 else a[np.newaxis, :]


def _verdict(check: str, residual: np.ndarray, tol: float,
             detail: str = "") -> IntegrityVerdict:
    bad = np.flatnonzero(residual > tol)
    return IntegrityVerdict(
        ok=bad.size == 0, check=check,
        residual=float(residual.max()) if residual.size else 0.0,
        tol=tol, failed_rows=tuple(int(i) for i in bad), detail=detail)


# ---------------------------------------------------------------------------
# Float checks (toleranced residuals, batch rows)
# ---------------------------------------------------------------------------

def check_fft(x, out, *, tol: float = FLOAT_TOL) -> IntegrityVerdict:
    """Parseval residual per batch row: |sum|X|^2 - n sum|x|^2| / scale."""
    x, out = _rows(x, np.complex128), _rows(out, np.complex128)
    n = x.shape[1]
    lhs = n * np.sum(np.abs(x) ** 2, axis=1)
    rhs = np.sum(np.abs(out) ** 2, axis=1)
    residual = np.abs(rhs - lhs) / np.maximum(1.0, lhs)
    return _verdict(CHECKS["fft"], residual, tol)


def check_rfft(x, out, *, tol: float = FLOAT_TOL) -> IntegrityVerdict:
    """Half-spectrum Parseval: the interior bins carry double weight
    (their conjugate mirrors are not materialized)."""
    x, out = _rows(x, np.float64), _rows(out, np.complex128)
    n = x.shape[1]
    assert out.shape[1] == n // 2 + 1, \
        f"rfft result width {out.shape[1]} != n//2+1 = {n // 2 + 1}"
    e = np.abs(out) ** 2
    rhs = e[:, 0] + e[:, -1] + 2.0 * np.sum(e[:, 1:-1], axis=1)
    lhs = n * np.sum(x ** 2, axis=1)
    residual = np.abs(rhs - lhs) / np.maximum(1.0, lhs)
    return _verdict(CHECKS["rfft"], residual, tol)


def _check_eval_at_one(a, b, r, check: str, tol: float) -> IntegrityVerdict:
    a, b, r = (_rows(v, np.complex128) for v in (a, b, r))
    p1, q1, r1 = a.sum(axis=1), b.sum(axis=1), r.sum(axis=1)
    want = p1 * q1
    # Robust scale: the product magnitude, or the Cauchy–Schwarz bound on
    # it when p1/q1 themselves cancel to ~0 (sums of zero-mean inputs).
    scale = np.maximum.reduce([
        np.ones(len(a)), np.abs(want),
        np.sqrt(np.sum(np.abs(a) ** 2, axis=1)
                * np.sum(np.abs(b) ** 2, axis=1))])
    residual = np.abs(r1 - want) / scale
    return _verdict(check, residual, tol)


def check_polymul(a, b, r, *, tol: float = FLOAT_TOL) -> IntegrityVerdict:
    """Circular complex product: r(1) = a(1) b(1)."""
    return _check_eval_at_one(a, b, r, CHECKS["polymul"], tol)


def check_polymul_real(a, b, r, *,
                       tol: float = FLOAT_TOL) -> IntegrityVerdict:
    """Circular real product: same DC identity on real coefficients."""
    return _check_eval_at_one(a, b, r, CHECKS["polymul-real"], tol)


# ---------------------------------------------------------------------------
# Exact modular checks
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _psi_powers(params) -> np.ndarray:
    """(n,) uint64 table of psi^j mod q for Horner-free vectored eval."""
    return params.powers(params.psi)


def _eval_at_psi(rows: np.ndarray, params) -> np.ndarray:
    """Vectored p(psi) mod q per row. Exact in uint64: residues < q < 2^31
    so products < 2^62; the per-element mod keeps partial sums < n * q
    < 2^43 for every supported n."""
    pw = _psi_powers(params)
    q = np.uint64(params.q)
    terms = (rows.astype(np.uint64) * pw) % q
    return terms.sum(axis=1) % q


def check_polymul_mod(a, b, r, params) -> IntegrityVerdict:
    """Exact negacyclic identity r(psi) = a(psi) b(psi) mod q."""
    a, b, r = _rows(a), _rows(b), _rows(r)
    q = np.uint64(params.q)
    ea, eb, er = (_eval_at_psi(v, params) for v in (a, b, r))
    bad = np.flatnonzero((ea * eb) % q != er)
    return IntegrityVerdict(
        ok=bad.size == 0, check=CHECKS["polymul-mod"],
        residual=float(bad.size), tol=0.0,
        failed_rows=tuple(int(i) for i in bad),
        detail=f"q={params.q}")


@functools.lru_cache(maxsize=32)
def check_limbs_for(rns) -> tuple:
    """The NTTParams of the prime factors of Q, recovered from the RNS
    working-limb set (``RNSParams.make(modulus_bits=...)`` builds Q as a
    product of a prefix of those limbs). Raises
    :class:`ABFTUnsupportedModulus` when Q has any other factor."""
    q = rns.modulus
    out = []
    for limb in rns.limbs:
        if q % limb.q == 0:
            out.append(limb)
            q //= limb.q
        if q == 1:
            return tuple(out)
    raise ABFTUnsupportedModulus(
        f"RNS modulus Q~2^{rns.modulus.bit_length()} does not factor over "
        f"its limb primes (remainder ~2^{q.bit_length()}); the per-factor "
        f"eval-at-psi check needs a scheme-style Q = product of NTT limb "
        f"primes — rebuild the route with RNSParams.make(modulus_bits=...)")


def check_polymul_rns(a, b, r, rns) -> IntegrityVerdict:
    """Exact eval-at-psi per prime factor p | Q on the mod-Q result rows
    (object arrays of python ints in [0, Q))."""
    limbs = check_limbs_for(rns)
    a, b, r = _rows(a), _rows(b), _rows(r)
    bad: set[int] = set()
    for limb in limbs:
        p = limb.q
        ra, rb, rr = ((v % p).astype(np.uint64) for v in (a, b, r))
        ea, eb, er = (_eval_at_psi(v, limb) for v in (ra, rb, rr))
        bad |= {int(i) for i in
                np.flatnonzero((ea * eb) % np.uint64(p) != er)}
    return IntegrityVerdict(
        ok=not bad, check=CHECKS["polymul-mod"], residual=float(len(bad)),
        tol=0.0, failed_rows=tuple(sorted(bad)),
        detail=f"rns k={len(limbs)} factors of Q~2^"
               f"{rns.modulus.bit_length()}")


# ---------------------------------------------------------------------------
# Check cost: one schedule, two views (closed form + sim charging)
# ---------------------------------------------------------------------------

def _serial_units(m: int, cfg: PIMConfig) -> int:
    """Column-unit serialization for an m-element check vector, matching
    the transforms' convention (two elements per row, beta column units,
    partitions fire concurrently)."""
    beta = max(1, math.ceil(m / (2 * cfg.crossbar_rows)))
    return math.ceil(beta / cfg.partitions)


def _schedule(workload: str, n: int,
              cfg: PIMConfig) -> list[tuple]:
    """The check's crossbar op sequence — the single source of truth for
    both :func:`check_cycles` and :func:`charge_check`.

    Entries: ("col", op, active_rows, serial) vectored column op;
             ("row", n_rows, cycles_per_row, tag) serial row moves;
             ("twiddle", count) periphery constant writes.
    """
    s: list[tuple] = []
    rows = cfg.crossbar_rows

    def reduce_tree(m: int, add_op: str) -> None:
        # Log-depth sum of m resident values: fold the live rows pairwise
        # (row-granularity moves to align), one vectored add per level.
        live = min(m, rows)
        if live > 1:
            s.append(("row", live - 1, 2, "abft-reduce"))
        if m > 1:
            s.append(("col", add_op, live,
                      math.ceil(math.log2(m)) * _serial_units(m, cfg)))

    def energy(m: int, complex_vals: bool) -> None:
        live = min(m, rows)
        if complex_vals:                 # |z|^2 = re^2 + im^2
            s.append(("col", "fmul", live, 2 * _serial_units(m, cfg)))
            s.append(("col", "fadd", live, _serial_units(m, cfg)))
        else:                            # x^2
            s.append(("col", "fmul", live, _serial_units(m, cfg)))
        reduce_tree(m, "fadd")

    def eval_mod(m: int) -> None:
        s.append(("twiddle", m))         # psi^j constant column
        s.append(("col", "modmul", min(m, rows), _serial_units(m, cfg)))
        reduce_tree(m, "modadd")

    # repro: noqa[dispatch-ladder]: per-workload check-SCHEDULE construction (cost data, not op dispatch) — serving binds these checks through the launch/ops.py registry
    if workload == "fft":
        energy(n, True)                  # input energy
        energy(n, True)                  # output energy
        s.append(("col", "fmul", 1, 1))  # scale lhs by n
        s.append(("row", 1, 2, "abft-compare"))
    elif workload == "rfft":
        energy(n, False)                 # real input energy
        energy(n // 2 + 1, True)         # half-spectrum energy
        s.append(("col", "fadd", 1, 1))  # interior double-weight fold
        s.append(("col", "fmul", 1, 1))  # scale lhs by n
        s.append(("row", 1, 2, "abft-compare"))
    elif workload == "polymul":
        for _ in range(3):               # a(1), b(1), r(1)
            reduce_tree(n, "cadd")
        s.append(("col", "cmul", 1, 1))  # a(1) * b(1)
        s.append(("row", 1, 2, "abft-compare"))
    elif workload == "polymul-real":
        for _ in range(3):
            reduce_tree(n, "fadd")
        s.append(("col", "fmul", 1, 1))
        s.append(("row", 1, 2, "abft-compare"))
    elif workload == "polymul-mod":
        for _ in range(3):               # a(psi), b(psi), r(psi)
            eval_mod(n)
        s.append(("col", "modmul", 1, 1))
        s.append(("col", "modadd", 1, 1))
        s.append(("row", 1, 2, "abft-compare"))
    else:
        raise ValueError(f"unknown workload {workload!r}")
    return s


def check_cycles(workload: str, n: int, cfg: PIMConfig, spec) -> int:
    """Closed-form latency cycles of one integrity check (per batch unit;
    batch rows ride the same vectored ops, exactly like the transforms)."""
    total = 0
    for entry in _schedule(workload, n, cfg):
        if entry[0] == "col":
            _, op, _rows_, serial = entry
            total += aritpim.op_cycles(op, spec) * serial
        elif entry[0] == "row":
            _, n_rows, per_row, _tag = entry
            total += n_rows * per_row
        else:                            # ("twiddle", count)
            total += entry[1]
    return total


def charge_check(sim, workload: str, n: int) -> None:
    """Charge the check schedule on a live ``CrossbarSim`` — the
    counter-parity twin of :func:`check_cycles` (same ``_schedule``, so
    charged cycles == closed form by construction; the test pins it
    against drift in the sim's charging conventions)."""
    for entry in _schedule(workload, n, sim.cfg):
        if entry[0] == "col":
            _, op, rows, serial = entry
            sim.charge_column_op(op, rows, serial=serial)
        elif entry[0] == "row":
            _, n_rows, per_row, tag = entry
            sim.charge_row_ops(n_rows, cycles_per_row=per_row, tag=tag)
        else:
            sim.charge_twiddle_writes(entry[1])
