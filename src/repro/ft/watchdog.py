"""Straggler / hang detection for the training loop.

Per-step wall-times feed an EWMA; a step exceeding ``threshold x EWMA`` is
flagged as a straggler event. In a multi-host deployment the driver uses
this to (a) emit telemetry, (b) skip the lagging host's data shard for the
next step (the synthetic pipeline is stateless so no data is lost), and
(c) after ``evict_after`` consecutive flags, request the elastic controller
to re-mesh without the straggling host (checkpoint -> resize -> restore via
ft.checkpoint's elastic re-shard).

On this single-host container the detector itself is exercised by tests;
the eviction hook is a callback.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class WatchdogConfig:
    ewma_alpha: float = 0.2
    threshold: float = 2.5       # x EWMA counts as a straggler step
    evict_after: int = 3         # consecutive flags before eviction request
    warmup_steps: int = 3        # ignore compile/first-step noise


class StepWatchdog:
    def __init__(self, cfg: Optional[WatchdogConfig] = None,
                 on_evict: Optional[Callable[[int], None]] = None):
        # None sentinel: a dataclass default here would be evaluated ONCE at
        # class-definition time and shared (mutably) by every watchdog.
        self.cfg = WatchdogConfig() if cfg is None else cfg
        self.on_evict = on_evict
        self.ewma: Optional[float] = None
        self.seen = 0
        self.consecutive_flags = 0
        self.events: list[dict] = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> bool:
        """Returns True if this step was flagged as a straggler."""
        assert self._t0 is not None, "start_step not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    # -- checkpointable state (manifest ``extra``, json-serializable) -------

    def state_dict(self) -> dict:
        """EWMA/flag/event state for the checkpoint manifest: a resumed run
        keeps its timing baseline instead of re-warming and re-learning it
        (and keeps the straggler event log across preemptions)."""
        return {"ewma": self.ewma, "seen": self.seen,
                "consecutive_flags": self.consecutive_flags,
                "events": list(self.events)}

    def load_state_dict(self, state: dict) -> None:
        self.ewma = state.get("ewma")
        self.seen = int(state.get("seen", 0))
        self.consecutive_flags = int(state.get("consecutive_flags", 0))
        self.events = list(state.get("events", []))

    def observe(self, step: int, dt: float) -> bool:
        """Pure observation API (used by tests with synthetic timings)."""
        self.seen += 1
        if self.seen <= self.cfg.warmup_steps:
            self.ewma = dt if self.ewma is None else \
                (1 - self.cfg.ewma_alpha) * self.ewma + self.cfg.ewma_alpha * dt
            return False
        flagged = dt > self.cfg.threshold * self.ewma
        if flagged:
            self.consecutive_flags += 1
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
            if (self.consecutive_flags >= self.cfg.evict_after
                    and self.on_evict is not None):
                self.on_evict(step)
                self.consecutive_flags = 0
        else:
            self.consecutive_flags = 0
            # stragglers do not poison the EWMA
            self.ewma = ((1 - self.cfg.ewma_alpha) * self.ewma
                         + self.cfg.ewma_alpha * dt)
        return flagged
