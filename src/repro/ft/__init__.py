"""Fault tolerance: atomic/elastic checkpointing, step watchdog."""
