"""Fault-tolerant checkpointing: atomic, sharded, elastic.

Design (1000+ node posture, DESIGN.md §6):
  * atomic step directories: write to ``step_N.tmp`` then rename — a crash
    mid-write never corrupts the latest checkpoint;
  * durable, ordered writes: every file lands via temp-file + flush +
    ``os.fsync`` + ``os.replace`` and the manifest is written LAST, so a
    manifest that exists implies its arrays are already durable; the
    containing directory is fsynced after each rename so the entries
    themselves survive power loss;
  * checked reads: a truncated or partial manifest (torn write, disk
    full) raises :class:`CheckpointCorruptError` by name instead of a
    bare ``JSONDecodeError`` deep in restore;
  * every array is saved with a manifest (tree paths, shapes, dtypes) and
    the data as host-local .npz shards; restore re-shards onto WHATEVER mesh
    is bound at restore time (elastic re-scaling: checkpoints taken on N
    devices restore onto M);
  * retention: keep the last K steps; auto-resume picks the newest complete
    step; partial (crashed) writes are garbage-collected on startup.

No orbax dependency — msgpack-free, npz + json only.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file exists but cannot be trusted: truncated or
    malformed manifest, or a manifest missing its required keys. Raised
    by name so callers can distinguish "this snapshot is damaged" (fall
    back to an older step, or refuse to resume) from "no snapshot"
    (FileNotFoundError)."""


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _durable_replace(part: str, dest: str) -> None:
    """Atomically publish ``part`` as ``dest`` and make both the data and
    the directory entry durable (fsync file, rename, fsync dir)."""
    os.replace(part, dest)
    _fsync_dir(os.path.dirname(dest))


def _load_manifest(final: str) -> dict:
    """Read + validate one step's manifest; truncated/partial manifests
    (torn write mid-crash) surface as :class:`CheckpointCorruptError`."""
    path = os.path.join(final, "manifest.json")
    try:
        with open(path, "rb") as f:
            raw = f.read()
        manifest = json.loads(raw.decode("utf-8"))
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(
            f"{path} is truncated or malformed ({e}); the step cannot be "
            f"trusted — fall back to an older step or delete it") from e
    if not isinstance(manifest, dict) or "arrays" not in manifest \
            or "step" not in manifest:
        raise CheckpointCorruptError(
            f"{path} parsed but is missing required keys "
            f"('step', 'arrays'): partial manifest from an interrupted "
            f"save — fall back to an older step or delete it")
    return manifest


def _flatten_with_paths(tree: Any):
    # jax.tree.flatten_with_path is newer jax; tree_util spells it on 0.4.x
    flatten = getattr(jax.tree, "flatten_with_path",
                      jax.tree_util.tree_flatten_with_path)
    flat, treedef = flatten(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    """Atomically save a pytree of (possibly sharded) jax arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "arrays": [], "extra": extra or {}}
    arrays = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i}"
        manifest["arrays"].append({"key": key, "name": name,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
        arrays[name] = arr
    # Arrays first, manifest LAST, every file fsynced before its rename:
    # a manifest that exists implies its arrays are already durable, so
    # readers never see a step whose data lags its metadata.
    npz_part = os.path.join(tmp, "arrays.npz.part")
    # repro: noqa[durable-write]: this IS the durable helper — .part file, fsync below, rename after
    with open(npz_part, "wb") as f:
        np.savez(f, **arrays)  # repro: noqa[durable-write]: into the fsynced .part file opened above
        f.flush()
        os.fsync(f.fileno())
    _durable_replace(npz_part, os.path.join(tmp, "arrays.npz"))
    man_part = os.path.join(tmp, "manifest.json.part")
    # repro: noqa[durable-write]: this IS the durable helper — manifest lands LAST via _durable_replace
    with open(man_part, "w") as f:
        json.dump(manifest, f)  # repro: noqa[durable-write]: into the fsynced .part file opened above
        f.flush()
        os.fsync(f.fileno())
    _durable_replace(man_part, os.path.join(tmp, "manifest.json"))
    if os.path.exists(final):
        # Re-saving an existing step must land the FRESH arrays. os.replace
        # cannot atomically replace a non-empty directory, so the old step
        # is first moved aside under a .tmp suffix (which _retain GCs like
        # any crashed partial write) and removed only after the rename. A
        # crash between the two renames leaves no step_N listed — never a
        # stale one masquerading as the new save.
        old = final + ".old.tmp"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.replace(final, old)
        os.replace(tmp, final)
        shutil.rmtree(old, ignore_errors=True)
    else:
        os.replace(tmp, final)
    _fsync_dir(ckpt_dir)    # the step_N entry itself survives power loss
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)
    # GC half-written tmp dirs
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The saved manifest (tree paths/shapes/dtypes + the ``extra`` payload
    callers stash host-side state in: watchdog EWMA/events, data-pipeline
    step cursor, engine bucket config — docs/fault_tolerance.md).

    Raises :class:`CheckpointCorruptError` for a truncated or partial
    manifest rather than handing the caller half a JSON document."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    return _load_manifest(final)


def read_extra(ckpt_dir: str, step: int) -> dict:
    return read_manifest(ckpt_dir, step).get("extra", {})


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; if ``shardings`` is given the
    arrays are device_put with those shardings (elastic re-shard: the saved
    mesh size is irrelevant — data is stored unsharded per tree leaf)."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    manifest = _load_manifest(final)
    data = np.load(os.path.join(final, "arrays.npz"))
    by_key = {e["key"]: data[e["name"]] for e in manifest["arrays"]}
    leaves, treedef = _flatten_with_paths(like)
    sh_leaves = None
    if shardings is not None:
        sh_flat, _ = _flatten_with_paths(shardings)
        sh_leaves = dict(sh_flat)
    out = []
    for key, leaf in leaves:
        if key not in by_key:
            raise KeyError(
                f"checkpoint {final} has no array for {key!r} — the saved "
                f"payload does not match the restore tree (e.g. resuming "
                f"--compress-grads from a checkpoint saved without the "
                f"grad_err residual); saved keys: "
                f"{sorted(by_key)[:8]}...")
        arr = by_key[key]
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if sh_leaves is not None and key in sh_leaves:
            out.append(jax.device_put(arr, sh_leaves[key]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def restore_latest(ckpt_dir: str, like: Any, shardings: Any = None
                   ) -> tuple[Optional[int], Any]:
    step = latest_step(ckpt_dir)
    if step is None:
        return None, like
    return step, restore(ckpt_dir, step, like, shardings)
