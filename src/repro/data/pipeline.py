"""Synthetic deterministic token pipeline with host sharding + prefetch.

Production posture: each host generates only its shard of the global batch
(deterministically from (seed, step, host_id) — so restarts resume exactly
and elastic re-sharding re-partitions the same logical stream), and a
background thread prefetches ahead of the training loop so input latency
overlaps compute (straggler mitigation at the input layer).

The "dataset" is a synthetic integer-sequence language: spans of arithmetic
progressions with noise, giving a learnable next-token structure (loss
decreases) without external data.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Deterministic, restartable synthetic token stream."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, n_hosts: int = 1, host_id: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // n_hosts
        self.seed = seed
        self.host_id = host_id

    def batch_at(self, step: int) -> dict:
        """Batch for `step`, independent of history (pure function)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s, v = self.local_batch, self.seq, self.vocab
        # arithmetic-progression spans: x[t] = (a + d*t) % v with occasional
        # re-draws — predictable structure a model can learn
        starts = rng.integers(0, v, (b, 1))
        deltas = rng.integers(1, 7, (b, 1))
        t = np.arange(s + 1)[None, :]
        toks = (starts + deltas * t) % v
        noise = rng.random((b, s + 1)) < 0.02
        toks = np.where(noise, rng.integers(0, v, (b, s + 1)), toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def stop(self):
        self._stop.set()
        self.thread.join(timeout=2.0)
