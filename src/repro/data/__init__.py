"""Synthetic-data pipeline (deterministic, host-shardable)."""
