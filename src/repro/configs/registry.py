"""Architecture registry (--arch <id>) and the assigned input-shape grid.

40 dry-run cells = 10 architectures x 4 shapes. ``cell_supported`` encodes
the long_500k sub-quadratic rule: run for SSM/hybrid/linear-attn and
sliding-window archs, skip (with a reason) for pure full-attention archs —
see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.models.config import ModelConfig

ARCH_MODULES = {
    "llama3-405b": "llama3_405b",
    "qwen3-1.7b": "qwen3_1p7b",
    "starcoder2-15b": "starcoder2_15b",
    "gemma3-4b": "gemma3_4b",
    "hymba-1.5b": "hymba_1p5b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "rwkv6-7b": "rwkv6_7b",
    # paper-native extra (not part of the 40-cell grid)
    "fourierpim-lm": "fourierpim_lm",
}

ASSIGNED = [k for k in ARCH_MODULES if k != "fourierpim-lm"]


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not). The only skips are long_500k on pure
    full-attention archs (O(S) KV with dense global attention at 500K has no
    sub-quadratic path; DESIGN.md §5)."""
    if shape.name == "long_500k":
        subquadratic = (cfg.mixer in ("rwkv6", "hymba", "fourier")
                        or cfg.attention in ("swa", "local_global"))
        if not subquadratic:
            return False, ("pure full-attention arch: long_500k needs "
                           "sub-quadratic attention (skip per DESIGN.md §5)")
    return True, ""
