"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [ibm-granite family].

NOTE: the assignment lists both "MoE 40e top-8" and "32 experts top-8";
we take the structured config field (40 experts) — see DESIGN.md §5."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    attention="full",
    num_experts=40, experts_per_token=8,
)
