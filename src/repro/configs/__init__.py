"""Architecture configs + the (arch x shape) cell registry."""
