"""fourierpim-lm: the paper's primitive as a sequence model — FourierPIM
FFT-convolution token mixing (O(S log S)) in place of attention. Used by
examples/fourier_lm.py and the Fourier-mixing ablation benchmarks."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="fourierpim-lm", family="fourier",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=32768,
    mixer="fourier", fourier_taps=256, attention="none",
)
