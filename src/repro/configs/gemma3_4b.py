"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global attention, 128k vocab x2 [gemma-3 family]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    d_ff=10240, vocab_size=262144,
    attention="local_global", local_global_ratio=5, window=1024,
    rope_theta=1_000_000.0,
)
