"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536
— Finch: data-dependent decay linear attention [arXiv:2404.05892].

The recurrence is not an LTI convolution (decay is input-dependent), so the
FourierPIM convolution theorem does not apply — runs without the technique
(DESIGN.md §Arch-applicability). O(1) state => long_500k supported."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    mixer="rwkv6", attention="none",
)
