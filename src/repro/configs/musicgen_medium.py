"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24, MHA) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec frontend is a STUB per the shape contract: input_specs()
provides precomputed frame embeddings (B, S, d_model); the backbone predicts
the 2048-way codebook tokens."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    attention="full", frontend="embeddings",
)
