"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191].

Vision frontend is a STUB: input_specs() provides the merged sequence of
precomputed patch+text embeddings plus (B, S, 3) M-RoPE position streams
(temporal / height / width)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    attention="full", mrope_sections=(16, 24, 24),
    frontend="embeddings", rope_theta=1_000_000.0,
    param_dtype="bfloat16", remat="full",
)
