"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676].

Attention heads are sliding-window (the Hymba recipe keeps most layers
local; the parallel SSM heads carry the global summary), so long_500k runs
with O(window) attention + O(1) SSM state."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    mixer="hymba", ssm_state=16,
    attention="swa", window=2048,
)
