"""CLI: ``python -m repro.analysis <paths...> [--format text|json]``.

Exit codes: 0 = clean, 1 = findings, 2 = usage error (missing path, no
paths). CI runs the text form as the gate and the JSON form as an
uploaded artifact (docs/static_analysis.md).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import engine
from repro.analysis import rules as rules_mod


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant linter encoding this repo's shipped-bug "
                    "contracts (docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to scan (e.g. src tests "
                         "benchmarks)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="report format (default text)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit 0")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in rules_mod.RULES:
            print(f"{rule.id:18s} [{rule.kind}] {rule.summary}")
        print(f"{len(rules_mod.RULES)} rules")
        return 0
    if not args.paths:
        ap.error("no paths given (e.g. src tests benchmarks)")
    try:
        result = engine.analyze_paths(args.paths)
    except FileNotFoundError as e:
        ap.error(f"path does not exist: {e.args[0]}")
    print(engine.render(result, args.format))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
