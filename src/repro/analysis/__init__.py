"""repro.analysis — AST-based invariant linter for this repo's contracts.

Each rule encodes a bug class this repository actually shipped and fixed
(docs/static_analysis.md has the full table); the CI ``static-analysis``
job gates ``python -m repro.analysis src tests benchmarks`` at zero
findings, so reintroducing any of those bugs fails the build with a
message naming the rule and the original PR.

Stdlib-only by design: importing this package must never pull in jax, so
the linter runs in a bare environment before any heavy dependency
installs, and linting cannot be broken by the code it lints.
"""
from repro.analysis.engine import (AnalysisResult, Finding, analyze_files,
                                   analyze_paths, analyze_source,
                                   iter_python_files, render, to_json,
                                   to_text)
from repro.analysis.rules import LAX_COLLECTIVES, OP_NAMES, RULE_IDS, RULES

__all__ = [
    "AnalysisResult", "Finding", "analyze_files", "analyze_paths",
    "analyze_source", "iter_python_files", "render", "to_json", "to_text",
    "LAX_COLLECTIVES", "OP_NAMES", "RULE_IDS", "RULES",
]
