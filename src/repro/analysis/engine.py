"""Analysis engine: file walking, suppression handling, reporters.

Pipeline per file (``analyze_source``):

1. ``ast.parse`` — a file that does not parse yields a single
   ``parse-error`` finding (engine-level, not a registered rule; the ruff
   E9 gate normally catches these first).
2. Every ``kind == 'ast'`` rule in ``rules.RULES`` runs over the tree.
3. ``# repro: noqa[rule-id]: reason`` comments are tokenized out.  A
   malformed suppression (missing reason, unknown rule id, or naming one
   of the engine-hosted meta rules) becomes a ``noqa-reason`` finding and
   suppresses nothing.
4. Valid suppressions absorb matching findings — same line, or a
   comment-only noqa line directly above — and the absorbed finding is
   kept in ``AnalysisResult.suppressed`` with its reason so the JSON
   report shows every excused site.
5. A valid suppression that absorbed nothing becomes ``unused-noqa``.

Exit-code contract of the CLI (``repro.analysis.__main__``): 0 clean,
1 findings, 2 usage error.  CI runs the text gate at zero findings and
uploads the JSON report as an artifact (docs/static_analysis.md).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Iterable, Sequence

from repro.analysis import rules as rules_mod

SCHEMA = "repro.analysis/v1"

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[(?P<ids>[^\]]*)\]"
    r"(?P<sep>\s*:\s*)?(?P<reason>.*)$")

#: ids a noqa may name: the AST rules only — the meta rules keep the
#: suppression machinery itself honest and cannot be suppressed.
_SUPPRESSIBLE = frozenset(
    r.id for r in rules_mod.RULES if r.kind == "ast")
_KNOWN_IDS = frozenset(r.id for r in rules_mod.RULES)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Noqa:
    line: int
    col: int
    ids: tuple[str, ...]
    reason: str
    standalone: bool        # comment-only line: also covers the line below
    problem: str | None     # set when malformed (reported as noqa-reason)


@dataclasses.dataclass
class AnalysisResult:
    findings: list[Finding]
    suppressed: list[dict]      # finding dict + reason + noqa_line
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.findings


@dataclasses.dataclass(frozen=True)
class FileContext:
    path: str
    tree: ast.AST
    source: str


def _parse_noqas(source: str) -> list[Noqa]:
    out: list[Noqa] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [t for t in tokens if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError):    # pragma: no cover
        return out
    for tok in comments:
        m = _NOQA_RE.search(tok.string)
        if not m:
            continue
        ids = tuple(s.strip() for s in m.group("ids").split(",") if s.strip())
        reason = (m.group("reason") or "").strip()
        problem = None
        if not ids:
            problem = "suppression names no rule id"
        elif not m.group("sep") or not reason:
            problem = (f"suppression of [{', '.join(ids)}] carries no "
                       "reason — write '# repro: noqa[rule-id]: why the "
                       "historical bug does not apply here'")
        else:
            unknown = [i for i in ids if i not in _KNOWN_IDS]
            meta = [i for i in ids if i in _KNOWN_IDS
                    and i not in _SUPPRESSIBLE]
            if unknown:
                problem = (f"suppression names unknown rule id "
                           f"{', '.join(unknown)} (known: "
                           f"{', '.join(sorted(_SUPPRESSIBLE))})")
            elif meta:
                problem = (f"rule {', '.join(meta)} keeps suppressions "
                           "honest and cannot itself be suppressed")
        line, col = tok.start
        standalone = tok.line[:col].strip() == ""
        out.append(Noqa(line=line, col=col, ids=ids, reason=reason,
                        standalone=standalone, problem=problem))
    return out


def _covers(nq: Noqa, finding: Finding) -> bool:
    if finding.rule not in nq.ids:
        return False
    return nq.line == finding.line or \
        (nq.standalone and nq.line == finding.line - 1)


def analyze_source(source: str, path: str) -> AnalysisResult:
    """Run every rule plus the suppression machinery over one file.
    ``path`` may be virtual (fixtures) — placement rules match suffixes."""
    path = path.replace(os.sep, "/")
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        f = Finding(path, e.lineno or 0, (e.offset or 1) - 1, "parse-error",
                    f"file does not parse: {e.msg}")
        return AnalysisResult(findings=[f], suppressed=[], n_files=1)
    ctx = FileContext(path=path, tree=tree, source=source)

    raw: list[Finding] = []
    for rule in rules_mod.RULES:
        if rule.kind != "ast":
            continue
        for line, col, msg in rule.check(ctx):
            raw.append(Finding(path, line, col, rule.id, msg))

    noqas = _parse_noqas(source)
    findings: list[Finding] = []
    for nq in noqas:
        if nq.problem:
            findings.append(Finding(path, nq.line, nq.col, "noqa-reason",
                                    nq.problem))
    valid = [nq for nq in noqas if nq.problem is None]

    suppressed: list[dict] = []
    used: set[int] = set()
    for f in raw:
        hit = next((nq for nq in valid if _covers(nq, f)), None)
        if hit is None:
            findings.append(f)
        else:
            used.add(id(hit))
            suppressed.append({**f.to_dict(), "reason": hit.reason,
                               "noqa_line": hit.line})
    for nq in valid:
        if id(nq) not in used:
            findings.append(Finding(
                path, nq.line, nq.col, "unused-noqa",
                f"suppression of [{', '.join(nq.ids)}] matches no finding "
                "on its line (or the line below, for a comment-only line) "
                "— stale noqas are latent holes; delete it"))

    findings.sort()
    return AnalysisResult(findings=findings, suppressed=suppressed,
                          n_files=1)


def iter_python_files(paths: Sequence[str]) -> list[str]:
    """Every .py under the given files/dirs, sorted, __pycache__ and
    dot-dirs skipped. Raises FileNotFoundError for a missing path."""
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d != "__pycache__"
                                 and not d.startswith("."))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
        else:
            raise FileNotFoundError(p)
    return sorted(dict.fromkeys(out))


def analyze_files(files: Iterable[str]) -> AnalysisResult:
    findings: list[Finding] = []
    suppressed: list[dict] = []
    n = 0
    for fp in files:
        with open(fp, encoding="utf-8") as f:
            source = f.read()
        res = analyze_source(source, fp)
        findings.extend(res.findings)
        suppressed.extend(res.suppressed)
        n += 1
    findings.sort()
    return AnalysisResult(findings=findings, suppressed=suppressed,
                          n_files=n)


def analyze_paths(paths: Sequence[str]) -> AnalysisResult:
    return analyze_files(iter_python_files(paths))


# ---------------------------------------------------------------------------
# Reporters
# ---------------------------------------------------------------------------

def to_text(result: AnalysisResult) -> str:
    lines = [f.format() for f in result.findings]
    lines.append(
        f"[repro.analysis] {len(result.findings)} finding(s), "
        f"{len(result.suppressed)} suppressed (with reasons), "
        f"{result.n_files} file(s), {len(rules_mod.RULES)} rules")
    return "\n".join(lines)


def to_json(result: AnalysisResult) -> dict:
    return {
        "schema": SCHEMA,
        "rule_count": len(rules_mod.RULES),
        "rules": [{"id": r.id, "kind": r.kind, "summary": r.summary}
                  for r in rules_mod.RULES],
        "n_files": result.n_files,
        "findings": [f.to_dict() for f in result.findings],
        "suppressed": result.suppressed,
        "ok": result.ok,
    }


def render(result: AnalysisResult, fmt: str) -> str:
    if fmt == "json":
        return json.dumps(to_json(result), indent=2)
    return to_text(result)
