"""The rule set: one class per bug this repo actually shipped and fixed.

Every rule here is an executable postmortem.  The coding contracts that
keep the counter-parity / ledger-parity invariants true (numpy-only table
caches, f64 twiddle phases, ledgered collectives, registry-only dispatch,
lock discipline in signal handlers, durable checkpoint writes, planner-only
FFTPlan construction) were each learned from a real regression in PRs 3-9;
until this module existed they lived only in CHANGES.md prose and a string
grep.  Each rule's docstring names the PR and the original bug so a finding
reads as "you are about to reship this", not as style nagging.

Rules are pure: ``check(ctx)`` yields ``(line, col, message)`` tuples from
the stdlib ``ast`` tree in ``ctx.tree`` (no third-party deps, no imports of
the code under analysis).  ``ctx.path`` is the forward-slash-normalized
file path; rules that encode *placement* contracts (the one module allowed
to do X) match on path suffixes.

Suppression: ``# repro: noqa[rule-id]: reason`` on the finding line, or on
a comment-only line directly above it.  The reason is mandatory
(``noqa-reason``) and the suppression must actually hit (``unused-noqa``)
— see ``repro.analysis.engine``.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator, Tuple

RawFinding = Tuple[int, int, str]

#: The served op names of the launch/ops.py registry. Kept literal so the
#: analyzer stays importable without jax; tests/test_analysis.py asserts
#: this set == op_registry.op_names() so the two cannot drift.
OP_NAMES = frozenset({"fft", "rfft", "polymul", "polymul-real",
                      "polymul-mod"})

#: Data-moving jax.lax collectives. axis_index is deliberately absent —
#: it moves no bytes, so calling it raw cannot break ledger parity.
LAX_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "all_to_all", "all_gather",
    "ppermute", "pshuffle", "psum_scatter", "pbroadcast",
})


def _dotted(node: ast.AST) -> str | None:
    """'jax.lax.psum' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jnp_ref(node: ast.AST) -> bool:
    if isinstance(node, ast.Name) and node.id == "jnp":
        return True
    d = _dotted(node)
    return bool(d) and (d == "jax.numpy" or d.startswith("jax.numpy."))


def _mentions_float32(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "float32":
        return True
    if isinstance(node, ast.Name) and node.id == "float32":
        return True
    return isinstance(node, ast.Constant) and node.value == "float32"


def _walk_skip_nested(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested function
    definitions (their bodies run on whatever thread *calls* them, not on
    the enclosing frame)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


class Rule:
    """Base: ``id`` is the noqa key; ``kind`` is 'ast' for tree rules or
    'noqa' for the engine-hosted suppression-hygiene rules."""

    id: str = ""
    kind: str = "ast"

    def check(self, ctx) -> Iterable[RawFinding]:
        return ()

    @property
    def summary(self) -> str:
        return (self.__doc__ or "").strip().splitlines()[0]


class TracerLeakRule(Rule):
    """lru_cache / functools.cache over a function that touches jnp — PR 3:
    the RNS kernel's per-limb constant tables were cached across jit traces
    and leaked tracers; the fix ("tables lru-cached as NUMPY") only holds if
    every cached table builder stays numpy-only."""

    id = "tracer-leak"
    _CACHE_DECOS = frozenset({"functools.lru_cache", "lru_cache",
                              "functools.cache", "cache"})

    def check(self, ctx) -> Iterable[RawFinding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            cached = any(
                _dotted(d.func if isinstance(d, ast.Call) else d)
                in self._CACHE_DECOS
                for d in node.decorator_list)
            if not cached:
                continue
            if any(_is_jnp_ref(sub) for sub in ast.walk(node)):
                yield (node.lineno, node.col_offset,
                       "cached function references jnp: lru-cached values "
                       "must be NUMPY — caching jnp arrays across jit "
                       "traces leaks tracers (PR 3, RNS table cache)")


class Fp32PhaseRule(Rule):
    """Twiddle/root phases built with float32 or traced operands — PR 5:
    the four-step FFT's step-3 twiddles were f32 ``k1*j2`` products with a
    separately-rounded in-graph device phase (~4e-7 error at n=2^20); the
    fix computes exact integer exponents with f64 host trig, rounded ONCE."""

    id = "fp32-phase"
    _TRIG = frozenset({"exp", "cos", "sin"})
    _HOST = frozenset({"np", "numpy", "math"})
    _GRAPH = frozenset({"jnp", "jax.numpy"})

    def check(self, ctx) -> Iterable[RawFinding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if not d or "." not in d:
                continue
            base, _, fn = d.rpartition(".")
            if fn not in self._TRIG:
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            subs = [s for a in args for s in ast.walk(a)]
            has_f32 = any(_mentions_float32(s) for s in subs)
            if base in self._HOST:
                if has_f32:
                    yield (node.lineno, node.col_offset,
                           f"host {d}() over a float32 phase: twiddle/root "
                           "angles must be exact-integer exponents in f64, "
                           "rounded once after the trig (PR 5, fp32 "
                           "four-step twiddle bug)")
                elif any(_is_jnp_ref(s) for s in subs):
                    yield (node.lineno, node.col_offset,
                           f"host {d}() fed a traced (jnp) operand: phase "
                           "tables are built host-side from integer "
                           "exponents, never from in-graph values (PR 5, "
                           "fp32 four-step twiddle bug)")
            elif base in self._GRAPH and has_f32:
                yield (node.lineno, node.col_offset,
                       f"in-graph {d}() over an explicitly float32 phase: "
                       "separately-rounded f32 phases cost ~10x twiddle "
                       "accuracy (PR 5, fp32 four-step twiddle bug)")


class MutableDefaultRule(Rule):
    """Mutable or config-dataclass default arguments — PR 7: a shared
    ``WatchdogConfig()`` default meant every StepWatchdog mutated the same
    config instance; the fix is a None sentinel. Flags mutable literals,
    mutable constructors, and calls to ``*Config`` names in defaults."""

    id = "mutable-default"
    _LITERALS = (ast.List, ast.Dict, ast.Set,
                 ast.ListComp, ast.SetComp, ast.DictComp)
    _CTORS = frozenset({"list", "dict", "set", "bytearray", "deque",
                        "defaultdict", "Counter", "OrderedDict"})

    def check(self, ctx) -> Iterable[RawFinding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for default in defaults:
                what = None
                if isinstance(default, self._LITERALS):
                    what = "mutable literal"
                elif isinstance(default, ast.Call):
                    last = (_dotted(default.func) or "").rpartition(".")[2]
                    if last in self._CTORS:
                        what = f"mutable {last}() constructor"
                    elif last.endswith("Config"):
                        what = f"config-dataclass instance {last}()"
                if what:
                    yield (default.lineno, default.col_offset,
                           f"{what} as a default argument is shared "
                           "across every call — use a None sentinel "
                           "(PR 7, shared-mutable WatchdogConfig bug)")


class RawCollectiveRule(Rule):
    """jax.lax collectives outside repro/dist/collectives.py — PR 1 built
    the byte-ledger wrappers and PRs 5/8 pinned closed-form byte formulas
    against that ledger; a raw ``jax.lax.psum``/``all_to_all`` call site
    moves bytes the ledger never sees, silently breaking ledger parity."""

    id = "raw-collective"
    _ALLOWED = ("repro/dist/collectives.py",)

    def check(self, ctx) -> Iterable[RawFinding]:
        if ctx.path.endswith(self._ALLOWED):
            return
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Attribute)
                    and node.attr in LAX_COLLECTIVES
                    and _dotted(node.value) in ("jax.lax", "lax")):
                yield (node.lineno, node.col_offset,
                       f"raw jax.lax.{node.attr}: collectives must go "
                       "through the byte-ledgered wrappers in "
                       "repro.dist.collectives, or their bytes never hit "
                       "the ledger the closed forms are pinned against "
                       "(PR 1 ledger, PR 5/8 parity gates)")


class DispatchLadderRule(Rule):
    """if/elif string ladders over the served op names outside the
    launch/ops.py registry — PR 6 replaced serve.py's per-op ladders with
    the OpSpec registry; a new ladder is a second dispatch surface that
    drifts from registry validation/binding. Promotes the PR 6 string-grep
    test (which a renamed variable could dodge) to an AST rule."""

    id = "dispatch-ladder"
    _ALLOWED = ("repro/launch/ops.py",)

    def check(self, ctx) -> Iterable[RawFinding]:
        if ctx.path.endswith(self._ALLOWED):
            return
        elif_nodes = set()
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.If) and len(node.orelse) == 1
                    and isinstance(node.orelse[0], ast.If)):
                elif_nodes.add(id(node.orelse[0]))
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.If) or id(node) in elif_nodes:
                continue
            subjects: dict[str, set[str]] = {}
            cur = node
            while True:
                m = self._str_eq(cur.test)
                if m:
                    subjects.setdefault(m[0], set()).add(m[1])
                if len(cur.orelse) == 1 and isinstance(cur.orelse[0], ast.If):
                    cur = cur.orelse[0]
                else:
                    break
            for vals in subjects.values():
                hit = sorted(vals & OP_NAMES)
                if len(hit) >= 2:
                    yield (node.lineno, node.col_offset,
                           f"op-name dispatch ladder ({', '.join(hit)}): "
                           "dispatch belongs in the launch/ops.py OpSpec "
                           "registry, not string switches (PR 6, serve "
                           "ladder removal)")
                    break

    @staticmethod
    def _str_eq(test: ast.AST) -> tuple[str, str] | None:
        if (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.ops[0], ast.Eq)):
            left, right = test.left, test.comparators[0]
            if isinstance(right, ast.Constant) and isinstance(right.value,
                                                             str):
                return ast.dump(left), right.value
            if isinstance(left, ast.Constant) and isinstance(left.value,
                                                             str):
                return ast.dump(right), left.value
        return None


class SignalLockRule(Rule):
    """Taking a non-reentrant lock inside a signal handler body — PR 7:
    the serve SIGTERM handler called ``engine.request_stop()`` on the
    interrupted main thread, whose frame may already hold the engine's
    Condition — a self-deadlock. The fix hands the call to a separate
    thread; nested defs are exempt (they run on whichever thread calls
    them, which is the hand-off pattern)."""

    id = "signal-lock"
    _LOCKY_CALLS = frozenset({"acquire", "wait", "notify", "notify_all",
                              "request_stop", "submit", "snapshot"})
    _LOCKY_NAMES = ("lock", "cv", "cond", "mutex")

    def check(self, ctx) -> Iterable[RawFinding]:
        handler_names: set[str] = set()
        handler_lambdas: list[ast.Lambda] = []
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and _dotted(node.func) == "signal.signal"
                    and len(node.args) >= 2):
                h = node.args[1]
                if isinstance(h, ast.Name):
                    handler_names.add(h.id)
                elif isinstance(h, ast.Lambda):
                    handler_lambdas.append(h)
        bodies: list[ast.AST] = list(handler_lambdas)
        bodies += [n for n in ast.walk(ctx.tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and n.name in handler_names]
        for fn in bodies:
            yield from self._scan(fn)

    def _scan(self, fn: ast.AST) -> Iterator[RawFinding]:
        for sub in _walk_skip_nested(fn):
            if isinstance(sub, ast.With):
                for item in sub.items:
                    name = ((_dotted(item.context_expr) or "")
                            .rpartition(".")[2].lower())
                    if any(k in name for k in self._LOCKY_NAMES):
                        yield (sub.lineno, sub.col_offset,
                               "signal handler enters a lock: the handler "
                               "runs on the interrupted main thread, which "
                               "may already hold it — self-deadlock (PR 7, "
                               "SIGTERM drain bug); hand off to a thread")
            elif (isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in self._LOCKY_CALLS):
                yield (sub.lineno, sub.col_offset,
                       f"signal handler calls .{sub.func.attr}() directly: "
                       "engine methods take the non-reentrant Condition — "
                       "on the interrupted main thread that self-deadlocks "
                       "(PR 7, SIGTERM drain bug); spawn a thread for it")


class DurableWriteRule(Rule):
    """Raw writes inside repro/ft/ — PR 9: a crash between payload and
    manifest writes left torn checkpoints that restore half-read; every
    durable file must go through the fsync+rename, manifest-LAST helper in
    ft/checkpoint.py. Flags open(..., 'w'/'a'/'x'/'+'), json.dump and
    np.save* in ft modules; the helper's own internals carry noqa reasons."""

    id = "durable-write"
    _SCOPE = "repro/ft/"
    _WRITE_FNS = frozenset({"json.dump", "np.save", "np.savez",
                            "np.savez_compressed", "numpy.save",
                            "numpy.savez", "numpy.savez_compressed"})

    def check(self, ctx) -> Iterable[RawFinding]:
        if self._SCOPE not in ctx.path:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d == "open":
                mode = None
                if len(node.args) >= 2 and isinstance(node.args[1],
                                                      ast.Constant):
                    mode = node.args[1].value
                for kw in node.keywords:
                    if kw.arg == "mode" and isinstance(kw.value,
                                                       ast.Constant):
                        mode = kw.value.value
                if isinstance(mode, str) and any(c in mode for c in "wax+"):
                    yield (node.lineno, node.col_offset,
                           f"raw open(..., {mode!r}) under ft/: durable "
                           "state must go through the fsync+rename "
                           "manifest-last helper, or a crash tears the "
                           "checkpoint (PR 9, torn-manifest bug)")
            elif d in self._WRITE_FNS:
                yield (node.lineno, node.col_offset,
                       f"raw {d}() under ft/: durable state must go "
                       "through the fsync+rename manifest-last helper "
                       "(PR 9, torn-manifest bug)")


class BarePlanLiteralRule(Rule):
    """Hand-built FFTPlan(...) literals outside planner.py/cost.py — PR 5:
    serve carried literal plans that silently skipped planner validation
    (the exact tier's shard checks among them); the fix routes every forced
    plan through ``plan(..., force_distributed=True)`` so the constraints
    fire. Only the planner and the cost model may construct FFTPlan."""

    id = "bare-plan-literal"
    _ALLOWED = ("repro/core/fft/planner.py", "repro/core/cost.py")

    def check(self, ctx) -> Iterable[RawFinding]:
        if ctx.path.endswith(self._ALLOWED):
            return
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and (_dotted(node.func) or "").rpartition(".")[2]
                    == "FFTPlan"):
                yield (node.lineno, node.col_offset,
                       "hand-built FFTPlan literal: construct plans via "
                       "plan(n, batch, ...) so planner validation "
                       "(shard divisibility, VMEM ceilings) runs (PR 5, "
                       "serve literal-plan bug)")


class NoqaReasonRule(Rule):
    """Suppression without a reason (or naming an unknown/meta rule) —
    PR 10's own contract: every ``# repro: noqa[rule]`` must carry
    ``: reason`` explaining why the historical bug does not apply here;
    a bare suppression is how contracts silently rot. Engine-hosted: the
    malformed suppression is reported and does NOT suppress."""

    id = "noqa-reason"
    kind = "noqa"


class UnusedNoqaRule(Rule):
    """Suppression that suppresses nothing — PR 10's own contract: a noqa
    left behind after the code it excused was fixed (or that never matched)
    is a latent hole; the engine reports it so suppressions track the code.
    Engine-hosted post-pass over the suppression table."""

    id = "unused-noqa"
    kind = "noqa"


RULES: tuple[Rule, ...] = (
    TracerLeakRule(),
    Fp32PhaseRule(),
    MutableDefaultRule(),
    RawCollectiveRule(),
    DispatchLadderRule(),
    SignalLockRule(),
    DurableWriteRule(),
    BarePlanLiteralRule(),
    NoqaReasonRule(),
    UnusedNoqaRule(),
)

RULE_IDS = tuple(r.id for r in RULES)
