"""Pallas TPU kernel: fused polynomial multiplication via the convolution
theorem (paper §5), one VMEM residency for the entire FFT -> product -> IFFT.

Paper correspondences:

* Eq. (9): C = IDFT(DFT(a) . DFT(b)) — the kernel computes both forward
  transforms, the pointwise product, and the inverse transform without ever
  leaving VMEM. cuFFT (and an unfused XLA graph) pays 3x the HBM traffic
  (two FFTs, a pointwise pass, an IFFT each round-trip memory); the paper
  makes the same observation about the GPU's element-wise multiply being
  memory-bound (§6, last paragraph) — fusion is the TPU-native counterpart.
* Input-permutation cancellation: the paper skips the FFT/IFFT bit-reversal
  permutations because they cancel across DFT.IDFT. Stockham autosort has no
  explicit permutation to begin with; the property holds structurally.
* Eq. (10) real packing: two real-coefficient transforms from one complex
  FFT via z = a + i b, unpacked with conjugate symmetry. The paper's PIM
  tricks map as: conjugate = sign flip on the imag plane; multiply by i =
  plane swap + sign flip; divide by 2 = scalar multiply (PIM decrements the
  exponent; the VPU just multiplies); Z_{n-k} = lane reversal + rotate-by-1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft import (plan_batch_block, stockham_stages,
                               twiddle_table)


def _roll1(x):
    """roll(x, 1) along the last axis via concat (gather-free for Mosaic)."""
    return jnp.concatenate([x[..., -1:], x[..., :-1]], axis=-1)


def _reverse_mod_n(xr, xi):
    """(Z_k) -> (Z_{n-k}), indices mod n: flip then rotate so k=0 stays."""
    return _roll1(jnp.flip(xr, axis=-1)), _roll1(jnp.flip(xi, axis=-1))


def _polymul_complex_kernel(wr_ref, wi_ref, ar_ref, ai_ref, br_ref, bi_ref,
                            cr_ref, ci_ref, *, n: int, radix: int):
    wr = wr_ref[...]
    wi = wi_ref[...]
    ar = ar_ref[...].astype(jnp.float32)
    ai = ai_ref[...].astype(jnp.float32)
    br = br_ref[...].astype(jnp.float32)
    bi = bi_ref[...].astype(jnp.float32)
    far, fai = stockham_stages(ar, ai, wr, wi, n=n, inverse=False, radix=radix)
    fbr, fbi = stockham_stages(br, bi, wr, wi, n=n, inverse=False, radix=radix)
    pr = far * fbr - fai * fbi
    pi = far * fbi + fai * fbr
    # Inverse transform with the conjugated table: conj(FFT(conj(.)))/n.
    cr, ci = stockham_stages(pr, -pi, wr, wi, n=n, inverse=False, radix=radix)
    inv = 1.0 / n
    cr_ref[...] = (cr * inv).astype(cr_ref.dtype)
    ci_ref[...] = (-ci * inv).astype(ci_ref.dtype)


def _polymul_real_kernel(wr_ref, wi_ref, a_ref, b_ref, c_ref, *,
                         n: int, radix: int):
    """Real-coefficient polymul with Eq. (10) packing: ONE forward FFT."""
    wr = wr_ref[...]
    wi = wi_ref[...]
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # z = a + i b ; Z = FFT(z)
    zr, zi = stockham_stages(a, b, wr, wi, n=n, inverse=False, radix=radix)
    zrr, zri = _reverse_mod_n(zr, zi)          # Z_{n-k}
    # A_k = (conj(Z_{n-k}) + Z_k)/2 ; B_k = i (conj(Z_{n-k}) - Z_k)/2
    far = 0.5 * (zrr + zr)
    fai = 0.5 * (-zri + zi)
    # i * ((zrr - zr) + i(-zri - zi)) = (zri + zi) + i (zrr - zr)
    fbr = 0.5 * (zri + zi)
    fbi = 0.5 * (zrr - zr)
    pr = far * fbr - fai * fbi
    pi = far * fbi + fai * fbr
    cr, ci = stockham_stages(pr, -pi, wr, wi, n=n, inverse=False, radix=radix)
    del ci  # product of real polys is real; imag is numerical noise
    c_ref[...] = (cr * (1.0 / n)).astype(c_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("radix", "interpret", "block_b"))
def polymul_complex_planes(ar, ai, br, bi, *, radix: int = 2,
                           interpret: bool = True, block_b: int | None = None):
    """Circular (mod x^n - 1) product of complex coefficient vectors (B, n)."""
    assert ar.shape == ai.shape == br.shape == bi.shape and ar.ndim == 2
    b, n = ar.shape
    blk = block_b or max(1, plan_batch_block(n) // 2)  # 3 transforms live
    pad = (-b) % blk
    if pad:
        ar, ai, br, bi = (jnp.pad(v, ((0, pad), (0, 0))) for v in (ar, ai, br, bi))
    bp = ar.shape[0]
    wr_np, wi_np = twiddle_table(n)
    kern = functools.partial(_polymul_complex_kernel, n=n, radix=radix)
    bspec = pl.BlockSpec((blk, n), lambda i: (i, 0))
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    cr, ci = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[wspec, wspec, bspec, bspec, bspec, bspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct((bp, n), ar.dtype),
                   jax.ShapeDtypeStruct((bp, n), ar.dtype)],
        interpret=interpret,
    )(jnp.asarray(wr_np), jnp.asarray(wi_np), ar, ai, br, bi)
    if pad:
        cr, ci = cr[:b], ci[:b]
    return cr, ci


@functools.partial(jax.jit,
                   static_argnames=("radix", "interpret", "block_b"))
def polymul_real_planes(a, b, *, radix: int = 2, interpret: bool = True,
                        block_b: int | None = None):
    """Circular product of REAL coefficient vectors (B, n) via Eq. (10).

    Two forward transforms are folded into one complex FFT; with the inverse
    transform that is 2 FFT-equivalents instead of 3 (the paper's §5
    optimization, which is why its real-polymul speedups exceed its FFT
    speedups).
    """
    assert a.shape == b.shape and a.ndim == 2
    bsz, n = a.shape
    blk = block_b or max(1, plan_batch_block(n) // 2)
    pad = (-bsz) % blk
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    bp = a.shape[0]
    wr_np, wi_np = twiddle_table(n)
    kern = functools.partial(_polymul_real_kernel, n=n, radix=radix)
    bspec = pl.BlockSpec((blk, n), lambda i: (i, 0))
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    c = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[wspec, wspec, bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct((bp, n), a.dtype),
        interpret=interpret,
    )(jnp.asarray(wr_np), jnp.asarray(wi_np), a, b)
    if pad:
        c = c[:bsz]
    return c
