"""Pallas TPU kernel: fused polynomial multiplication via the convolution
theorem (paper §5), one VMEM residency for the entire FFT -> product -> IFFT.

Paper correspondences:

* Eq. (9): C = IDFT(DFT(a) . DFT(b)) — the kernel computes both forward
  transforms, the pointwise product, and the inverse transform without ever
  leaving VMEM. cuFFT (and an unfused XLA graph) pays 3x the HBM traffic
  (two FFTs, a pointwise pass, an IFFT each round-trip memory); the paper
  makes the same observation about the GPU's element-wise multiply being
  memory-bound (§6, last paragraph) — fusion is the TPU-native counterpart.
* Input-permutation cancellation: the paper skips the FFT/IFFT bit-reversal
  permutations because they cancel across DFT.IDFT. Stockham autosort has no
  explicit permutation to begin with; the property holds structurally.
* Eq. (10) real packing: two real-coefficient transforms from one complex
  FFT via z = a + i b, unpacked with conjugate symmetry. The paper's PIM
  tricks map as: conjugate = sign flip on the imag plane; multiply by i =
  plane swap + sign flip; divide by 2 = scalar multiply (PIM decrements the
  exponent; the VPU just multiplies); Z_{n-k} = lane reversal + rotate-by-1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft import (_fit_block, hermitian_split, plan_batch_block,
                               stockham_stages, twiddle_table)


def _polymul_complex_kernel(wr_ref, wi_ref, ar_ref, ai_ref, br_ref, bi_ref,
                            cr_ref, ci_ref, *, n: int, radix: int):
    wr = wr_ref[...]
    wi = wi_ref[...]
    ar = ar_ref[...].astype(jnp.float32)
    ai = ai_ref[...].astype(jnp.float32)
    br = br_ref[...].astype(jnp.float32)
    bi = bi_ref[...].astype(jnp.float32)
    far, fai = stockham_stages(ar, ai, wr, wi, n=n, inverse=False, radix=radix)
    fbr, fbi = stockham_stages(br, bi, wr, wi, n=n, inverse=False, radix=radix)
    pr = far * fbr - fai * fbi
    pi = far * fbi + fai * fbr
    # Inverse transform with the conjugated table: conj(FFT(conj(.)))/n.
    cr, ci = stockham_stages(pr, -pi, wr, wi, n=n, inverse=False, radix=radix)
    inv = 1.0 / n
    cr_ref[...] = (cr * inv).astype(cr_ref.dtype)
    ci_ref[...] = (-ci * inv).astype(ci_ref.dtype)


def _polymul_real_kernel(wr_ref, wi_ref, wir_ref, wii_ref, a_ref, b_ref,
                         c_ref, *, n: int, radix: int):
    """Real-coefficient polymul: ONE forward FFT per product (Eq. (10)
    packing z = a + i b) and ONE inverse FFT per PAIR of products.

    The product spectrum P = A·B of two Hermitian spectra is exactly
    Hermitian (``hermitian_split`` is component-exact under conjugation), so
    IFFT(P) is real and two products can share an inverse transform:
    Q = P_{2j} + i P_{2j+1}, c_{2j} = Re IFFT(Q), c_{2j+1} = Im IFFT(Q).
    Butterfly work per product: 1 forward + 1/2 inverse = 1.5
    complex-transform-equivalents vs the complex kernel's 3.
    """
    blk = a_ref.shape[0]
    wr = wr_ref[...]
    wi = wi_ref[...]
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # z = a + i b ; Z = FFT(z); Hermitian split -> A = FFT(a), B = FFT(b)
    zr, zi = stockham_stages(a, b, wr, wi, n=n, inverse=False, radix=radix)
    far, fai, fbr, fbi = hermitian_split(zr, zi)
    pr = far * fbr - fai * fbi
    pi = far * fbi + fai * fbr
    # Pair rows for the inverse: Q = P_even + i P_odd.
    pr = pr.reshape(blk // 2, 2, n)
    pi = pi.reshape(blk // 2, 2, n)
    qr = pr[:, 0] - pi[:, 1]
    qi = pi[:, 0] + pr[:, 1]
    cr, ci = stockham_stages(qr, qi, wir_ref[...], wii_ref[...], n=n,
                             inverse=True, radix=radix)
    c_ref[...] = jnp.stack([cr, ci], axis=1).reshape(blk, n).astype(
        c_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("radix", "interpret", "block_b"))
def polymul_complex_planes(ar, ai, br, bi, *, radix: int = 2,
                           interpret: bool = True, block_b: int | None = None):
    """Circular (mod x^n - 1) product of complex coefficient vectors (B, n)."""
    assert ar.shape == ai.shape == br.shape == bi.shape and ar.ndim == 2
    b, n = ar.shape
    # 3 transforms live; clamp to the actual batch (no padding past b).
    blk = block_b or _fit_block(max(1, plan_batch_block(n) // 2), b)
    pad = (-b) % blk
    if pad:
        ar, ai, br, bi = (jnp.pad(v, ((0, pad), (0, 0))) for v in (ar, ai, br, bi))
    bp = ar.shape[0]
    wr_np, wi_np = twiddle_table(n)
    kern = functools.partial(_polymul_complex_kernel, n=n, radix=radix)
    bspec = pl.BlockSpec((blk, n), lambda i: (i, 0))
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    cr, ci = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[wspec, wspec, bspec, bspec, bspec, bspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct((bp, n), ar.dtype),
                   jax.ShapeDtypeStruct((bp, n), ar.dtype)],
        interpret=interpret,
    )(jnp.asarray(wr_np), jnp.asarray(wi_np), ar, ai, br, bi)
    if pad:
        cr, ci = cr[:b], ci[:b]
    return cr, ci


@functools.partial(jax.jit,
                   static_argnames=("radix", "interpret", "block_b"))
def polymul_real_planes(a, b, *, radix: int = 2, interpret: bool = True,
                        block_b: int | None = None):
    """Circular product of REAL coefficient vectors (B, n) via Eq. (10).

    Two forward transforms fold into one complex FFT per product, and two
    products share each inverse transform (Hermitian pairing) — 1.5
    FFT-equivalents per product instead of the complex path's 3 (the
    paper's §5 optimization plus the batch-paired inverse, which is why the
    real-polymul speedups exceed the FFT speedups). The halved working set
    also buys the doubled real-mode batch block (twice the rows per VMEM
    residency of ``polymul_complex_planes``).
    """
    assert a.shape == b.shape and a.ndim == 2
    bsz, n = a.shape
    blk = block_b or _fit_block(max(2, plan_batch_block(n, real=True) // 2),
                                bsz, even=True)
    assert blk % 2 == 0, f"paired inverse needs an even block, got {blk}"
    pad = (-bsz) % blk
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    bp = a.shape[0]
    wr_np, wi_np = twiddle_table(n)
    wir_np, wii_np = twiddle_table(n, inverse=True)
    kern = functools.partial(_polymul_real_kernel, n=n, radix=radix)
    bspec = pl.BlockSpec((blk, n), lambda i: (i, 0))
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    c = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[wspec, wspec, wspec, wspec, bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct((bp, n), a.dtype),
        interpret=interpret,
    )(jnp.asarray(wr_np), jnp.asarray(wi_np), jnp.asarray(wir_np),
      jnp.asarray(wii_np), a, b)
    if pad:
        c = c[:bsz]
    return c
