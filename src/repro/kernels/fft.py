"""Pallas TPU kernel: batched Stockham FFT, all stages resident in VMEM.

This is the TPU adaptation of FourierPIM's in-memory FFT (paper §4):

* The paper keeps the whole transform inside a memristive crossbar so no data
  ever moves to a compute unit. Here the whole transform stays inside VMEM:
  one HBM read of the input block, ``log_radix n`` butterfly sweeps on VMEM
  values, one HBM write. An XLA/cuFFT-style implementation round-trips HBM
  once per fused stage group; for the memory-bound FFT regime (Fig. 1 of the
  paper) this single-residency property is the entire win.
* The paper's element-parallel butterfly (§4.2, one butterfly per crossbar
  row) maps to the batch dimension living on sublanes: every (batch-row,
  lane-column) performs an independent butterfly per VPU instruction.
* The paper's r/2r layout dance (§4.3–4.4, snake order + in-place swaps to
  avoid an intermediate representation) maps to the Stockham autosort
  schedule: contiguous strided slices only, no bit-reversal permutation, no
  gathers — the same "never leave the array, never reorder through memory"
  property in the layout natural to a vector unit.
* The paper's partitions (§4.5, more parallel column-units per array) map to
  the radix-4 path: twice the butterflies retired per sweep, halving the
  number of sweeps, which is the same lever (more parallel work per step).

Complex data is carried as split real/imag planes (SoA): a memristor row can
concatenate bit-fields at zero cost, a VPU cannot (DESIGN.md §2).

Stockham invariant (see kernels/ref.py::fft_stockham for the jnp oracle):
  A_t has shape (B, L, r), L = radix^t-ish, r = n / L, and column q of A_t is
  FFT_L of the decimated subsequence x[q :: r].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _log2(n: int) -> int:
    assert n & (n - 1) == 0 and n > 0, f"n={n} must be a power of two"
    return n.bit_length() - 1


def twiddle_table(n: int, *, inverse: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Master twiddle table W[k] = exp(sign * 2 pi i k / n), k < n, fp32 planes.

    Every stage's twiddles are static strided slices of this one table
    (stage L uses W[:: n/(2L)][:L]), so a single (1, n) pair serves the whole
    kernel. Computed in float64 then rounded once to fp32.
    """
    k = np.arange(n, dtype=np.float64)
    sign = 1.0 if inverse else -1.0
    ang = sign * 2.0 * np.pi * k / n
    return (np.cos(ang).astype(np.float32)[None, :],
            np.sin(ang).astype(np.float32)[None, :])


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _mul_i(r, i, sign: float):
    """(r + i j) * (sign * j):  sign=-1 -> multiply by -i, sign=+1 -> by +i."""
    if sign < 0:
        return i, -r
    return -i, r


def _radix2_stage(yr, yi, wr, wi, L, r, n):
    """One Stockham radix-2 sweep: (B, L, r) -> (B, 2L, r/2)."""
    half = r // 2
    er, ei = yr[:, :, :half], yi[:, :, :half]
    orr, oii = yr[:, :, half:], yi[:, :, half:]
    stride = n // (2 * L)
    twr = jax.lax.slice_in_dim(wr, 0, L * stride, stride, axis=1)  # (1, L)
    twi = jax.lax.slice_in_dim(wi, 0, L * stride, stride, axis=1)
    twr = twr[:, :, None]  # (1, L, 1) broadcast over batch and q
    twi = twi[:, :, None]
    tr, ti = _cmul(twr, twi, orr, oii)
    outr = jnp.concatenate([er + tr, er - tr], axis=1)
    outi = jnp.concatenate([ei + ti, ei - ti], axis=1)
    return outr, outi


def _radix4_stage(yr, yi, wr, wi, L, r, n, sign: float):
    """One Stockham radix-4 sweep: (B, L, r) -> (B, 4L, r/4).

    U[l + m L'] = sum_j w4^{jm} T_j,  T_j = W_n^{j l (n/4L)} E_j[l],
    with E_j = A[:, :, j*r/4 : (j+1)*r/4] and L' = 4L blocks concatenated.
    """
    q = r // 4
    s = n // (4 * L)
    e = [(yr[:, :, j * q:(j + 1) * q], yi[:, :, j * q:(j + 1) * q]) for j in range(4)]
    t = [e[0]]
    for j in (1, 2, 3):
        twr = jax.lax.slice_in_dim(wr, 0, L * j * s, j * s, axis=1)[:, :, None]
        twi = jax.lax.slice_in_dim(wi, 0, L * j * s, j * s, axis=1)[:, :, None]
        t.append(_cmul(twr, twi, e[j][0], e[j][1]))
    (t0r, t0i), (t1r, t1i), (t2r, t2i), (t3r, t3i) = t
    # Even/odd regroup
    a_r, a_i = t0r + t2r, t0i + t2i      # T0 + T2
    b_r, b_i = t0r - t2r, t0i - t2i      # T0 - T2
    c_r, c_i = t1r + t3r, t1i + t3i      # T1 + T3
    d_r, d_i = t1r - t3r, t1i - t3i      # T1 - T3
    id_r, id_i = _mul_i(d_r, d_i, sign)  # (+-i)(T1 - T3)
    outr = jnp.concatenate([a_r + c_r, b_r + id_r, a_r - c_r, b_r - id_r], axis=1)
    outi = jnp.concatenate([a_i + c_i, b_i + id_i, a_i - c_i, b_i - id_i], axis=1)
    return outr, outi


def stockham_stages(xr, xi, wr, wi, *, n: int, inverse: bool, radix: int,
                    scale: float | None = None):
    """Run all Stockham sweeps on values already resident in VMEM/registers.

    xr, xi: (B_blk, n) fp32. wr, wi: (1, n) fp32 master table (already
    conjugated for inverse). Returns (B_blk, n) planes.
    """
    sign = 1.0 if inverse else -1.0
    b = xr.shape[0]
    yr = xr.reshape(b, 1, n)
    yi = xi.reshape(b, 1, n)
    L, r = 1, n
    stages2 = _log2(n)
    if radix == 4 and stages2 % 2 == 1:
        yr, yi = _radix2_stage(yr, yi, wr, wi, L, r, n)
        L, r = 2 * L, r // 2
    while r > 1:
        if radix == 4 and r % 4 == 0:
            yr, yi = _radix4_stage(yr, yi, wr, wi, L, r, n, sign)
            L, r = 4 * L, r // 4
        else:
            yr, yi = _radix2_stage(yr, yi, wr, wi, L, r, n)
            L, r = 2 * L, r // 2
    yr = yr.reshape(b, n)
    yi = yi.reshape(b, n)
    if inverse:
        inv = 1.0 / n if scale is None else scale / n
        yr, yi = yr * inv, yi * inv
    elif scale is not None:
        yr, yi = yr * scale, yi * scale
    return yr, yi


def _fft_kernel(wr_ref, wi_ref, xr_ref, xi_ref, or_ref, oi_ref, *,
                n: int, inverse: bool, radix: int):
    xr = xr_ref[...].astype(jnp.float32)
    xi = xi_ref[...].astype(jnp.float32)
    wr = wr_ref[...]
    wi = wi_ref[...]
    yr, yi = stockham_stages(xr, xi, wr, wi, n=n, inverse=inverse, radix=radix)
    or_ref[...] = yr.astype(or_ref.dtype)
    oi_ref[...] = yi.astype(oi_ref.dtype)


# ---------------------------------------------------------------------------
# VMEM planning: pick the batch block so the working set fits.
# ---------------------------------------------------------------------------

VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of v5e's 16 MB VMEM
_LIVE_FACTOR = 4  # in+out planes plus stage temporaries, empirically safe


def plan_batch_block(n: int, max_block: int = 1024, *,
                     real: bool = False) -> int:
    """Largest power-of-two batch block whose fp32 working set fits VMEM.

    ``real=True`` is the two-for-one packed mode (rfft/irfft/real polymul):
    two real rows share one complex working row, so the per-row footprint
    halves and both the VMEM-derived block and its cap double — the batch
    block the halved working set buys (paper Eq. (10): area halves, batch
    doubles).
    """
    planes = 1 if real else 2
    per_row = planes * n * 4 * _LIVE_FACTOR  # fp32 planes, live copies
    if real:
        max_block *= 2
    blk = VMEM_BUDGET_BYTES // per_row
    blk = max(2 if real else 1, min(max_block, blk))
    return 1 << (blk.bit_length() - 1)


def _fit_block(blk: int, batch: int, *, even: bool = False) -> int:
    """Shrink a planned batch block to the actual batch (next power of two
    >= batch) so small batches don't zero-pad to the full VMEM block — the
    planned block is a CAP from the VMEM budget, not a minimum.
    ``even=True`` keeps the two-for-one pairing invariant."""
    cap = 1 << max(0, batch - 1).bit_length()
    blk = min(blk, max(1, cap))
    return max(2, blk) if even else blk


# ---------------------------------------------------------------------------
# Real-input fast path: two-for-one packed rfft / irfft (paper Eq. (10)).
#
# Two real rows ride ONE complex transform as z = a + i b; conjugate symmetry
# recovers both spectra. For real input the spectrum is Hermitian, so only
# n/2+1 bins carry information — stored in the packed-Nyquist layout
# (n/2 complex bins, power-of-two lane widths):
#
#   P[0] = X[0].re + i * X[n/2].re     (DC and Nyquist are both real)
#   P[k] = X[k]                        (1 <= k < n/2)
#
# The split/pack happens INSIDE the kernel: the half-spectrum never
# round-trips HBM at full width, halving both butterfly work (half the
# complex rows) and HBM traffic (half the output planes) vs. running the
# complex kernel on zero-imag input.
# ---------------------------------------------------------------------------

def _roll1(x):
    """roll(x, 1) along the last axis via concat (gather-free for Mosaic)."""
    return jnp.concatenate([x[..., -1:], x[..., :-1]], axis=-1)


def _reverse_mod_n(xr, xi):
    """(Z_k) -> (Z_{n-k}), indices mod n: flip then rotate so k=0 stays."""
    return _roll1(jnp.flip(xr, axis=-1)), _roll1(jnp.flip(xi, axis=-1))


def hermitian_split(zr, zi):
    """Split Z = FFT(a + i b) of two real rows into their spectra (Eq. (10)).

    A_k = (Z_k + conj(Z_{n-k})) / 2,  B_k = -i (Z_k - conj(Z_{n-k})) / 2.
    The results are EXACTLY Hermitian in fp32 (each component of A_{n-k} is
    the same float expression as ±A_k's), which is what lets the paired
    inverse in kernels/polymul.py split two real products per transform.
    """
    zrr, zri = _reverse_mod_n(zr, zi)
    ar = 0.5 * (zrr + zr)
    ai = 0.5 * (-zri + zi)
    br = 0.5 * (zri + zi)
    bi = 0.5 * (zrr - zr)
    return ar, ai, br, bi


def _pack_half(sr, si, nh: int):
    """Full Hermitian spectrum planes (B, n) -> packed-Nyquist (B, nh)."""
    pr = sr[:, :nh]
    pi = jnp.concatenate([sr[:, nh:nh + 1], si[:, 1:nh]], axis=1)
    return pr, pi


def _unpack_full(pr, pi, n: int):
    """Packed-Nyquist half-spectrum (B, n/2) -> full Hermitian planes (B, n).

    Mirror bins k in (n/2, n) are conj(P[n-k]); DC/Nyquist imag parts are
    structurally zero. Concat/flip only — gather-free for Mosaic.
    """
    nh = n // 2
    zero = jnp.zeros_like(pr[:, :1])
    head_i = jnp.concatenate([zero, pi[:, 1:]], axis=1)        # im, bins < n/2
    tail_r = jnp.flip(pr[:, 1:], axis=1)                       # re, bins > n/2
    tail_i = -jnp.flip(pi[:, 1:], axis=1)
    fr = jnp.concatenate([pr, pi[:, :1], tail_r], axis=1)      # Nyquist at n/2
    fi = jnp.concatenate([head_i, zero, tail_i], axis=1)
    return fr, fi


def _rfft_kernel(wr_ref, wi_ref, x_ref, or_ref, oi_ref, *, n: int, radix: int):
    blk = x_ref.shape[0]
    nh = n // 2
    x = x_ref[...].astype(jnp.float32).reshape(blk // 2, 2, n)
    zr, zi = stockham_stages(x[:, 0, :], x[:, 1, :], wr_ref[...], wi_ref[...],
                             n=n, inverse=False, radix=radix)
    ar, ai, br, bi = hermitian_split(zr, zi)
    par, pai = _pack_half(ar, ai, nh)
    pbr, pbi = _pack_half(br, bi, nh)
    or_ref[...] = jnp.stack([par, pbr], axis=1).reshape(blk, nh).astype(
        or_ref.dtype)
    oi_ref[...] = jnp.stack([pai, pbi], axis=1).reshape(blk, nh).astype(
        oi_ref.dtype)


def _irfft_kernel(wr_ref, wi_ref, xr_ref, xi_ref, o_ref, *, n: int,
                  radix: int):
    blk = xr_ref.shape[0]
    nh = n // 2
    xr = xr_ref[...].astype(jnp.float32).reshape(blk // 2, 2, nh)
    xi = xi_ref[...].astype(jnp.float32).reshape(blk // 2, 2, nh)
    ar, ai = _unpack_full(xr[:, 0], xi[:, 0], n)
    br, bi = _unpack_full(xr[:, 1], xi[:, 1], n)
    # Linearity: IFFT(A + i B) = a + i b for real rows a, b.
    yr, yi = stockham_stages(ar - bi, ai + br, wr_ref[...], wi_ref[...],
                             n=n, inverse=True, radix=radix)
    o_ref[...] = jnp.stack([yr, yi], axis=1).reshape(blk, n).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("radix", "interpret", "block_b"))
def rfft_planes(x: jax.Array, *, radix: int = 2, interpret: bool = True,
                block_b: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Packed half-spectrum FFT of real rows: (B, n) -> planes (B, n//2).

    Grid/tiling contract matches ``fft_planes`` with the real-mode batch
    block (doubled: half the working set per row). The batch is zero-padded
    to the (even) block, so odd batches are fine.
    """
    assert x.ndim == 2, f"expected (batch, n), got {x.shape}"
    b, n = x.shape
    assert n >= 2 and n & (n - 1) == 0, f"n={n} must be a power of two >= 2"
    blk = block_b or _fit_block(plan_batch_block(n, real=True), b, even=True)
    assert blk % 2 == 0, f"two-for-one packing needs an even block, got {blk}"
    pad = (-b) % blk
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    bp = x.shape[0]
    nh = n // 2
    wr_np, wi_np = twiddle_table(n)
    kern = functools.partial(_rfft_kernel, n=n, radix=radix)
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    yr, yi = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[wspec, wspec,
                  pl.BlockSpec((blk, n), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((blk, nh), lambda i: (i, 0)),
                   pl.BlockSpec((blk, nh), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((bp, nh), x.dtype),
                   jax.ShapeDtypeStruct((bp, nh), x.dtype)],
        interpret=interpret,
    )(jnp.asarray(wr_np), jnp.asarray(wi_np), x)
    if pad:
        yr, yi = yr[:b], yi[:b]
    return yr, yi


@functools.partial(jax.jit, static_argnames=("radix", "interpret", "block_b"))
def irfft_planes(xr: jax.Array, xi: jax.Array, *, radix: int = 2,
                 interpret: bool = True,
                 block_b: int | None = None) -> jax.Array:
    """Inverse of ``rfft_planes``: packed planes (B, n//2) -> real (B, n).

    Two packed half-spectra are re-mirrored to full Hermitian spectra inside
    the kernel and ride ONE inverse complex transform (Z = A + i B), so the
    butterfly count matches the forward path.
    """
    assert xr.shape == xi.shape and xr.ndim == 2, (xr.shape, xi.shape)
    b, nh = xr.shape
    n = 2 * nh
    assert n & (n - 1) == 0, f"n={n} must be a power of two"
    blk = block_b or _fit_block(plan_batch_block(n, real=True), b, even=True)
    assert blk % 2 == 0, f"two-for-one packing needs an even block, got {blk}"
    pad = (-b) % blk
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    bp = xr.shape[0]
    wr_np, wi_np = twiddle_table(n, inverse=True)
    kern = functools.partial(_irfft_kernel, n=n, radix=radix)
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    y = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[wspec, wspec,
                  pl.BlockSpec((blk, nh), lambda i: (i, 0)),
                  pl.BlockSpec((blk, nh), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, n), xr.dtype),
        interpret=interpret,
    )(jnp.asarray(wr_np), jnp.asarray(wi_np), xr, xi)
    return y[:b] if pad else y


@functools.partial(jax.jit, static_argnames=("inverse", "radix", "interpret", "block_b"))
def fft_planes(xr: jax.Array, xi: jax.Array, *, inverse: bool = False,
               radix: int = 2, interpret: bool = True,
               block_b: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Batched FFT on split planes. xr, xi: (B, n). Returns planes (B, n).

    The pallas_call tiles the batch: grid=(B/B_blk,), each program transforms
    its (B_blk, n) block entirely in VMEM (one HBM read + one HBM write).
    """
    assert xr.shape == xi.shape and xr.ndim == 2
    b, n = xr.shape
    blk = block_b or _fit_block(plan_batch_block(n), b)
    pad = (-b) % blk
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    bp = xr.shape[0]
    wr_np, wi_np = twiddle_table(n, inverse=inverse)
    wr = jnp.asarray(wr_np)
    wi = jnp.asarray(wi_np)
    out_dtype = xr.dtype
    kern = functools.partial(_fft_kernel, n=n, inverse=inverse, radix=radix)
    yr, yi = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),   # twiddle real (broadcast)
            pl.BlockSpec((1, n), lambda i: (0, 0)),   # twiddle imag
            pl.BlockSpec((blk, n), lambda i: (i, 0)),  # x real
            pl.BlockSpec((blk, n), lambda i: (i, 0)),  # x imag
        ],
        out_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, n), out_dtype),
            jax.ShapeDtypeStruct((bp, n), out_dtype),
        ],
        interpret=interpret,
    )(wr, wi, xr, xi)
    if pad:
        yr, yi = yr[:b], yi[:b]
    return yr, yi
