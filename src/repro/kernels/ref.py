"""Pure-jnp oracles for the Fourier kernels.

Every Pallas kernel in this package is validated against these references in
``tests/test_kernels_fft.py``. The references are deliberately written three
independent ways (naive Vandermonde DFT, recursive radix-2 FFT, and an
iterative Stockham in plain jnp) so a bug shared by the kernel and one oracle
cannot hide.

Complex values are carried as jnp complex64/complex128 here; the kernels use
split real/imag planes (see DESIGN.md §2 — SoA adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Naive O(n^2) DFT — the ground truth (Eq. (1)/(2) of the paper).
# ---------------------------------------------------------------------------

def dft_matrix(n: int, *, inverse: bool = False, dtype=jnp.complex64) -> jax.Array:
    """Vandermonde matrix W[j, k] = omega_n^{j k},  omega_n = e^{-2 pi i / n}."""
    k = np.arange(n)
    sign = 1.0 if inverse else -1.0
    w = np.exp(sign * 2j * np.pi * np.outer(k, k) / n)
    return jnp.asarray(w, dtype=dtype)


def dft(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Naive DFT via matmul; x shape (..., n)."""
    n = x.shape[-1]
    x = x.astype(jnp.complex64)
    y = x @ dft_matrix(n, inverse=inverse).T
    if inverse:
        y = y / n
    return y


# ---------------------------------------------------------------------------
# Recursive radix-2 FFT (Eq. (3) of the paper) — textbook divide and conquer.
# ---------------------------------------------------------------------------

def fft_recursive(x: jax.Array) -> jax.Array:
    """Recursive decimation-in-time FFT; x shape (..., n), n a power of two."""
    n = x.shape[-1]
    if n == 1:
        return x.astype(jnp.complex64)
    assert n % 2 == 0, f"n={n} is not a power of two"
    even = fft_recursive(x[..., 0::2])
    odd = fft_recursive(x[..., 1::2])
    k = jnp.arange(n // 2)
    w = jnp.exp(-2j * jnp.pi * k / n).astype(jnp.complex64)
    t = w * odd
    return jnp.concatenate([even + t, even - t], axis=-1)


def ifft_recursive(x: jax.Array) -> jax.Array:
    n = x.shape[-1]
    return jnp.conj(fft_recursive(jnp.conj(x))) / n


# ---------------------------------------------------------------------------
# Iterative Stockham autosort FFT in plain jnp.
#
# This is the exact dataflow the Pallas kernel implements (kernels/fft.py) and
# also serves as the fast pure-XLA fallback used for CPU execution paths where
# interpret-mode Pallas would be too slow (e.g. examples/train_lm.py).
#
# Invariant: A_t has shape (..., L, r) with L = 2^t, r = n / 2^t and
#   A_t[..., l, q] = FFT_{L}( x[q :: r] )[l].
# Transition (DIT split of each length-2L subsequence into even/odd parts):
#   E = A_t[..., :, :r/2],  O = A_t[..., :, r/2:]
#   A_{t+1}[..., l,     q] = E[..., l, q] + w_l O[..., l, q]
#   A_{t+1}[..., l + L, q] = E[..., l, q] - w_l O[..., l, q]
# with w_l = exp(-2 pi i l / 2L). No bit-reversal permutation is ever applied
# — the paper's r/2r "avoid the intermediate representation" goal, in the
# layout natural to vector hardware (DESIGN.md §2).
# ---------------------------------------------------------------------------

def fft_stockham(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"n={n} must be a power of two"
    batch = x.shape[:-1]
    sign = 1.0 if inverse else -1.0
    y = x.astype(jnp.complex64).reshape(*batch, 1, n)
    L, r = 1, n
    while r > 1:
        half = r // 2
        e = y[..., :, :half]
        o = y[..., :, half:]
        w = jnp.exp(sign * 2j * jnp.pi * jnp.arange(L) / (2 * L)).astype(jnp.complex64)
        w = w[..., :, None]
        t = w * o
        y = jnp.concatenate([e + t, e - t], axis=-2)
        L, r = 2 * L, half
    y = y.reshape(*batch, n)
    if inverse:
        y = y / n
    return y


def ifft_stockham(x: jax.Array) -> jax.Array:
    return fft_stockham(x, inverse=True)


# ---------------------------------------------------------------------------
# Convolution / polynomial multiplication references (paper §5).
# ---------------------------------------------------------------------------

def convolve_direct(a: jax.Array, b: jax.Array) -> jax.Array:
    """Full linear convolution, O(n^2), via explicit sum. a,b shape (..., n)."""
    n = a.shape[-1]
    m = b.shape[-1]
    out_len = n + m - 1
    a64 = a.astype(jnp.float64) if a.dtype in (jnp.float32, jnp.float64) else a.astype(jnp.complex128)
    b64 = b.astype(a64.dtype)
    # out[k] = sum_j a[j] b[k - j]
    pads = [(0, 0)] * (a.ndim - 1) + [(0, out_len - n)]
    a_p = jnp.pad(a64, pads)
    rows = jnp.stack([jnp.roll(a_p, s, axis=-1) for s in range(m)], axis=-2)  # (..., m, out_len)
    out = jnp.einsum("...m,...ml->...l", b64, rows)
    return out


def polymul_circular_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Circular (mod x^n - 1) product via the convolution theorem with oracle DFTs."""
    fa = dft(a)
    fb = dft(b)
    return dft(fa * fb, inverse=True)


def polymul_linear_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Full product of degree-(n-1) polys: zero-pad to 2n then circular multiply.

    Matches the paper's footnote 4: pad with n zeros for degree up to 2n.
    Output length 2n (last coefficient is structurally zero).
    """
    n = a.shape[-1]
    pads = [(0, 0)] * (a.ndim - 1) + [(0, n)]
    return polymul_circular_ref(jnp.pad(a, pads), jnp.pad(b, pads))


# ---------------------------------------------------------------------------
# Real-packing (paper Eq. (10)): two real FFTs from one complex FFT.
# ---------------------------------------------------------------------------

def realpack_fft_ref(x: jax.Array, y: jax.Array) -> tuple[jax.Array, jax.Array]:
    """FFTs of two real sequences via one complex FFT of z = x + i y.

    X_k = (conj(Z_{n-k}) + Z_k) / 2,   Y_k = i (conj(Z_{n-k}) - Z_k) / 2.
    (Indices mod n: Z_{n-0} := Z_0.)
    """
    z = x.astype(jnp.complex64) + 1j * y.astype(jnp.complex64)
    zf = dft(z)
    zrev = jnp.roll(jnp.flip(zf, axis=-1), 1, axis=-1)  # Z_{n-k}
    xk = 0.5 * (jnp.conj(zrev) + zf)
    yk = 0.5j * (jnp.conj(zrev) - zf)
    return xk, yk


# ---------------------------------------------------------------------------
# FFT-based long convolution (used by models/layers/fourier.py).
# ---------------------------------------------------------------------------

def fft_causal_conv_ref(x: jax.Array, k: jax.Array) -> jax.Array:
    """Causal depthwise convolution y[t] = sum_{s<=t} k[s] x[t-s], oracle version.

    x: (..., T), k: (..., T) (kernel padded/truncated to T taps).
    """
    T = x.shape[-1]
    full = convolve_direct(x, k)
    return full[..., :T].astype(x.dtype)
