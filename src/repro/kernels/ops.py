"""Public jit'd wrappers over the Fourier kernels.

Backend selection
-----------------
``backend='pallas'`` runs the Pallas kernels (compiled on TPU; interpret mode
on CPU — bit-exact dataflow, Python-speed). ``backend='xla'`` runs the same
Stockham dataflow as a plain jnp program (fast on CPU, used by the model
layers and examples in this container). ``backend=None`` auto-selects:
Pallas on TPU, XLA elsewhere. Override with env ``REPRO_FFT_BACKEND``.

All functions accept/return complex arrays (complex64) or real arrays where
documented; shape (..., n) with any leading batch dims, n a power of two.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import fft as _kfft
from repro.kernels import polymul as _kpoly
from repro.kernels import ref as _ref


def _auto_backend() -> str:
    env = os.environ.get("REPRO_FFT_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _as2d(x):
    n = x.shape[-1]
    return x.reshape(-1, n), x.shape[:-1]


def _pallas_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fft(x: jax.Array, *, inverse: bool = False, backend: str | None = None,
        radix: int = 2) -> jax.Array:
    """Batched FFT of a complex array (..., n)."""
    backend = backend or _auto_backend()
    if backend == "xla":
        return _ref.fft_stockham(x, inverse=inverse)
    x2, lead = _as2d(x)
    xr = jnp.real(x2).astype(jnp.float32)
    xi = jnp.imag(x2).astype(jnp.float32)
    yr, yi = _kfft.fft_planes(xr, xi, inverse=inverse, radix=radix,
                              interpret=_pallas_interpret())
    return (yr + 1j * yi).astype(jnp.complex64).reshape(*lead, x.shape[-1])


def ifft(x: jax.Array, **kw) -> jax.Array:
    return fft(x, inverse=True, **kw)


# ---------------------------------------------------------------------------
# Real-Hermitian fast path (paper Eq. (10)): rfft / irfft / polymul_real.
#
# Public layout is numpy's (..., n/2 + 1) complex half-spectrum so callers
# can diff against np.fft.rfft directly; ``packed=True`` exposes the
# kernel's packed-Nyquist layout (n/2 bins, P[0] = X[0].re + i X[n/2].re)
# without the O(n) repack — the layout that never leaves HBM at full width.
# ---------------------------------------------------------------------------

def packed_to_halfspec(yr: jax.Array, yi: jax.Array) -> jax.Array:
    """Packed-Nyquist planes (..., n/2) -> numpy-layout (..., n/2+1).

    Public: the distributed real tier (``core.fft.rfft_distributed``)
    emits the same packed layout as the local kernels, and its callers
    repack with this converter (it is the single layout definition).
    """
    zero = jnp.zeros_like(yr[..., :1])
    re = jnp.concatenate([yr, yi[..., :1]], axis=-1)
    im = jnp.concatenate([zero, yi[..., 1:], zero], axis=-1)
    return (re + 1j * im).astype(jnp.complex64)


def halfspec_to_packed(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Numpy-layout half-spectrum (..., n/2+1) -> packed planes (..., n/2)."""
    nh = x.shape[-1] - 1
    re = jnp.real(x).astype(jnp.float32)
    im = jnp.imag(x).astype(jnp.float32)
    pr = re[..., :nh]
    pi = jnp.concatenate([re[..., nh:], im[..., 1:nh]], axis=-1)
    return pr, pi


# Pre-rename aliases (the converters became public with the distributed
# real tier).
_packed_to_halfspec = packed_to_halfspec
_halfspec_to_packed = halfspec_to_packed


def rfft(x: jax.Array, *, backend: str | None = None, radix: int = 2,
         packed: bool = False):
    """FFT of a real array (..., n): half-spectrum only (Hermitian symmetry).

    Returns complex (..., n/2+1) matching ``np.fft.rfft``, or the packed
    planes ``(re, im)`` of shape (..., n/2) with ``packed=True``. The Pallas
    route runs the two-for-one kernel: two real rows per complex transform,
    half the butterflies and half the HBM traffic of ``fft`` on real input.
    """
    if jnp.iscomplexobj(x):
        raise TypeError(f"rfft needs real input, got {x.dtype}")
    n = x.shape[-1]
    backend = backend or _auto_backend()
    if backend == "xla":
        full = _ref.fft_stockham(x.astype(jnp.complex64))
        half = full[..., :n // 2 + 1]
        return _halfspec_to_packed(half) if packed else half
    x2, lead = _as2d(x)
    yr, yi = _kfft.rfft_planes(x2.astype(jnp.float32), radix=radix,
                               interpret=_pallas_interpret())
    yr = yr.reshape(*lead, n // 2)
    yi = yi.reshape(*lead, n // 2)
    return (yr, yi) if packed else _packed_to_halfspec(yr, yi)


def irfft(x, *, backend: str | None = None, radix: int = 2,
          packed: bool = False) -> jax.Array:
    """Inverse of ``rfft``: half-spectrum -> real (..., n).

    ``x`` is complex (..., n/2+1) (numpy layout), or the packed plane pair
    with ``packed=True``. The Pallas route re-mirrors two half-spectra per
    inverse complex transform inside the kernel.
    """
    if packed:
        pr, pi = x
        pr = jnp.asarray(pr, jnp.float32)
        pi = jnp.asarray(pi, jnp.float32)
    else:
        pr, pi = _halfspec_to_packed(x)
    n = 2 * pr.shape[-1]
    backend = backend or _auto_backend()
    if backend == "xla":
        half = _packed_to_halfspec(pr, pi)
        tail = jnp.conj(jnp.flip(half[..., 1:-1], axis=-1))
        full = jnp.concatenate([half, tail], axis=-1)
        return jnp.real(_ref.fft_stockham(full, inverse=True)).astype(
            jnp.float32)
    p2, lead = _as2d(pr)
    q2, _ = _as2d(pi)
    y = _kfft.irfft_planes(p2, q2, radix=radix,
                           interpret=_pallas_interpret())
    return y.reshape(*lead, n)


def polymul_real(a: jax.Array, b: jax.Array, *, mode: str = "linear",
                 backend: str | None = None, radix: int = 2,
                 block_b: int | None = None) -> jax.Array:
    """Polynomial product of REAL coefficient arrays — the explicit fast
    path (``polymul`` also auto-detects real input, but serving code routes
    here so the selection is visible/testable). Raises on complex input,
    then delegates to ``polymul``'s real branch (one dispatch to keep in
    sync): the fused two-for-one kernel — one forward FFT per product, one
    inverse per pair of products (1.5 transform-equivalents vs the complex
    path's 3).
    """
    if jnp.iscomplexobj(a) or jnp.iscomplexobj(b):
        raise TypeError(f"polymul_real needs real input, got "
                        f"{a.dtype}/{b.dtype}")
    return polymul(a, b, mode=mode, backend=backend, radix=radix,
                   block_b=block_b)


def polymul(a: jax.Array, b: jax.Array, *, mode: str = "linear",
            backend: str | None = None, radix: int = 2,
            block_b: int | None = None) -> jax.Array:
    """Polynomial multiplication via the convolution theorem (paper Eq. (9)).

    mode='circular': product mod x^n - 1 (length n).
    mode='linear'  : full product — inputs zero-padded to 2n (paper fn. 4);
                     returns length 2n (last coefficient structurally 0).

    Real inputs dispatch to the Eq. (10) real-packed path (one complex FFT
    for both operands); complex inputs use the three-transform path.
    """
    assert a.shape == b.shape
    n = a.shape[-1]
    if mode == "linear":
        pads = [(0, 0)] * (a.ndim - 1) + [(0, n)]
        a = jnp.pad(a, pads)
        b = jnp.pad(b, pads)
        n = 2 * n
    elif mode != "circular":
        raise ValueError(f"unknown mode {mode!r}")
    backend = backend or _auto_backend()
    real_in = not jnp.iscomplexobj(a) and not jnp.iscomplexobj(b)

    if backend == "xla":
        fa = _ref.fft_stockham(a.astype(jnp.complex64))
        fb = _ref.fft_stockham(b.astype(jnp.complex64))
        c = _ref.fft_stockham(fa * fb, inverse=True)
        return jnp.real(c).astype(jnp.float32) if real_in else c

    a2, lead = _as2d(a)
    b2, _ = _as2d(b)
    if real_in:
        c = _kpoly.polymul_real_planes(a2.astype(jnp.float32),
                                       b2.astype(jnp.float32), radix=radix,
                                       interpret=_pallas_interpret(),
                                       block_b=block_b)
        return c.reshape(*lead, n)
    cr, ci = _kpoly.polymul_complex_planes(
        jnp.real(a2).astype(jnp.float32), jnp.imag(a2).astype(jnp.float32),
        jnp.real(b2).astype(jnp.float32), jnp.imag(b2).astype(jnp.float32),
        radix=radix, interpret=_pallas_interpret(), block_b=block_b)
    return (cr + 1j * ci).astype(jnp.complex64).reshape(*lead, n)


def realpack_fft(x: jax.Array, y: jax.Array, *, backend: str | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """FFTs of two real sequences via one complex FFT (paper Eq. (10))."""
    z = x.astype(jnp.complex64) + 1j * y.astype(jnp.complex64)
    zf = fft(z, backend=backend)
    zrev = jnp.roll(jnp.flip(zf, axis=-1), 1, axis=-1)
    xk = 0.5 * (jnp.conj(zrev) + zf)
    yk = 0.5j * (jnp.conj(zrev) - zf)
    return xk, yk


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def fft2(x: jax.Array, *, inverse: bool = False,
         backend: str | None = None) -> jax.Array:
    """2-D FFT of (..., H, W) via row + column transforms of the batched
    1-D primitive (separability) — the paper's signal-processing use case
    lifted to images. H, W powers of two."""
    y = fft(x, inverse=inverse, backend=backend)          # along W
    y = jnp.swapaxes(y, -1, -2)
    y = fft(y, inverse=inverse, backend=backend)          # along H
    return jnp.swapaxes(y, -1, -2)


def fft_conv2d(img: jax.Array, kern: jax.Array, *,
               backend: str | None = None) -> jax.Array:
    """'same'-padded 2-D convolution via the convolution theorem.

    img: (..., H, W) real; kern: (kh, kw) real, kh/kw odd. O(HW log HW).
    """
    H, W = img.shape[-2:]
    kh, kw = kern.shape
    Hp = _next_pow2(H + kh)
    Wp = _next_pow2(W + kw)
    pads = [(0, 0)] * (img.ndim - 2) + [(0, Hp - H), (0, Wp - W)]
    xi = jnp.pad(img.astype(jnp.float32), pads)
    ki = jnp.pad(kern.astype(jnp.float32), ((0, Hp - kh), (0, Wp - kw)))
    fx = fft2(xi.astype(jnp.complex64), backend=backend)
    fk = fft2(ki.astype(jnp.complex64), backend=backend)
    full = jnp.real(fft2(fx * fk, inverse=True, backend=backend))
    r0, c0 = kh // 2, kw // 2
    return full[..., r0:r0 + H, c0:c0 + W].astype(img.dtype)


def fft_causal_conv(x: jax.Array, k: jax.Array, *,
                    backend: str | None = None) -> jax.Array:
    """Causal depthwise long convolution via FFT: y[t] = sum_{s<=t} k[s] x[t-s].

    x: (..., T) real signal, k: (..., K) real taps (K <= T). O(T log T) — the
    sub-quadratic primitive the model layers use for Fourier token mixing.
    Internally pads to the next power of two >= T + K to avoid wraparound.
    """
    T = x.shape[-1]
    K = k.shape[-1]
    n = _next_pow2(T + K)
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n - T)])
    kp = jnp.pad(k, [(0, 0)] * (k.ndim - 1) + [(0, n - K)])
    fa = fft(xp.astype(jnp.complex64), backend=backend)
    fb = fft(kp.astype(jnp.complex64), backend=backend)
    y = ifft(fa * fb, backend=backend)
    return jnp.real(y[..., :T]).astype(x.dtype)
