"""Pallas TPU kernel: fused flash attention (causal + sliding window).

The attention analogue of the FourierPIM adaptation used for the FFT kernel:
keep the whole online-softmax state resident in VMEM while streaming KV
blocks, so the (Sq x Sk) score matrix never exists in HBM — one HBM read of
Q/K/V and one write of O per (head, q-block).

Grid = (heads, q_blocks, kv_blocks); the kv axis is innermost and sequential
on TPU, so VMEM scratch (m, l, acc) carries the running max / normalizer /
accumulator across kv steps: initialized at j == 0, folded every step,
normalized and stored at j == nK - 1.

The model layers use the pure-JAX blockwise formulation (same dataflow, XLA
lowers the scan) for portability; this kernel is the TPU-native hot-spot
implementation, validated against kernels/ref-style oracles in
tests/test_kernels_attention.py (interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_k: int, seq_len: int, window: int,
                  causal: bool):
    h, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                 # (bk, hd)
    s = q @ k.T * (q.shape[-1] ** -0.5)              # (bq, bk)

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < seq_len
    if causal:
        valid &= kpos <= qpos
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 1 << 30, causal: bool = True,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: (H, S, hd) (fold batch/GQA groups into H upstream).

    Returns (H, S, hd). Blocks padded to bq/bk internally.
    """
    H, S, hd = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    n_q = qp.shape[1] // bq
    n_k = kp.shape[1] // bk
    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, n_k=n_k,
                             seq_len=S, window=window, causal=causal)
    out = pl.pallas_call(
        kern,
        grid=(H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running normalizer
            pltpu.VMEM((bq, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S]


def attention_ref(q, k, v, *, window: int = 1 << 30, causal: bool = True):
    """Naive oracle: full score matrix, masked softmax."""
    H, S, hd = q.shape
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    valid = jnp.ones((S, S), bool)
    if causal:
        valid = (kpos <= qpos) & (kpos > qpos - window)
    s = jnp.where(valid[None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)
