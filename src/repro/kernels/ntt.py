"""Pallas TPU kernel: batched Stockham NTT + fused modular polymul.

The exact (mod-q) counterpart of ``kernels/fft.py`` / ``kernels/polymul.py``:
the same single-VMEM-residency Stockham schedule, with the complex butterfly
replaced by a modular one in uint32 lanes. This opens the paper's §5 crypto
workload end to end — RLWE/FHE polynomial products must be bit-exact, which
the float FFT path cannot deliver.

Arithmetic strategy (all in 32-bit lanes; no 64-bit integers needed, so the
kernel runs identically under jax's default x64-disabled config and on TPU):

* Residues live in uint32, q an odd prime < 2^31 (``core.ntt.ref`` selects
  it), so sums fit without carry columns and 2q < 2^32.
* 32x32 -> 64-bit products are built from four 16x16 partial products with
  explicit carry recovery (``_mul32_full``) — the VPU analogue of AritPIM's
  bit-serial shift-and-add multiplier.
* Twiddles are stored in Montgomery form (w^k * 2^32 mod q), so each
  butterfly multiply is ONE Montgomery REDC: mont(v, w_mont) = v*w mod q.
  Data itself stays in the normal domain throughout — the same trick NTT
  libraries use so no domain conversion passes are needed.
* The fused ``ntt_polymul`` folds the negacyclic psi-twist into the input
  multiply and the psi^{-1}/n untwist into the output multiply — the exact
  analogue of ``kernels/polymul.py``'s permutation-cancellation/scaling
  fusion (paper §5): forward x2 -> pointwise modmul -> inverse, one VMEM
  residency, zero extra passes for twist/scale.

Batching reuses ``plan_batch_block`` from kernels/fft.py: a uint32 residue
plane is half the footprint of the fp32 complex planes, so the FFT's block
plan is strictly conservative here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.ntt.ref import NTTParams
from repro.kernels.fft import plan_batch_block

# Plain Python ints: weak-typed scalars stay out of the kernel closure
# (pallas_call rejects captured traced constants).
_U16 = 16
_MASK16 = 0xFFFF


# ---------------------------------------------------------------------------
# uint32 modular primitives
# ---------------------------------------------------------------------------

def _mul32_full(a, b):
    """Full 64-bit product of uint32 lanes as a (hi, lo) uint32 pair.

    Four 16x16 partials; each fits uint32 exactly. Carries recovered with
    unsigned-compare tricks (x + y wrapped iff result < x).
    """
    a0, a1 = a & _MASK16, a >> _U16
    b0, b1 = b & _MASK16, b >> _U16
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    hh = a1 * b1
    mid = lh + hl
    carry_mid = (mid < lh).astype(jnp.uint32)
    lo = ll + (mid << _U16)
    carry_lo = (lo < ll).astype(jnp.uint32)
    hi = hh + (mid >> _U16) + (carry_mid << _U16) + carry_lo
    return hi, lo


def _u32(v):
    """uint32 scalar from either a static python int or a traced value (the
    RNS kernel reads per-limb constants out of a Ref, so q/qinv/r2 arrive as
    tracers there while the single-modulus kernels keep them static)."""
    return v if isinstance(v, jax.Array) else jnp.uint32(v)


def _mont_mul(a, b, q, qinv):
    """Montgomery product a*b*2^-32 mod q (q odd, < 2^31; qinv = -q^-1 mod
    2^32). With b in Montgomery form this is a*b mod q in one REDC."""
    qq = _u32(q)
    hi, lo = _mul32_full(a, b)
    m = lo * _u32(qinv)                       # mod 2^32 wrap is the point
    mq_hi, _ = _mul32_full(m, qq)
    # lo + (m*q mod 2^32) == 0 mod 2^32 by construction: carry iff lo != 0.
    t = hi + mq_hi + (lo != 0).astype(jnp.uint32)
    return jnp.where(t >= qq, t - qq, t)      # t < 2q always


def _add_mod(a, b, q):
    qq = _u32(q)
    s = a + b                                  # a, b < q < 2^31: no wrap
    return jnp.where(s >= qq, s - qq, s)


def _sub_mod(a, b, q):
    return jnp.where(a >= b, a - b, a + _u32(q) - b)


# ---------------------------------------------------------------------------
# Stockham sweeps (mirrors kernels/fft.py::stockham_stages, radix-2)
# ---------------------------------------------------------------------------

def _ntt_radix2_stage(y, w, L, r, n, q: int, qinv: int):
    """One Stockham sweep (B, L, r) -> (B, 2L, r/2) over F_q."""
    half = r // 2
    e = y[:, :, :half]
    o = y[:, :, half:]
    stride = n // (2 * L)
    tw = jax.lax.slice_in_dim(w, 0, L * stride, stride, axis=1)[:, :, None]
    t = _mont_mul(o, tw, q, qinv)
    return jnp.concatenate([_add_mod(e, t, q), _sub_mod(e, t, q)], axis=1)


def ntt_stages(x, w, *, n: int, q: int, qinv: int):
    """All Stockham sweeps on VMEM-resident values.

    x: (B_blk, n) uint32 residues. w: (1, n) master Montgomery twiddle table
    (powers of the n-th root; of its inverse for the inverse transform).
    Output is in natural order — Stockham autosorts, so like the float
    kernel there is no bit-reversal permutation anywhere.
    """
    b = x.shape[0]
    y = x.reshape(b, 1, n)
    L, r = 1, n
    while r > 1:
        y = _ntt_radix2_stage(y, w, L, r, n, q, qinv)
        L, r = 2 * L, r // 2
    return y.reshape(b, n)


def _ntt_kernel(w_ref, x_ref, o_ref, *, n: int, q: int, qinv: int,
                scale_mont: int | None):
    y = ntt_stages(x_ref[...], w_ref[...], n=n, q=q, qinv=qinv)
    if scale_mont is not None:     # inverse: fold in n^-1 (Montgomery form)
        y = _mont_mul(y, jnp.uint32(scale_mont), q, qinv)
    o_ref[...] = y


def _rns_ntt_polymul_kernel(scal_ref, wf_ref, wi_ref, twist_ref, untwist_ref,
                            a_ref, b_ref, c_ref, *, n: int, negacyclic: bool,
                            prefetch: bool):
    """One grid cell = one (limb, batch-block) tile of the RNS polymul.

    Identical dataflow to ``_ntt_polymul_kernel``; the limb's modulus
    constants are *data* (scal_ref row: q, qinv, r2) instead of closure
    constants, which is what lets k different-q transforms share a single
    pallas launch on the (limb, batch) grid.

    ``prefetch=True`` is the scalar-prefetch layout
    (``pltpu.PrefetchScalarGridSpec``): ``scal_ref`` is the WHOLE (k, 4)
    table resident in SMEM before the body runs — the per-limb constants
    never occupy a VMEM block and are available for the twiddle DMAs.
    ``prefetch=False`` is the scalar-Ref fallback (a (1, 4) VMEM block per
    grid cell), kept for backends/modes without SMEM prefetch. Both paths
    are pinned bit-exactly equal in tests/test_rns_ntt.py.
    """
    row = pl.program_id(0) if prefetch else 0
    q = scal_ref[row, 0]
    qinv = scal_ref[row, 1]
    r2 = scal_ref[row, 2]
    wf = wf_ref[...]
    wi = wi_ref[...]
    a = a_ref[0]
    b = b_ref[0]
    if negacyclic:
        tw = twist_ref[...]
        a = _mont_mul(a, tw, q, qinv)
        b = _mont_mul(b, tw, q, qinv)
    fa = ntt_stages(a, wf, n=n, q=q, qinv=qinv)
    fb = ntt_stages(b, wf, n=n, q=q, qinv=qinv)
    p = _mont_mul(_mont_mul(fa, r2, q, qinv), fb, q, qinv)
    c = ntt_stages(p, wi, n=n, q=q, qinv=qinv)
    c_ref[...] = _mont_mul(c, untwist_ref[...], q, qinv)[None]


def _ntt_polymul_kernel(wf_ref, wi_ref, twist_ref, untwist_ref,
                        a_ref, b_ref, c_ref, *, n: int, q: int, qinv: int,
                        r2: int, negacyclic: bool):
    """Fused modular polymul: twist -> NTT x2 -> pointwise -> INTT -> untwist,
    one VMEM residency (paper §5 structure, exact arithmetic)."""
    wf = wf_ref[...]
    wi = wi_ref[...]
    a = a_ref[...]
    b = b_ref[...]
    if negacyclic:                 # psi^j twist: x^n+1 products via cyclic NTT
        tw = twist_ref[...]
        a = _mont_mul(a, tw, q, qinv)
        b = _mont_mul(b, tw, q, qinv)
    fa = ntt_stages(a, wf, n=n, q=q, qinv=qinv)
    fb = ntt_stages(b, wf, n=n, q=q, qinv=qinv)
    # Pointwise product needs one operand in Montgomery form first (r2 hop).
    p = _mont_mul(_mont_mul(fa, jnp.uint32(r2), q, qinv), fb, q, qinv)
    c = ntt_stages(p, wi, n=n, q=q, qinv=qinv)
    # untwist table carries psi^{-j} * n^{-1} (or just n^{-1} for cyclic).
    c_ref[...] = _mont_mul(c, untwist_ref[...], q, qinv)


# ---------------------------------------------------------------------------
# Host-side wrappers
# ---------------------------------------------------------------------------

def _master_table(params: NTTParams, base: int) -> jnp.ndarray:
    """(1, n) uint32 Montgomery-form powers of ``base``."""
    pw = params.powers(base)
    return jnp.asarray(params.to_montgomery(pw).astype(np.uint32)[None, :])


def untwist_table(params: NTTParams, negacyclic: bool) -> np.ndarray:
    """Output-pass multiplier values (normal domain, uint64): psi^{-j}·n^{-1}
    for the negacyclic untwist+scale, or the n^{-1} broadcast for cyclic.
    THE single definition — the fused kernel, the RNS limb tables, and the
    distributed four-step edge passes all read it from here, so a change to
    the untwist convention cannot silently diverge per path."""
    if negacyclic:
        return (params.powers(params.psi_inv) * np.uint64(params.n_inv)
                % np.uint64(params.q))
    return np.full(params.n, params.n_inv, np.uint64)


def _as_residues(x, q: int):
    """Reduce integer coefficients into [0, q) uint32 — same contract as
    ``core.ntt.ref.as_residues``: floats raise, negatives wrap Python-style.
    The in-kernel butterflies assume operands < q; skipping this reduction
    would silently corrupt results for unreduced input."""
    x = jnp.asarray(x)
    assert x.ndim == 2, f"expected (batch, n), got {x.shape}"
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise TypeError(f"NTT needs integer residues, got {x.dtype}")
    if jnp.issubdtype(x.dtype, jnp.signedinteger):
        # jnp.remainder takes the divisor's sign: (-1) % q == q - 1, and
        # the result fits int32 since q < 2^31.
        return (x.astype(jnp.int32) % q).astype(jnp.uint32)
    return x.astype(jnp.uint32) % jnp.uint32(q)


@functools.partial(jax.jit, static_argnames=("params", "inverse",
                                             "interpret", "block_b"))
def ntt_batched(x: jax.Array, params: NTTParams, *, inverse: bool = False,
                interpret: bool = True, block_b: int | None = None
                ) -> jax.Array:
    """Batched cyclic NTT of uint32 residues (B, n) mod ``params.q``.

    Bit-exact equal to ``core.ntt.ref.ntt``/``intt`` (tests/test_ntt.py).
    Same grid/tiling contract as ``fft_planes``: grid=(B/B_blk,), each
    program transforms its block entirely in VMEM.
    """
    x = _as_residues(x, params.q)
    b, n = x.shape
    assert n == params.n, f"n={n} != params.n={params.n}"
    blk = block_b or plan_batch_block(n)
    pad = (-b) % blk
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    bp = x.shape[0]
    w = _master_table(params, params.w_inv if inverse else params.w)
    scale = None
    if inverse:
        scale = params.n_inv * (1 << 32) % params.q   # Montgomery n^-1
    kern = functools.partial(_ntt_kernel, n=n, q=params.q, qinv=params.qinv,
                             scale_mont=scale)
    y = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),    # twiddles (broadcast)
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, n), jnp.uint32),
        interpret=interpret,
    )(w, x)
    return y[:b] if pad else y


@functools.partial(jax.jit, static_argnames=("params", "negacyclic",
                                             "interpret", "block_b"))
def ntt_polymul(a: jax.Array, b: jax.Array, params: NTTParams, *,
                negacyclic: bool = True, interpret: bool = True,
                block_b: int | None = None) -> jax.Array:
    """Exact polynomial product mod (x^n + 1, q) — or x^n - 1 with
    ``negacyclic=False`` — of residue batches (B, n), fully fused.

    Matches ``core.ntt.ref.negacyclic_polymul`` (and the schoolbook oracle)
    bit-exactly; see docs/ntt.md for the RLWE semantics.
    """
    a = _as_residues(a, params.q)
    bb = _as_residues(b, params.q)
    assert a.shape == bb.shape
    bsz, n = a.shape
    assert n == params.n, f"n={n} != params.n={params.n}"
    blk = block_b or max(1, plan_batch_block(n) // 2)  # 3 transforms live
    pad = (-bsz) % blk
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, pad), (0, 0)))
    bp = a.shape[0]
    wf = _master_table(params, params.w)
    wi = _master_table(params, params.w_inv)
    twist = _master_table(params, params.psi if negacyclic else 1)
    untwist = jnp.asarray(params.to_montgomery(
        untwist_table(params, negacyclic)).astype(np.uint32)[None, :])
    kern = functools.partial(_ntt_polymul_kernel, n=n, q=params.q,
                             qinv=params.qinv, r2=params.r2,
                             negacyclic=negacyclic)
    bspec = pl.BlockSpec((blk, n), lambda i: (i, 0))
    wspec = pl.BlockSpec((1, n), lambda i: (0, 0))
    c = pl.pallas_call(
        kern,
        grid=(bp // blk,),
        in_specs=[wspec, wspec, wspec, wspec, bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct((bp, n), jnp.uint32),
        interpret=interpret,
    )(wf, wi, twist, untwist, a, bb)
    return c[:bsz] if pad else c


# ---------------------------------------------------------------------------
# RNS: k limbs through one launch on the (limb, batch) grid
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _rns_tables(rns, negacyclic: bool):
    """Per-limb constant stacks for the RNS kernel, all uint32:
    scalars (k, 4) = [q, qinv, r2, 0]; wf/wi/twist/untwist (k, n) in
    Montgomery form. Cached on the hashable RNSParams as NUMPY arrays
    (caching jnp values across jit traces would leak tracers)."""
    k, n = rns.k, rns.n
    scal = np.zeros((k, 4), np.uint32)
    wf = np.empty((k, n), np.uint32)
    wi = np.empty((k, n), np.uint32)
    twist = np.empty((k, n), np.uint32)
    untwist = np.empty((k, n), np.uint32)
    for i, p in enumerate(rns.limbs):
        scal[i] = (p.q, p.qinv, p.r2, 0)
        wf[i] = p.to_montgomery(p.powers(p.w)).astype(np.uint32)
        wi[i] = p.to_montgomery(p.powers(p.w_inv)).astype(np.uint32)
        twist[i] = p.to_montgomery(
            p.powers(p.psi) if negacyclic
            else np.ones(n, np.uint64)).astype(np.uint32)    # cyclic: unused
        untwist[i] = p.to_montgomery(
            untwist_table(p, negacyclic)).astype(np.uint32)
    return scal, wf, wi, twist, untwist


@functools.partial(jax.jit, static_argnames=("rns", "negacyclic",
                                             "interpret", "block_b",
                                             "scalar_prefetch"))
def rns_ntt_polymul(ar: jax.Array, br: jax.Array, rns, *,
                    negacyclic: bool = True, interpret: bool = True,
                    block_b: int | None = None,
                    scalar_prefetch: bool | None = None) -> jax.Array:
    """Limb-batched exact polymul: residue stacks (k, B, n) -> (k, B, n).

    ``rns`` is a ``core.ntt.rns.RNSParams`` (kept opaque here so the kernel
    layer has no core->kernels cycle); inputs are per-limb REDUCED residues
    (< q_i each, as ``rns.to_rns`` produces). All k limbs and all batch
    blocks run through ONE pallas launch: the limb dimension rides the same
    ``plan_batch_block`` grid the batched single-modulus kernels use, so an
    8-limb 100-bit-Q product costs one kernel dispatch, not eight.
    CRT reconstruction (``rns.crt_to_modulus``) lives with the caller.

    ``scalar_prefetch`` hoists the per-limb q/qinv/r2 table to TPU scalar
    prefetch (SMEM, ``PrefetchScalarGridSpec``) instead of streaming it as
    a (1, 4) VMEM block per grid cell. Default: enabled exactly when the
    kernel compiles for real hardware (``not interpret``); pass explicitly
    to pin either layout (tests force both and assert bit-equality).
    """
    ar = jnp.asarray(ar)
    br = jnp.asarray(br)
    assert ar.shape == br.shape and ar.ndim == 3, (ar.shape, br.shape)
    assert ar.dtype == jnp.uint32 and br.dtype == jnp.uint32, \
        "RNS kernel wants pre-reduced uint32 residue stacks (rns.to_rns)"
    k, bsz, n = ar.shape
    assert k == rns.k and n == rns.n, (ar.shape, rns.k, rns.n)
    blk = block_b or max(1, plan_batch_block(n) // 2)  # 3 transforms live
    pad = (-bsz) % blk
    if pad:
        ar = jnp.pad(ar, ((0, 0), (0, pad), (0, 0)))
        br = jnp.pad(br, ((0, 0), (0, pad), (0, 0)))
    bp = ar.shape[1]
    scal, wf, wi, twist, untwist = (jnp.asarray(t) for t in
                                    _rns_tables(rns, negacyclic))
    prefetch = (not interpret) if scalar_prefetch is None else scalar_prefetch
    kern = functools.partial(_rns_ntt_polymul_kernel, n=n,
                             negacyclic=negacyclic, prefetch=prefetch)
    out_shape = jax.ShapeDtypeStruct((k, bp, n), jnp.uint32)
    if prefetch:
        from jax.experimental.pallas import tpu as pltpu
        # index maps gain the prefetched scal Ref as a trailing argument.
        wspec = pl.BlockSpec((1, n), lambda l, i, s: (l, 0))
        bspec = pl.BlockSpec((1, blk, n), lambda l, i, s: (l, i, 0))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k, bp // blk),
            in_specs=[wspec, wspec, wspec, wspec, bspec, bspec],
            out_specs=bspec,
        )
        c = pl.pallas_call(
            kern, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(scal, wf, wi, twist, untwist, ar, br)
    else:
        sspec = pl.BlockSpec((1, 4), lambda l, i: (l, 0))
        wspec = pl.BlockSpec((1, n), lambda l, i: (l, 0))
        bspec = pl.BlockSpec((1, blk, n), lambda l, i: (l, i, 0))
        c = pl.pallas_call(
            kern,
            grid=(k, bp // blk),
            in_specs=[sspec, wspec, wspec, wspec, wspec, bspec, bspec],
            out_specs=bspec,
            out_shape=out_shape,
            interpret=interpret,
        )(scal, wf, wi, twist, untwist, ar, br)
    return c[:, :bsz] if pad else c
