"""Launch layer: meshes, dry-run, train/serve drivers."""
