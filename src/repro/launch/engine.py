"""Continuous-batching serve engine over the op-dispatch registry.

One engine process multiplexes a mixed request stream: every request
carries its own ``(op, n)`` (and payload dtype via the registry), is
admitted against a bounded queue, and lands in a per-``(op, n)`` shape
bucket. A single scheduler loop drains buckets with continuous batching:

  * **shape bucketing** — requests only ever batch with shape-compatible
    peers; each bucket dispatches through its :class:`~repro.launch.ops.
    BoundOp` (plan, route, jitted fn) resolved once from the registry;
  * **tail batches at actual size** — a bucket holding 3 requests
    dispatches 3 rows; nothing is padded to the block cap (the kernels'
    ``_fit_block`` clamps the VMEM block to the real batch instead);
  * **async dispatch, deferred sync** — ``jax.block_until_ready`` for
    batch k is deferred until AFTER batch k+1 has been staged and
    dispatched, so host-side stacking/transfer of the next batch overlaps
    the current batch's compute (one batch in flight, the maxtext
    decode-microbenchmark warmup/steady-state split);
  * **oldest-head scheduling** — among non-empty buckets the one whose
    head request has waited longest dispatches next, so a hot bucket
    cannot starve a cold one;
  * **backpressure** — ``submit`` blocks (or raises :class:`Backpressure`
    with ``block=False``) while ``max_pending`` requests are queued: the
    admission policy is a bounded queue, pushing the wait back into
    producers instead of growing host memory without bound.

Metrics (docs/serving.md has the glossary): per-request latency
(submit -> result materialized) percentiles p50/p90/p99, end-to-end and
busy-only throughput, and per-bucket batch-size traces with utilization
(mean dispatched batch / block cap) — the number that says whether traffic
actually fills the arrays the paper's throughput claims assume.

Fault tolerance (docs/fault_tolerance.md): the engine is preemption-safe.
``request_stop`` (the SIGTERM path in ``launch/serve.py``) stops admission
and lets ``run`` DRAIN — every already-admitted request is dispatched,
resolved and delivered before ``run`` returns; ``snapshot`` then persists
the lifetime stats, bucket config and watchdog state through
``ft.checkpoint`` (atomic step dirs), and ``ServeEngine.from_snapshot``
warm-restarts: buckets re-register and re-bind on the CURRENT context —
which may have a different ``model_shards`` — while served counters, the
latency record and the watchdog's timing baseline carry over. A
:class:`~repro.ft.watchdog.StepWatchdog` observes per-batch service times;
its ``on_evict`` hook is the elastic trigger the watchdog module
documents (checkpoint -> resize -> restore).

Integrity (``verified=True``): every deliverable batch passes its op's
ABFT check (``ft/abft.py``) before any client sees a result. A failed
check triggers bounded re-execution with exponential backoff; when the
retry budget is exhausted the bucket's circuit breaker trips — it
re-binds on a ``pim_ok=False`` context (cost model plans the PIM backend
as infeasible), the simulated crossbar array behind it is quarantined to
a spare, and the batch re-runs on the clean route. ``--inject-faults``
chaos testing drives this path deterministically via a seeded
:class:`~repro.core.pim.FaultModel` whose per-bucket injectors corrupt
delivered rows. Per-request deadlines (``submit(..., deadline_s=...)``)
complete expired requests with a structured timeout error instead of a
result; expired requests never enter the latency record.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

from repro.ft import checkpoint as ckpt_lib
from repro.ft.watchdog import StepWatchdog, WatchdogConfig
from repro.launch import ops as op_registry

SNAPSHOT_SCHEMA = "serve_engine_snapshot/v1"

#: Bounded window for the per-request latency record. Lifetime percentiles
#: are computed over (at most) the most recent window, and — the actual
#: bug this bounds — ``snapshot`` persists at most this many samples, so a
#: restart loop (snapshot -> from_snapshot -> snapshot ...) plateaus
#: instead of growing the payload by one generation's traffic each cycle.
#: Older samples DECAY out of the percentile inputs by design: a
#: deployment's p99 should describe recent service, not the union of every
#: generation since the first boot.
LATENCY_WINDOW = 4096


class Backpressure(RuntimeError):
    """Admission rejected: the bounded request queue is full."""


class EngineStopped(RuntimeError):
    """Admission rejected: the engine is draining toward a stop/snapshot."""


@dataclasses.dataclass
class _Request:
    rid: int
    key: tuple[str, int]
    payload: Any
    t_submit: float
    # absolute perf_counter() deadline; expired requests complete with a
    # structured error instead of a result (and never batch)
    deadline: float | None = None


@dataclasses.dataclass
class _BucketStats:
    served: int = 0
    batches: int = 0
    batch_sizes: list = dataclasses.field(default_factory=list)
    # accumulated dispatch -> materialized seconds of this bucket's
    # batches: the OBSERVED side of the cost model's predicted-vs-observed
    # comparison (docs/planner.md)
    service_s: float = 0.0
    # deadline-expired requests swept from this bucket's queue
    expired: int = 0
    # ABFT ledger (verified=True): checks run / failures detected /
    # re-executions / breaker trips (detected -> retried -> fell_back is
    # the recovery state machine in docs/fault_tolerance.md)
    checked: int = 0
    corrupted: int = 0
    retried: int = 0
    fell_back: int = 0


class _FaultInjector:
    """Chaos hook for one serve bucket: wraps the bucket's bound ``fn``,
    runs the real kernel, then — driven by the engine's seeded
    :class:`~repro.core.pim.FaultModel` for this bucket's virtual array —
    corrupts delivered rows. Deterministic per (model seed, array id,
    dispatch index), so a chaos run replays exactly.

    Corruption mirrors the sim-level fault modes at the result surface:
    permanent faults (dead / stuck cells) corrupt EVERY dispatch, transient
    bit-flips fire with probability ``1 - (1 - rate)^gates`` where gates
    scales with the batch's work (rows * n * log2 n). Injected damage is a
    magnitude change (float/complex) or a low-bit flip (modular) on one
    element — exactly the class of error the ABFT checks are sound
    against."""

    def __init__(self, model, array_id: int, bound):
        self.model = model
        self.array_id = array_id
        self.bound = bound
        self.inner = bound.fn
        self.dispatches = 0

    def __call__(self, *operands):
        out = self.inner(*operands)
        idx = self.dispatches
        self.dispatches += 1
        faults = self.model.for_array(self.array_id)
        if faults is None:
            return out      # quarantined-to-spare or clean array
        arr = np.array(self.bound.to_numpy(out), copy=True)
        rows = arr if arr.ndim > 1 else arr.reshape(1, -1)
        rng = self.model.rng_for(self.array_id, salt=1000 + idx)
        if faults.permanent:
            corrupt = True
        else:
            n = rows.shape[1]
            gates = rows.shape[0] * n * max(1, n.bit_length() - 1)
            p = 1.0 - (1.0 - faults.bitflip_per_gate) ** gates
            corrupt = bool(rng.random() < p)
        if corrupt:
            r = int(rng.integers(rows.shape[0]))
            j = int(rng.integers(rows.shape[1]))
            row = rows[r]
            if row.dtype == object:
                row[j] = row[j] + 1
            elif np.issubdtype(row.dtype, np.complexfloating):
                row[j] += (1.0 + float(np.abs(row).max())) * (3.0 + 3.0j)
            elif np.issubdtype(row.dtype, np.floating):
                row[j] += (1.0 + float(np.abs(row).max())) * 3.0
            else:
                row[j] = row.dtype.type(int(row[j]) ^ 1)
        return arr


class ServeEngine:
    """Multiplexing continuous-batching executor for registry ops.

    ``max_batch`` caps one dispatch (the continuous-batching block);
    ``max_pending`` bounds the admission queue across all buckets. The
    process-level ``modulus_bits`` / ``model_shards`` context feeds each
    op through ``OpSpec.narrow`` unless a bucket is registered with
    ``strict=True`` (the single-op CLI path, which rejects knobs the op
    does not consume).
    """

    def __init__(self, *, max_batch: int = 64, max_pending: int = 1024,
                 modulus_bits: int | None = None, model_shards: int = 1,
                 auto: bool = False,
                 collect_timeout_s: float = 0.05,
                 watchdog_cfg: Optional[WatchdogConfig] = None,
                 on_evict: Optional[Callable[["ServeEngine", int], None]]
                 = None,
                 verified: bool = False,
                 fault_model=None,
                 retry_cap: int = 2,
                 retry_backoff_s: float = 0.001):
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} must be >= 1")
        if max_pending < 1:
            raise ValueError(f"max_pending={max_pending} must be >= 1")
        if retry_cap < 0:
            raise ValueError(f"retry_cap={retry_cap} must be >= 0")
        if fault_model is not None and not verified:
            raise ValueError(
                "fault_model without verified=True would deliver corrupted "
                "results: chaos injection requires the ABFT gate")
        self.max_batch = max_batch
        self.max_pending = max_pending
        self.collect_timeout_s = collect_timeout_s
        # auto=True: each bucket's bind lets the cost model pick the tier
        # and packing (plan(workload=...)); explicit-knob binding otherwise.
        self.ctx = op_registry.OpContext(modulus_bits=modulus_bits,
                                         model_shards=model_shards,
                                         auto=auto, verified=verified)
        # ABFT recovery knobs (docs/fault_tolerance.md): detected
        # corruption -> up to retry_cap re-executions with exponential
        # backoff -> circuit breaker (XLA re-bind + array quarantine).
        self.verified = verified
        self.fault_model = fault_model
        self.retry_cap = retry_cap
        self.retry_backoff_s = retry_backoff_s
        self._injectors: dict[tuple[str, int], _FaultInjector] = {}
        self._breaker_open: set[tuple[str, int]] = set()
        self._next_array_id = 0
        # rid -> structured error for requests completed WITHOUT a result
        # (deadline_exceeded today); disjoint from ``results``.
        self.errors: dict[int, dict] = {}
        self._bound: dict[tuple[str, int], op_registry.BoundOp] = {}
        self._strict: dict[tuple[str, int], bool] = {}
        self._bucket_stats: dict[tuple[str, int], _BucketStats] = {}
        self._buckets: dict[tuple[str, int], deque[_Request]] = {}
        self._bind_lock = threading.Lock()
        self._cv = threading.Condition()
        self._pending = 0
        self._served = 0
        self._next_rid = 0
        self._stopping = False
        self.results: dict[int, np.ndarray] = {}
        self._latencies_s: list[float] = []
        # Warm-restart carry-over (``from_snapshot`` fills these): lifetime
        # counters from before the restart, so the trajectory a deployment
        # reports survives preemption instead of resetting to zero.
        self.restarts = 0
        self._prev_served = 0
        self._prev_batches = 0
        self._prev_bucket_served: dict[str, int] = {}
        self._prev_latencies_s: list[float] = []
        # Straggler watchdog over per-batch service times (dispatch ->
        # materialized); ``on_evict(engine, batch_idx)`` is the elastic
        # hook — the driver checkpoints, resizes the mesh and warm-restarts
        # (``elastic_restart``). Default: record the event.
        self.evictions: list[int] = []
        self._user_on_evict = on_evict
        self.watchdog = StepWatchdog(watchdog_cfg,
                                     on_evict=self._handle_evict)
        self._batch_idx = 0

    def _handle_evict(self, batch_idx: int) -> None:
        self.evictions.append(batch_idx)
        if self._user_on_evict is not None:
            self._user_on_evict(self, batch_idx)

    # -- registration -------------------------------------------------------

    def register(self, op: str, n: int, *, strict: bool = False
                 ) -> op_registry.BoundOp:
        """Resolve (op, n) against the registry and open its bucket.

        Validation errors surface as :class:`~repro.launch.ops.
        OpConfigError` here, at admission of the SHAPE, not mid-stream.
        """
        key = (op, n)
        with self._bind_lock:
            if key not in self._bound:
                spec = op_registry.get_op(op)
                bound = spec.bind(n, self.ctx, batch=self.max_batch,
                                  strict=strict)
                if self.fault_model is not None:
                    # one virtual crossbar array per bucket, assigned
                    # round-robin over the model's array space
                    aid = self._next_array_id % self.fault_model.n_arrays
                    self._next_array_id += 1
                    inj = _FaultInjector(self.fault_model, aid, bound)
                    bound.fn = inj
                    self._injectors[key] = inj
                self._bound[key] = bound
                self._strict[key] = strict
                self._buckets[key] = deque()
                self._bucket_stats[key] = _BucketStats()
            return self._bound[key]

    def bound(self, op: str, n: int) -> op_registry.BoundOp:
        return self.register(op, n)

    def warmup(self) -> None:
        """Compile every registered bucket at its block cap (deploy-time
        warmup: reported throughput is steady state, not trace+compile)."""
        for bound in list(self._bound.values()):
            bound.warmup(self.max_batch)

    # -- admission ----------------------------------------------------------

    def submit(self, op: str, n: int, payload, *, rid: int | None = None,
               block: bool = True, timeout: float | None = None,
               deadline_s: float | None = None) -> int:
        """Enqueue one request; returns its rid.

        Blocks while the bounded queue is full (``block=False`` raises
        :class:`Backpressure` instead — the caller's cue to shed load).
        ``timeout`` bounds THIS call's wait for queue space;
        ``deadline_s`` bounds the REQUEST's total time-to-result: a
        request still queued past its deadline is completed with a
        structured ``deadline_exceeded`` error (``engine.errors[rid]``)
        instead of a result, and is excluded from the latency record.
        """
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s={deadline_s} must be > 0")
        if self._stopping:
            raise EngineStopped(
                "engine is draining (request_stop/SIGTERM); submit after "
                "the warm restart")
        bound = self.register(op, n)     # validates shape/route once
        bound.check_payload(payload)     # reject NaN/Inf BEFORE it batches
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cv:
            while self._pending >= self.max_pending:
                if self._stopping:
                    raise EngineStopped(
                        "engine is draining (request_stop/SIGTERM); "
                        "submit after the warm restart")
                if not block:
                    raise Backpressure(
                        f"queue full ({self._pending}/{self.max_pending} "
                        f"pending); retry or shed load")
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise Backpressure(
                        f"queue full after {timeout}s "
                        f"({self._pending}/{self.max_pending} pending)")
                self._cv.wait(remaining if remaining is not None else 0.1)
            if rid is None:
                rid = self._next_rid
            self._next_rid = max(self._next_rid, rid + 1)
            now = time.perf_counter()
            self._buckets[bound.key].append(
                _Request(rid, bound.key, payload, now,
                         deadline=(None if deadline_s is None
                                   else now + deadline_s)))
            self._pending += 1
            self._cv.notify_all()
        return rid

    # -- scheduling ---------------------------------------------------------

    def _sweep_expired_locked(self) -> None:
        """Complete deadline-expired queued requests with a structured
        error (caller holds ``_cv``). Expired requests count as served —
        they are COMPLETED, just without a result — so ``run`` terminates;
        they never enter the latency record, so p99 describes delivered
        results only."""
        now = time.perf_counter()
        for key, q in self._buckets.items():
            if not any(r.deadline is not None and r.deadline < now
                       for r in q):
                continue
            keep: deque[_Request] = deque()
            for r in q:
                if r.deadline is not None and r.deadline < now:
                    self.errors[r.rid] = {
                        "error": "deadline_exceeded",
                        "op": key[0], "n": key[1],
                        "waited_s": now - r.t_submit,
                    }
                    self._bucket_stats[key].expired += 1
                    self._pending -= 1
                    self._served += 1
                else:
                    keep.append(r)
            self._buckets[key] = keep
        self._cv.notify_all()

    def _pop_batch(self, timeout: float) -> tuple[tuple[str, int],
                                                  list[_Request]] | None:
        """Take up to ``max_batch`` requests from the non-empty bucket whose
        head has waited longest; None if nothing arrives within timeout."""
        with self._cv:
            if not any(self._buckets.values()):
                self._cv.wait(timeout)
            self._sweep_expired_locked()
            ready = [(q[0].t_submit, key)
                     for key, q in self._buckets.items() if q]
            if not ready:
                return None
            _, key = min(ready)
            q = self._buckets[key]
            take = min(len(q), self.max_batch)
            reqs = [q.popleft() for _ in range(take)]
            self._pending -= take
            self._cv.notify_all()
            return key, reqs

    def _dispatch(self, key: tuple[str, int], reqs: list[_Request]):
        """Stage + launch one batch at its ACTUAL size (async for device
        routes); the sync happens later in ``_resolve``."""
        return self._bound[key].execute([r.payload for r in reqs])

    def _verified_rows(self, key: tuple[str, int], reqs: list[_Request],
                       arr: np.ndarray) -> np.ndarray:
        """ABFT gate for one deliverable batch: check, then on detected
        corruption re-execute up to ``retry_cap`` times with exponential
        backoff; exhausted retries trip the bucket's circuit breaker
        (XLA re-bind + array quarantine) and re-run once on the clean
        route. Raises RuntimeError only if even the fallback route fails
        its check — no corrupted batch is ever delivered."""
        bound = self._bound[key]
        payloads = [r.payload for r in reqs]
        bs = self._bucket_stats[key]
        verdict = bound.integrity(payloads, arr)
        bs.checked += 1
        if verdict.ok:
            return arr
        bs.corrupted += 1
        for attempt in range(self.retry_cap):
            time.sleep(self.retry_backoff_s * (2 ** attempt))
            bs.retried += 1
            arr = bound.to_numpy(bound.execute(payloads))
            verdict = bound.integrity(payloads, arr)
            bs.checked += 1
            if verdict.ok:
                return arr
            bs.corrupted += 1
        bs.fell_back += 1
        bound = self._trip_breaker(key)
        arr = bound.to_numpy(bound.execute(payloads))
        verdict = bound.integrity(payloads, arr)
        bs.checked += 1
        if not verdict.ok:
            bs.corrupted += 1
            raise RuntimeError(
                f"integrity check still failing after circuit-breaker "
                f"fallback for {key[0]}/n={key[1]}: "
                f"{verdict.detail or verdict.check}")
        return arr

    def _trip_breaker(self, key: tuple[str, int]):
        """Open the bucket's circuit breaker: re-bind on a ``pim_ok=
        False`` context (the cost model marks the PIM backend infeasible
        for this bucket from now on) and quarantine the bucket's
        simulated array to a spare. The new bound is CLEAN — no fault
        injector wraps it."""
        op, n = key
        with self._bind_lock:
            ctx = dataclasses.replace(self.ctx, pim_ok=False)
            spec = op_registry.get_op(op)
            bound = spec.bind(n, ctx, batch=self.max_batch,
                              strict=self._strict[key])
            self._bound[key] = bound
            self._breaker_open.add(key)
            inj = self._injectors.pop(key, None)
            if inj is not None and self.fault_model is not None:
                from repro.core.pim.faults import SparesExhausted
                try:
                    self.fault_model.quarantine(inj.array_id)
                except SparesExhausted:
                    pass    # breaker still isolates the bucket via re-bind
        return bound

    def _resolve(self, key: tuple[str, int], reqs: list[_Request],
                 out) -> None:
        """Materialize a dispatched batch: record results + latencies."""
        arr = self._bound[key].to_numpy(out)
        if self.verified:
            arr = self._verified_rows(key, reqs, arr)
        t_done = time.perf_counter()
        assert arr.shape[0] == len(reqs), \
            f"batch executed at {arr.shape[0]} rows for {len(reqs)} requests"
        stats = self._bucket_stats[key]
        for j, req in enumerate(reqs):
            self.results[req.rid] = arr[j]
            self._latencies_s.append(t_done - req.t_submit)
        stats.served += len(reqs)
        stats.batches += 1
        stats.batch_sizes.append(len(reqs))
        self._served += len(reqs)

    # -- the serve loop -----------------------------------------------------

    def request_stop(self) -> None:
        """SIGTERM path: stop ADMITTING (submit raises
        :class:`EngineStopped`) but keep serving — ``run`` drains every
        already-admitted request, resolves the in-flight batch, and
        returns. The caller then ``snapshot``s and warm-restarts."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()

    @property
    def stopping(self) -> bool:
        return self._stopping

    def run(self, total_requests: int) -> dict:
        """Serve until ``total_requests`` results have materialized in this
        engine instance (lifetime counters from BEFORE a warm restart do
        not raise the bar — ``_served`` restarts at zero), or — after
        ``request_stop`` — until the admitted backlog has fully drained.

        One batch is kept in flight: batch k+1 is staged and dispatched
        before batch k is synced, so transfer and compute overlap. Each
        batch's service time (dispatch -> materialized) feeds the straggler
        watchdog. Returns the stats dict (see ``stats``).
        """
        target = total_requests
        t0 = time.perf_counter()
        busy_s = 0.0
        inflight: tuple | None = None

        def finish(flight) -> float:
            key, reqs, out, t_disp = flight
            tb = time.perf_counter()
            self._resolve(key, reqs, out)
            t_done = time.perf_counter()
            self._batch_idx += 1
            self.watchdog.observe(self._batch_idx, t_done - t_disp)
            # observed service time, attributed to the bucket: the
            # measured side of predicted-vs-observed in stats()
            self._bucket_stats[key].service_s += t_done - t_disp
            return t_done - tb

        while self._served < target:
            if self._stopping and self._pending == 0:
                break   # drained: nothing left to admit or schedule
            picked = self._pop_batch(
                0.0 if self._stopping else self.collect_timeout_s)
            if picked is None:
                if inflight is not None:
                    busy_s += finish(inflight)
                    inflight = None
                continue
            key, reqs = picked
            tb = time.perf_counter()
            out = self._dispatch(key, reqs)
            if inflight is not None:
                finish(inflight)
            busy_s += time.perf_counter() - tb
            inflight = (key, reqs, out, tb)
        if inflight is not None:
            busy_s += finish(inflight)
        return self.stats(seconds=time.perf_counter() - t0, busy_s=busy_s)

    # -- metrics ------------------------------------------------------------

    def stats(self, *, seconds: float, busy_s: float) -> dict:
        # Percentiles over the bounded recent window (LATENCY_WINDOW):
        # lifetime inputs decay instead of accumulating across restarts.
        lat = np.asarray((self._prev_latencies_s
                          + self._latencies_s)[-LATENCY_WINDOW:],
                         np.float64) * 1e3
        if lat.size:
            p50, p90, p99 = np.percentile(lat, [50, 90, 99])
            latency_ms = {"p50": float(p50), "p90": float(p90),
                          "p99": float(p99), "mean": float(lat.mean()),
                          "max": float(lat.max())}
        else:
            latency_ms = {"p50": 0.0, "p90": 0.0, "p99": 0.0,
                          "mean": 0.0, "max": 0.0}
        buckets = {}
        for key, bs in self._bucket_stats.items():
            op, n = key
            sizes = bs.batch_sizes
            bound = self._bound[key]
            entry = {
                "op": op, "n": n, "served": bs.served,
                "lifetime_served": (self._prev_bucket_served.get(
                    f"{op}/{n}", 0) + bs.served),
                "batches": bs.batches,
                "route": bound.route,
                "max_block": self.max_batch,
                "mean_batch": (sum(sizes) / len(sizes)) if sizes else 0.0,
                # fill of the continuous-batching block: 1.0 means every
                # dispatch ran at the cap, < 1 quantifies tail/trickle cost
                "utilization": (sum(sizes) / (len(sizes) * self.max_batch))
                               if sizes else 0.0,
                "batch_sizes": list(sizes),
                # observed per-request service seconds (dispatch ->
                # materialized, batch time amortized over its rows)
                "observed_s_per_req": (bs.service_s / bs.served
                                       if bs.served else None),
                "expired": bs.expired,
                # ABFT ledger (all zeros when verified=False): the
                # detected -> retried -> fell_back recovery trajectory
                "integrity": {
                    "checked": bs.checked,
                    "corrupted": bs.corrupted,
                    "retried": bs.retried,
                    "fell_back": bs.fell_back,
                    "breaker_open": key in self._breaker_open,
                },
            }
            cost = getattr(bound.plan, "cost", None)
            if cost is not None and cost.get("best") is not None:
                best = cost["best"]
                # the bind-time batch hint the plan was costed at
                per = max(1, cost.get("batch") or 1)
                entry["predicted_s_per_req"] = best["total_s"] / per
                entry["predicted_tier"] = best["tier"]
                entry["predicted_backend"] = best["backend_best"]
            buckets[f"{op}/n={n}"] = entry
        batches = sum(b.batches for b in self._bucket_stats.values())
        return {
            "served": self._served,
            "batches": batches,
            # requests completed with a deadline_exceeded error (included
            # in ``served`` — they are finished — but never in latency_ms)
            "expired": sum(b.expired for b in self._bucket_stats.values()),
            "seconds": seconds,
            "throughput_per_s": self._served / max(seconds, 1e-9),
            # busy-only rate: excludes queue-collection waits, so endpoint
            # comparisons reflect dispatch+compute, not the driver
            "compute_seconds": busy_s,
            "compute_throughput_per_s": self._served / max(busy_s, 1e-9),
            "latency_ms": latency_ms,
            "buckets": buckets,
            # deployment-lifetime view: counters carried across warm
            # restarts (``from_snapshot``), so preemption does not reset
            # the trajectory a long-running endpoint reports
            "lifetime": {
                "served": self._prev_served + self._served,
                "batches": self._prev_batches + batches,
                "restarts": self.restarts,
            },
            "watchdog": {"events": list(self.watchdog.events),
                         "evictions": list(self.evictions),
                         "ewma_s": self.watchdog.ewma},
        }

    # -- snapshot / warm restart (docs/fault_tolerance.md) ------------------

    def snapshot(self, ckpt_dir: str) -> str:
        """Persist the engine's durable state through ``ft.checkpoint``.

        Must be called DRAINED (after ``request_stop`` + ``run`` returned):
        a snapshot with admitted-but-unserved requests would silently drop
        them on restart, so pending requests are a hard error. The saved
        tree carries the lifetime latency record; the manifest ``extra``
        carries bucket config (op, n, strict), engine knobs, counters and
        the watchdog state. Results themselves are NOT snapshotted —
        delivered results belong to the clients that collected them.
        """
        if self._pending:
            raise RuntimeError(
                f"snapshot with {self._pending} pending requests would "
                f"drop them: request_stop() and let run() drain first")
        # Bounded: persist at most the recent LATENCY_WINDOW samples, so a
        # snapshot -> restart -> snapshot loop plateaus instead of growing
        # the payload by each generation's traffic (the old unbounded
        # prev+current concatenation did exactly that).
        lat = np.asarray((self._prev_latencies_s
                          + self._latencies_s)[-LATENCY_WINDOW:],
                         np.float64)
        extra = {
            "schema": SNAPSHOT_SCHEMA,
            "engine": {"max_batch": self.max_batch,
                       "max_pending": self.max_pending,
                       "collect_timeout_s": self.collect_timeout_s,
                       "modulus_bits": self.ctx.modulus_bits,
                       "model_shards": self.ctx.model_shards,
                       "auto": self.ctx.auto,
                       "verified": self.verified},
            "buckets": [{"op": op, "n": n, "strict": self._strict[(op, n)]}
                        for op, n in self._bound],
            "counters": {
                "served": self._prev_served + self._served,
                "batches": self._prev_batches
                           + sum(b.batches for b in
                                 self._bucket_stats.values()),
                "next_rid": self._next_rid,
                "restarts": self.restarts,
                "bucket_served": {
                    f"{op}/{n}": (self._prev_bucket_served.get(
                        f"{op}/{n}", 0) + self._bucket_stats[(op, n)].served)
                    for op, n in self._bound},
            },
            "watchdog": self.watchdog.state_dict(),
        }
        step = self._prev_served + self._served
        return ckpt_lib.save(ckpt_dir, step,
                             {"latencies_s": lat}, extra=extra)

    @classmethod
    def from_snapshot(cls, ckpt_dir: str, *,
                      model_shards: int | None = None,
                      max_batch: int | None = None,
                      watchdog_cfg: Optional[WatchdogConfig] = None,
                      on_evict: Optional[Callable[["ServeEngine", int],
                                                  None]] = None
                      ) -> "ServeEngine":
        """Warm-restart from ``snapshot``: rebuild the engine, re-register
        and re-BIND every bucket on the restart-time context (pass
        ``model_shards`` to re-shard elastically — this is the resize leg
        of the watchdog's checkpoint -> resize -> restore path), and carry
        the lifetime counters, latency record and watchdog baseline over.
        """
        step = ckpt_lib.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no engine snapshot under {ckpt_dir}")
        extra = ckpt_lib.read_extra(ckpt_dir, step)
        if extra.get("schema") != SNAPSHOT_SCHEMA:
            raise ValueError(
                f"{ckpt_dir} step {step} is not an engine snapshot "
                f"(schema={extra.get('schema')!r})")
        _, restored = ckpt_lib.restore_latest(
            ckpt_dir, {"latencies_s": np.zeros(0, np.float64)})
        eng_cfg = extra["engine"]
        engine = cls(
            max_batch=max_batch or eng_cfg["max_batch"],
            max_pending=eng_cfg["max_pending"],
            collect_timeout_s=eng_cfg["collect_timeout_s"],
            modulus_bits=eng_cfg["modulus_bits"],
            model_shards=(eng_cfg["model_shards"] if model_shards is None
                          else model_shards),
            auto=bool(eng_cfg.get("auto", False)),
            verified=bool(eng_cfg.get("verified", False)),
            watchdog_cfg=watchdog_cfg, on_evict=on_evict)
        for b in extra["buckets"]:
            engine.register(b["op"], int(b["n"]), strict=bool(b["strict"]))
        counters = extra["counters"]
        engine._prev_served = int(counters["served"])
        engine._prev_batches = int(counters["batches"])
        engine._prev_bucket_served = dict(counters["bucket_served"])
        engine._next_rid = int(counters["next_rid"])
        engine.restarts = int(counters["restarts"]) + 1
        engine._prev_latencies_s = [
            float(v) for v in np.asarray(restored["latencies_s"])]
        engine.watchdog.load_state_dict(extra.get("watchdog", {}))
        return engine

    def elastic_restart(self, ckpt_dir: str, *,
                        model_shards: int | None = None,
                        max_batch: int | None = None) -> "ServeEngine":
        """The on_evict path in one move: snapshot this (drained) engine,
        then warm-restart it with a resized context. Returns the NEW
        engine; this one stays stopped."""
        self.snapshot(ckpt_dir)
        return ServeEngine.from_snapshot(
            ckpt_dir, model_shards=model_shards, max_batch=max_batch,
            watchdog_cfg=self.watchdog.cfg, on_evict=self._user_on_evict)
