"""Production mesh construction (multi-pod dry-run contract).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. Mesh creation goes through ``repro.dist.compat``
so axis types are requested on jax versions that have them and elided on
ones that don't.
"""
from __future__ import annotations

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis (pure data parallel across the DCN/pod boundary; FSDP extends over
    (pod, data) so parameter shards scale with the installation)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=compat.axis_types_auto(len(axes)))


def make_dev_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for tests on forced-host-device subprocesses."""
    return compat.make_mesh((n_data, n_model), ("data", "model"),
                            axis_types=compat.axis_types_auto(2))
