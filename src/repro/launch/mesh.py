"""Production mesh construction (multi-pod dry-run contract).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis (pure data parallel across the DCN/pod boundary; FSDP extends over
    (pod, data) so parameter shards scale with the installation)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_dev_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for tests on forced-host-device subprocesses."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
