"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

input_specs() follows the shannon/kernels pattern: weak-type-correct,
shardable, zero allocation. sanitize_spec() drops mesh axes that do not
divide the corresponding dimension (e.g. batch=1 at long_500k, 25 heads on a
16-way model axis) so every cell lowers cleanly on both production meshes.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ShapeSpec
from repro.dist.sharding import sanitize_spec  # noqa: F401  (re-export)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct

DP = ("pod", "data")


def sanitize_tree(specs: Any, shapes: Any, mesh: Mesh) -> Any:
    is_spec = lambda x: isinstance(x, P)  # noqa: E731
    return jax.tree.map(
        lambda sp, sh: sanitize_spec(sp, sh.shape, mesh),
        specs, shapes, is_leaf=is_spec)


def shardings_for(specs: Any, shapes: Any, mesh: Mesh) -> Any:
    clean = sanitize_tree(specs, shapes, mesh)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), clean,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Per-cell inputs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                with_labels: bool) -> tuple[dict, dict]:
    """(ShapeDtypeStructs, PartitionSpecs) for a train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    sds: dict[str, Any] = {}
    sp: dict[str, Any] = {}
    if cfg.frontend == "embeddings":
        sds["embeds"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        sp["embeds"] = P(DP, None, None)
        sds["tokens"] = None
        sp["tokens"] = None
    else:
        sds["tokens"] = SDS((B, S), jnp.int32)
        sp["tokens"] = P(DP, None)
    if cfg.mrope_sections is not None:
        sds["positions"] = SDS((B, S, 3), jnp.int32)
        sp["positions"] = P(DP, None, None)
    if with_labels:
        sds["labels"] = SDS((B, S), jnp.int32)
        sp["labels"] = P(DP, None)
    return sds, sp


def abstract_opt_state(cfg: ModelConfig, opt_cfg: adamw.OptConfig):
    params = lm.abstract_params(cfg)
    return jax.eval_shape(
        functools.partial(adamw.init_state, cfg=opt_cfg), params)


def abstract_decode_state(cfg: ModelConfig, shape: ShapeSpec):
    return jax.eval_shape(functools.partial(
        lm.init_decode_state, cfg, shape.global_batch, shape.seq_len))


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(sds, specs) for (token, pos[, positions, embed]) decode inputs."""
    B = shape.global_batch
    sds = {"pos": SDS((), jnp.int32)}
    sp = {"pos": P()}
    if cfg.frontend == "embeddings":
        sds["embed"] = SDS((B, 1, cfg.d_model), jnp.bfloat16)
        sp["embed"] = P(DP, None, None)
        sds["token"] = None
        sp["token"] = None
    else:
        sds["token"] = SDS((B,), jnp.int32)
        sp["token"] = P(DP)
    if cfg.mrope_sections is not None:
        sds["positions"] = SDS((B, 1, 3), jnp.int32)
        sp["positions"] = P(DP, None, None)
    return sds, sp


def opt_config_for(cfg: ModelConfig) -> adamw.OptConfig:
    """8-bit moments for the >=70B archs so optimizer state fits HBM."""
    big = cfg.param_count() > 7e10
    return adamw.OptConfig(state_dtype="int8" if big else "float32")
