"""Serving drivers.

--service fft  : batched FFT / polynomial-multiplication service — the
                 paper's actual workload (batched transforms at maximum
                 throughput). Requests arrive on a queue, are batched to
                 the configured batch size, executed through the Fourier
                 core (Pallas on TPU / XLA path on CPU), and throughput is
                 reported. This is deliverable (b)'s end-to-end serve
                 driver for the paper's kind (a compute-primitive paper).

--service lm   : batched greedy decode for any --arch (reduced with
                 --smoke): prefill then token-by-token decode_step.

Example:
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 64 --requests 512 --op polymul-real
  # exact modular (RLWE negacyclic) polymul endpoint:
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 32 --requests 128 --op polymul-mod
  # multi-limb RNS route for FHE-scale moduli (limb count from the bits):
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 8 --requests 16 --op polymul-mod --modulus-bits 120
  # distributed exact tier (four-step NTT over 8 sequence shards):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --service fft --n 1024 --batch 4 \
      --requests 16 --op polymul-mod --model-shards 8
  # real-signal half-spectrum transforms (two-for-one packed kernel):
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 64 --requests 256 --op rfft
  # distributed real tier (four-step packed FFT, per-shard Hermitian split):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --service fft --n 1024 --batch 4 \
      --requests 16 --op polymul-real --model-shards 8
  PYTHONPATH=src python -m repro.launch.serve --service lm \
      --arch qwen3-1.7b --smoke --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import fft as fft_core
from repro.models import lm


# ---------------------------------------------------------------------------
# FFT service
# ---------------------------------------------------------------------------

class FFTService:
    """Batched transform service with a request queue and a worker loop.

    ``op='polymul-real'`` is the paper's headline serving workload —
    real-coefficient products — routed through the real-Hermitian fast path
    (``fft_core.polymul_real``: two-for-one packed forward, paired
    inverse); ``self.plan`` records the planner's real-tier selection so
    tests can assert the route, not just the values. ``op='rfft'`` serves
    half-spectrum transforms of real signals the same way. With
    ``model_shards > 1``, ``polymul-real`` dispatches the DISTRIBUTED real
    tier (``core.fft.distributed.make_sharded_polymul_real``): sequence
    sharded over a ``model`` mesh axis, Hermitian split per shard, paired
    inverse at the collective level — ~0.58x the complex distributed
    path's interconnect bytes.

    ``op='polymul'`` is the complex endpoint (payloads are cast to
    complex64 — real requests belong on ``polymul-real``).

    ``op='polymul-mod'`` is the exact modular endpoint (paper §5's crypto
    motivation): negacyclic products mod (x^n + 1, q) through the fused
    NTT kernel — bit-exact, so results can feed an RLWE/FHE pipeline.
    With ``model_shards > 1`` it dispatches the distributed four-step NTT
    (``core.ntt.distributed``) over a ``data`` mesh axis of that many
    devices — the serve endpoint for the planner's distributed exact tier.
    """

    def __init__(self, n: int, batch: int, op: str = "fft",
                 modulus_bits: int | None = None, model_shards: int = 1):
        self.n = n
        self.batch = batch
        self.op = op
        self.ntt_params = None
        self.rns = None
        self.mesh = None
        self.plan = None
        self.route = op
        self.q: queue.Queue = queue.Queue()
        self.results: dict[int, np.ndarray] = {}
        self.done = threading.Event()
        if op == "fft":
            self.plan = fft_core.plan(n, batch)
            self._fn = jax.jit(lambda x: fft_core.fft(x))
        elif op == "rfft":
            self.plan = fft_core.plan(n, batch, real=True)
            self.route = "rfft-real"
            self._fn = jax.jit(lambda x: fft_core.rfft(x))
        elif op == "polymul":
            self.plan = fft_core.plan(n, batch)
            self._fn = jax.jit(lambda a, b: fft_core.polymul(
                a.astype(jnp.complex64), b.astype(jnp.complex64),
                mode="circular"))
        elif op == "polymul-real" and model_shards > 1:
            from repro.core.fft import distributed as dfft
            if batch % 2:
                raise ValueError("distributed polymul-real pairs products "
                                 f"for the shared inverse; --batch must be "
                                 f"even, got {batch}")
            # An explicit --model-shards request pins the distributed real
            # tier even where the planner's policy would keep a short
            # sequence local; ``force_distributed`` makes the planner
            # validate the shape and emit the plan actually executed.
            self.plan = fft_core.plan(n, batch, real=True,
                                      model_shards=model_shards,
                                      force_distributed=True)
            self.route = "polymul-real-distributed"
            self.mesh = jax.make_mesh((model_shards,), ("model",))
            self._fn = jax.jit(dfft.make_sharded_polymul_real(
                self.mesh, batch_axes=()))
        elif op == "polymul-real":
            self.plan = fft_core.plan(n, batch, real=True)
            self.route = "polymul-real-packed"
            self._fn = jax.jit(lambda a, b: fft_core.polymul_real(
                a, b, mode="circular"))
        elif op == "polymul-mod" and model_shards > 1:
            if modulus_bits is not None and modulus_bits > 30:
                raise ValueError("distributed polymul-mod is single-limb: "
                                 "RNS (modulus_bits > 30) shards limbs, not "
                                 "the sequence")
            from repro.core.ntt import NTTParams
            from repro.core.ntt import distributed as dntt
            # An explicit --model-shards request pins the distributed tier
            # even where the planner's policy would keep a short sequence
            # local; the planner emits the plan actually executed.
            self.plan = fft_core.plan(n, batch, exact=True,
                                      model_shards=model_shards,
                                      force_distributed=True)
            self.route = "polymul-mod-distributed"
            self.ntt_params = NTTParams.make(
                n, bits=30 if modulus_bits is None else modulus_bits)
            self.mesh = jax.make_mesh((model_shards,), ("data",))
            self._fn = jax.jit(dntt.make_sharded_ntt_polymul(
                self.mesh, self.ntt_params))
        elif op == "polymul-mod":
            self.plan = fft_core.plan(n, batch, exact=True)
            # ``modulus_bits`` is the request-level knob: single-word q
            # (< 2^31) stays on the fused uint32 kernel; anything wider
            # routes through the RNS layer, which picks the limb count to
            # cover Q and runs all limbs in ONE kernel launch.
            if modulus_bits is not None and modulus_bits > 30:
                from repro.core.ntt import RNSParams
                self.rns = RNSParams.make(n, modulus_bits=modulus_bits)
                from repro.core.ntt import rns_polymul
                self._fn = functools.partial(rns_polymul, rns=self.rns)
            else:
                from repro.core.ntt import NTTParams
                from repro.kernels import ntt as kntt
                # <= 30 bits stays single-word and HONORS the request:
                # choose_modulus validates the width against n and picks
                # the largest q < 2^modulus_bits (default 30).
                self.ntt_params = NTTParams.make(
                    n, bits=30 if modulus_bits is None else modulus_bits)
                self._fn = functools.partial(kntt.ntt_polymul,
                                             params=self.ntt_params)
        else:
            raise ValueError(op)

    def warmup(self) -> None:
        """Compile the batch function before serving (deploy-time warmup):
        the reported throughput is steady-state, not trace+compile."""
        n, batch = self.n, self.batch
        if self.op == "fft":
            jax.block_until_ready(self._fn(jnp.zeros((batch, n),
                                                     jnp.complex64)))
        elif self.op == "rfft":
            jax.block_until_ready(self._fn(jnp.zeros((batch, n),
                                                     jnp.float32)))
        elif self.rns is not None:
            z = np.zeros((batch, n), object)
            z += 0   # python-int zeros, as the RNS route receives
            self._fn(z, z)
        elif self.op == "polymul-mod":
            z = jnp.zeros((batch, n), jnp.uint32)
            jax.block_until_ready(self._fn(z, z))
        elif self.op == "polymul":
            z = jnp.zeros((batch, n), jnp.complex64)   # the payload dtype
            jax.block_until_ready(self._fn(z, z))
        else:
            z = jnp.zeros((batch, n), jnp.float32)
            jax.block_until_ready(self._fn(z, z))

    def submit(self, req_id: int, payload):
        self.q.put((req_id, payload))

    def _collect(self, timeout=0.05):
        items = []
        deadline = time.time() + timeout
        while len(items) < self.batch and time.time() < deadline:
            try:
                items.append(self.q.get(timeout=max(
                    0.0, deadline - time.time())))
            except queue.Empty:
                break
        return items

    def run(self, total_requests: int) -> dict:
        served = 0
        t0 = time.time()
        batches = 0
        compute_s = 0.0
        while served < total_requests:
            items = self._collect()
            if not items:
                continue
            ids = [i for i, _ in items]
            pay = [p for _, p in items]
            # pad the tail batch
            while len(pay) < self.batch:
                pay.append(pay[-1])
            t_c = time.time()
            if self.op == "fft":
                x = jnp.asarray(np.stack(pay)).astype(jnp.complex64)
                out = np.asarray(self._fn(x))
            elif self.op == "rfft":
                x = jnp.asarray(np.stack(pay)).astype(jnp.float32)
                out = np.asarray(self._fn(x))
            elif self.rns is not None:
                # Big-Q coefficients are python ints (object dtype): the RNS
                # route splits to per-limb uint32 residues host-side, runs
                # the limb-batched kernel, and CRT-reconstructs mod Q.
                a = np.stack([np.asarray(p[0], object) for p in pay])
                b = np.stack([np.asarray(p[1], object) for p in pay])
                out = self._fn(a, b)
            else:
                a = jnp.asarray(np.stack([p[0] for p in pay]))
                b = jnp.asarray(np.stack([p[1] for p in pay]))
                out = np.asarray(self._fn(a, b))
            compute_s += time.time() - t_c
            for j, rid in enumerate(ids):
                self.results[rid] = out[j]
            served += len(ids)
            batches += 1
        dt = time.time() - t0
        return {"served": served, "batches": batches, "seconds": dt,
                "throughput_per_s": served / dt,
                # compute-only rate: excludes queue collection waits, so
                # endpoint comparisons reflect the kernels, not the driver
                "compute_seconds": compute_s,
                "compute_throughput_per_s": served / max(compute_s, 1e-9)}


def run_fft_service(args) -> dict:
    rng = np.random.default_rng(0)
    svc = FFTService(args.n, args.batch, args.op,
                     modulus_bits=args.modulus_bits,
                     model_shards=args.model_shards)
    svc.warmup()

    def producer():
        for rid in range(args.requests):
            if args.op == "fft":
                payload = (rng.standard_normal(args.n)
                           + 1j * rng.standard_normal(args.n))
            elif args.op == "rfft":
                payload = rng.standard_normal(args.n).astype(np.float32)
            elif args.op == "polymul":
                # The complex endpoint gets genuinely complex payloads:
                # zero-imag inputs would let XLA strip half the butterflies
                # at compile time and misrepresent the endpoint's cost
                # (real requests belong on polymul-real).
                payload = (
                    (rng.standard_normal(args.n)
                     + 1j * rng.standard_normal(args.n)).astype(np.complex64),
                    (rng.standard_normal(args.n)
                     + 1j * rng.standard_normal(args.n)).astype(np.complex64))
            elif args.op == "polymul-mod" and svc.rns is not None:
                from repro.core.ntt.rns import random_poly
                payload = (random_poly(rng, args.n, svc.rns.modulus),
                           random_poly(rng, args.n, svc.rns.modulus))
            elif args.op == "polymul-mod":
                q = svc.ntt_params.q
                payload = (rng.integers(0, q, args.n).astype(np.uint32),
                           rng.integers(0, q, args.n).astype(np.uint32))
            else:
                payload = (rng.standard_normal(args.n).astype(np.float32),
                           rng.standard_normal(args.n).astype(np.float32))
            svc.submit(rid, payload)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    stats = svc.run(args.requests)
    th.join()
    # verify one result against numpy
    rid = 0
    if args.op == "fft":
        pass  # payload not retained; correctness covered by kernel tests
    limbs = f" limbs={svc.rns.k} Q~2^{svc.rns.modulus.bit_length()}" \
        if svc.rns is not None else ""
    print(f"[serve:fft] op={args.op}{limbs} route={svc.route} n={args.n} "
          f"batch={args.batch} served={stats['served']} in "
          f"{stats['seconds']:.2f}s "
          f"-> {stats['throughput_per_s']:.1f} req/s "
          f"(compute-only {stats['compute_throughput_per_s']:.1f} req/s) "
          f"[{svc.plan.describe()}]")
    return stats


# ---------------------------------------------------------------------------
# LM decode service
# ---------------------------------------------------------------------------

def run_lm_service(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.scaled_down()
    params = lm.init_params(cfg, jax.random.key(0))
    B, S = args.batch, args.prompt_len
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    t0 = time.time()
    capacity = S + args.gen
    logits, state = lm.prefill(cfg, params, tokens,
                               cache_capacity=capacity)
    decode = jax.jit(lambda p, st, tok, pos: lm.decode_step(
        cfg, p, st, tok, pos))
    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits_i, state = decode(params, state, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits_i, axis=-1)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = B * args.gen
    print(f"[serve:lm] arch={cfg.name} batch={B} prompt={S} gen={args.gen} "
          f"-> {toks / dt:.1f} tok/s (incl. prefill, jit warmup)")
    return {"tokens_per_s": toks / dt, "generated": np.stack(out_tokens)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", choices=["fft", "lm"], default="fft")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--op", default="fft",
                    choices=["fft", "rfft", "polymul", "polymul-real",
                             "polymul-mod"])
    ap.add_argument("--modulus-bits", type=int, default=None,
                    help="polymul-mod target modulus width; > 30 routes "
                         "through the multi-limb RNS/CRT layer (limb count "
                         "chosen to cover Q, docs/ntt.md)")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="polymul-mod / polymul-real: shard the sequence "
                         "over this many devices via the distributed "
                         "four-step NTT (core/ntt/distributed.py) or the "
                         "real-Hermitian four-step FFT "
                         "(core/fft/distributed.py) — the serve endpoints "
                         "for the planner's distributed tiers")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)
    if args.service == "fft":
        return run_fft_service(args)
    return run_lm_service(args)


if __name__ == "__main__":
    main()
