"""Serving drivers.

--service fft  : batched FFT / polynomial-multiplication service — the
                 paper's actual workload (batched transforms at maximum
                 throughput). Requests arrive on a queue, are batched to
                 the configured batch size, executed through the Fourier
                 core (Pallas on TPU / XLA path on CPU), and throughput is
                 reported. This is deliverable (b)'s end-to-end serve
                 driver for the paper's kind (a compute-primitive paper).

--service lm   : batched greedy decode for any --arch (reduced with
                 --smoke): prefill then token-by-token decode_step.

Example:
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 64 --requests 512 --op polymul-real
  # exact modular (RLWE negacyclic) polymul endpoint:
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 32 --requests 128 --op polymul-mod
  # multi-limb RNS route for FHE-scale moduli (limb count from the bits):
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 8 --requests 16 --op polymul-mod --modulus-bits 120
  PYTHONPATH=src python -m repro.launch.serve --service lm \
      --arch qwen3-1.7b --smoke --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import fft as fft_core
from repro.models import lm


# ---------------------------------------------------------------------------
# FFT service
# ---------------------------------------------------------------------------

class FFTService:
    """Batched transform service with a request queue and a worker loop.

    ``op='polymul-mod'`` is the exact modular endpoint (paper §5's crypto
    motivation): negacyclic products mod (x^n + 1, q) through the fused
    NTT kernel — bit-exact, so results can feed an RLWE/FHE pipeline.
    """

    def __init__(self, n: int, batch: int, op: str = "fft",
                 modulus_bits: int | None = None):
        self.n = n
        self.batch = batch
        self.op = op
        self.ntt_params = None
        self.rns = None
        self.q: queue.Queue = queue.Queue()
        self.results: dict[int, np.ndarray] = {}
        self.done = threading.Event()
        if op == "fft":
            self._fn = jax.jit(lambda x: fft_core.fft(x))
        elif op == "polymul":
            self._fn = jax.jit(
                lambda a, b: fft_core.polymul(a, b, mode="circular"))
        elif op == "polymul-real":
            self._fn = jax.jit(
                lambda a, b: fft_core.polymul(a, b, mode="circular"))
        elif op == "polymul-mod":
            # ``modulus_bits`` is the request-level knob: single-word q
            # (< 2^31) stays on the fused uint32 kernel; anything wider
            # routes through the RNS layer, which picks the limb count to
            # cover Q and runs all limbs in ONE kernel launch.
            if modulus_bits is not None and modulus_bits > 30:
                from repro.core.ntt import RNSParams
                self.rns = RNSParams.make(n, modulus_bits=modulus_bits)
                from repro.core.ntt import rns_polymul
                self._fn = functools.partial(rns_polymul, rns=self.rns)
            else:
                from repro.core.ntt import NTTParams
                from repro.kernels import ntt as kntt
                # <= 30 bits stays single-word and HONORS the request:
                # choose_modulus validates the width against n and picks
                # the largest q < 2^modulus_bits (default 30).
                self.ntt_params = NTTParams.make(
                    n, bits=30 if modulus_bits is None else modulus_bits)
                self._fn = functools.partial(kntt.ntt_polymul,
                                             params=self.ntt_params)
        else:
            raise ValueError(op)

    def submit(self, req_id: int, payload):
        self.q.put((req_id, payload))

    def _collect(self, timeout=0.05):
        items = []
        deadline = time.time() + timeout
        while len(items) < self.batch and time.time() < deadline:
            try:
                items.append(self.q.get(timeout=max(
                    0.0, deadline - time.time())))
            except queue.Empty:
                break
        return items

    def run(self, total_requests: int) -> dict:
        served = 0
        t0 = time.time()
        batches = 0
        while served < total_requests:
            items = self._collect()
            if not items:
                continue
            ids = [i for i, _ in items]
            pay = [p for _, p in items]
            # pad the tail batch
            while len(pay) < self.batch:
                pay.append(pay[-1])
            if self.op == "fft":
                x = jnp.asarray(np.stack(pay)).astype(jnp.complex64)
                out = np.asarray(self._fn(x))
            elif self.rns is not None:
                # Big-Q coefficients are python ints (object dtype): the RNS
                # route splits to per-limb uint32 residues host-side, runs
                # the limb-batched kernel, and CRT-reconstructs mod Q.
                a = np.stack([np.asarray(p[0], object) for p in pay])
                b = np.stack([np.asarray(p[1], object) for p in pay])
                out = self._fn(a, b)
            else:
                a = jnp.asarray(np.stack([p[0] for p in pay]))
                b = jnp.asarray(np.stack([p[1] for p in pay]))
                out = np.asarray(self._fn(a, b))
            for j, rid in enumerate(ids):
                self.results[rid] = out[j]
            served += len(ids)
            batches += 1
        dt = time.time() - t0
        return {"served": served, "batches": batches, "seconds": dt,
                "throughput_per_s": served / dt}


def run_fft_service(args) -> dict:
    rng = np.random.default_rng(0)
    svc = FFTService(args.n, args.batch, args.op,
                     modulus_bits=args.modulus_bits)

    def producer():
        for rid in range(args.requests):
            if args.op == "fft":
                payload = (rng.standard_normal(args.n)
                           + 1j * rng.standard_normal(args.n))
            elif args.op == "polymul-mod" and svc.rns is not None:
                from repro.core.ntt.rns import random_poly
                payload = (random_poly(rng, args.n, svc.rns.modulus),
                           random_poly(rng, args.n, svc.rns.modulus))
            elif args.op == "polymul-mod":
                q = svc.ntt_params.q
                payload = (rng.integers(0, q, args.n).astype(np.uint32),
                           rng.integers(0, q, args.n).astype(np.uint32))
            else:
                payload = (rng.standard_normal(args.n).astype(np.float32),
                           rng.standard_normal(args.n).astype(np.float32))
            svc.submit(rid, payload)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    stats = svc.run(args.requests)
    th.join()
    # verify one result against numpy
    rid = 0
    if args.op == "fft":
        pass  # payload not retained; correctness covered by kernel tests
    limbs = f" limbs={svc.rns.k} Q~2^{svc.rns.modulus.bit_length()}" \
        if svc.rns is not None else ""
    print(f"[serve:fft] op={args.op}{limbs} n={args.n} batch={args.batch} "
          f"served={stats['served']} in {stats['seconds']:.2f}s "
          f"-> {stats['throughput_per_s']:.1f} req/s")
    return stats


# ---------------------------------------------------------------------------
# LM decode service
# ---------------------------------------------------------------------------

def run_lm_service(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.scaled_down()
    params = lm.init_params(cfg, jax.random.key(0))
    B, S = args.batch, args.prompt_len
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    t0 = time.time()
    capacity = S + args.gen
    logits, state = lm.prefill(cfg, params, tokens,
                               cache_capacity=capacity)
    decode = jax.jit(lambda p, st, tok, pos: lm.decode_step(
        cfg, p, st, tok, pos))
    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits_i, state = decode(params, state, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits_i, axis=-1)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = B * args.gen
    print(f"[serve:lm] arch={cfg.name} batch={B} prompt={S} gen={args.gen} "
          f"-> {toks / dt:.1f} tok/s (incl. prefill, jit warmup)")
    return {"tokens_per_s": toks / dt, "generated": np.stack(out_tokens)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", choices=["fft", "lm"], default="fft")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--op", default="fft",
                    choices=["fft", "polymul", "polymul-real",
                             "polymul-mod"])
    ap.add_argument("--modulus-bits", type=int, default=None,
                    help="polymul-mod target modulus width; > 30 routes "
                         "through the multi-limb RNS/CRT layer (limb count "
                         "chosen to cover Q, docs/ntt.md)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)
    if args.service == "fft":
        return run_fft_service(args)
    return run_lm_service(args)


if __name__ == "__main__":
    main()
