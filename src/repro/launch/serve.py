"""Serving drivers.

--service fft    : single-op batched transform service. The op, its route
                   (local / RNS / distributed), payload dtype, warmup
                   shape, traffic generator and result verifier all come
                   from the op-dispatch registry (``launch/ops.py``) — the
                   same table the continuous-batching engine, benchmarks
                   and tests dispatch through.

--service engine : multiplexing continuous-batching engine
                   (``launch/engine.py``): a mixed stream of requests,
                   each with its own (op, n), is shape-bucketed and served
                   from ONE process with tail batches at actual size,
                   deferred device sync (next batch transfers while the
                   current one computes), bounded-queue backpressure, and
                   per-request p50/p99 latency reported alongside
                   throughput (docs/serving.md).

--service lm     : batched greedy decode for any --arch (reduced with
                   --smoke): prefill then token-by-token decode_step.

Example:
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 64 --requests 512 --op polymul-real
  # exact modular (RLWE negacyclic) polymul endpoint:
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 32 --requests 128 --op polymul-mod
  # multi-limb RNS route for FHE-scale moduli (limb count from the bits):
  PYTHONPATH=src python -m repro.launch.serve --service fft --n 1024 \
      --batch 8 --requests 16 --op polymul-mod --modulus-bits 120
  # distributed exact tier (four-step NTT over 8 sequence shards):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --service fft --n 1024 --batch 4 \
      --requests 16 --op polymul-mod --model-shards 8
  # mixed-op continuous batching: one engine, four ops, two lengths,
  # the polymul-real / polymul-mod buckets on the distributed tier:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --service engine \
      --ops fft,rfft,polymul-real,polymul-mod --ns 512,1024 \
      --model-shards 8 --batch 8 --requests 64
  PYTHONPATH=src python -m repro.launch.serve --service lm \
      --arch qwen3-1.7b --smoke --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch import ops as op_registry
from repro.launch.engine import ServeEngine
from repro.models import lm


# ---------------------------------------------------------------------------
# FFT service (single-op): a thin wrapper over the registry + engine
# ---------------------------------------------------------------------------

class FFTService:
    """Single-op transform service: one registry bucket on the engine.

    All op dispatch — route selection (local packed / RNS / distributed),
    payload dtype, warmup shape — flows through ``launch/ops.py``; this
    class only pins ONE (op, n) bucket and keeps the legacy surface
    (``plan``/``route``/``_fn``/``ntt_params``/``rns``/``mesh``) that
    tests and callers assert against. Invalid combinations (RNS +
    model_shards, unknown knobs, non-tileable shapes) raise
    :class:`~repro.launch.ops.OpConfigError` from the registry's own
    validation, here at construction.
    """

    def __init__(self, n: int, batch: int, op: str = "fft",
                 modulus_bits: int | None = None, model_shards: int = 1,
                 auto: bool = False):
        self.n = n
        self.batch = batch
        self.op = op
        self.engine = ServeEngine(max_batch=batch,
                                  modulus_bits=modulus_bits,
                                  model_shards=model_shards,
                                  auto=auto)
        # strict: knobs the op does not consume are config errors, not
        # silently ignored flags
        self.bound = self.engine.register(op, n, strict=True)
        self.plan = self.bound.plan
        self.route = self.bound.route
        self._fn = self.bound.fn
        self.ntt_params = self.bound.ntt_params
        self.rns = self.bound.rns
        self.mesh = self.bound.mesh
        self.results = self.engine.results

    def warmup(self) -> None:
        """Compile the batch function before serving (deploy-time warmup):
        the reported throughput is steady-state, not trace+compile."""
        self.engine.warmup()

    def submit(self, req_id: int, payload):
        self.engine.submit(self.op, self.n, payload, rid=req_id)

    def run(self, total_requests: int) -> dict:
        return self.engine.run(total_requests)


def run_fft_service(args) -> dict:
    rng = np.random.default_rng(0)
    svc = FFTService(args.n, args.batch, args.op,
                     modulus_bits=args.modulus_bits,
                     model_shards=args.model_shards,
                     auto=args.auto)
    svc.warmup()
    first: dict[int, object] = {}

    def producer():
        for rid in range(args.requests):
            payload = svc.bound.random_payload(rng)
            if rid == 0:
                first[rid] = payload
            svc.submit(rid, payload)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    stats = svc.run(args.requests)
    th.join()
    # verify one served result against the registry's numpy oracle
    if first:
        svc.bound.verify(first[0], svc.results[0])
    limbs = f" limbs={svc.rns.k} Q~2^{svc.rns.modulus.bit_length()}" \
        if svc.rns is not None else ""
    lat = stats["latency_ms"]
    print(f"[serve:fft] op={args.op}{limbs} route={svc.route} n={args.n} "
          f"batch={args.batch} served={stats['served']} in "
          f"{stats['seconds']:.2f}s "
          f"-> {stats['throughput_per_s']:.1f} req/s "
          f"(compute-only {stats['compute_throughput_per_s']:.1f} req/s) "
          f"p50={lat['p50']:.2f}ms p99={lat['p99']:.2f}ms "
          f"[{svc.plan.describe()}]")
    return stats


# ---------------------------------------------------------------------------
# Mixed-op continuous-batching engine service
# ---------------------------------------------------------------------------

def _watchdog_cfg_from_args(args):
    from repro.ft.watchdog import WatchdogConfig
    if (args.watchdog_threshold is None and args.watchdog_evict_after
            is None and args.watchdog_warmup is None):
        return None
    base = WatchdogConfig()
    return WatchdogConfig(
        threshold=(base.threshold if args.watchdog_threshold is None
                   else args.watchdog_threshold),
        evict_after=(base.evict_after if args.watchdog_evict_after is None
                     else args.watchdog_evict_after),
        warmup_steps=(base.warmup_steps if args.watchdog_warmup is None
                      else args.watchdog_warmup))


def _arm_chaos(engine: ServeEngine, args) -> None:
    """Deterministic straggler injection for exercising the elastic path
    at the CLI (tests/CI): batches after --inject-straggler-after sleep
    --inject-straggler-ms before dispatch, so the watchdog's EWMA sees a
    consecutive run of breaches. Armed only on the FIRST generation —
    after an elastic restart the resized engine serves cleanly."""
    if not args.inject_straggler_ms or engine.restarts > 0:
        return
    counter = {"i": 0}

    def make_slow(fn):
        def slow(*a):
            counter["i"] += 1
            if counter["i"] > args.inject_straggler_after:
                time.sleep(args.inject_straggler_ms / 1e3)
            return fn(*a)
        return slow

    for bound in engine._bound.values():
        bound.fn = make_slow(bound.fn)


def run_engine_service(args) -> dict:
    """Serve a mixed (op, n) stream from one engine process.

    Buckets come from the cross product of ``--ops`` and ``--ns``; the
    process-level ``--modulus-bits`` / ``--model-shards`` context is
    narrowed per op (ops without that route stay local), so one engine can
    serve local fft next to the distributed polymul-mod tier. ``--auto``
    hands tier/packing choice per bucket to the cost model
    (docs/planner.md) and reports predicted-vs-observed per-bucket cost.
    One result per bucket is verified against the registry's numpy oracle
    after the drain.

    With ``--snapshot-dir`` the process is preemption-safe
    (docs/fault_tolerance.md): SIGTERM stops admission, the engine drains
    every already-admitted request, and the lifetime stats + bucket config
    + watchdog state are snapshotted through ``ft.checkpoint``; a restart
    with the same ``--snapshot-dir`` warm-restarts from the snapshot
    (buckets re-bind on the restart-time context, counters carry over).

    ``--elastic`` (requires ``--snapshot-dir``) closes the watchdog loop
    AT the CLI: an eviction drains the engine, snapshots, and
    warm-restarts it with ``--model-shards`` halved (floor 1) — the
    checkpoint -> resize -> restore path that previously only tests could
    drive — then keeps serving the remaining requests.
    """
    ops = [s.strip() for s in args.ops.split(",") if s.strip()]
    ns = [int(s) for s in args.ns.split(",") if s.strip()]
    from repro.ft import checkpoint as ckpt_lib
    from repro.launch.engine import EngineStopped

    # chaos fault model (docs/fault_tolerance.md): one virtual crossbar
    # array per bucket plus enough spares to quarantine every one of them
    fault_model = None
    if args.inject_faults or args.inject_stuck:
        from repro.core.pim import FaultModel
        n_buckets = max(4, len(ops) * len(ns))
        fault_model = FaultModel(seed=args.inject_fault_seed,
                                 bitflip_per_gate=args.inject_faults,
                                 stuck_per_array=args.inject_stuck,
                                 n_arrays=n_buckets, spares=n_buckets)

    holder: dict = {"engine": None, "evicted": False}

    def _on_evict(eng, batch_idx):
        if not args.elastic:
            return
        holder["evicted"] = True
        print(f"[serve:engine] watchdog evicted batch {batch_idx}: "
              f"draining for elastic resize", flush=True)
        # request_stop on a separate thread for the same reason as the
        # SIGTERM handler: never take the engine's condition lock from
        # a frame that may already hold it.
        threading.Thread(target=eng.request_stop, daemon=True).start()

    wd_cfg = _watchdog_cfg_from_args(args)
    if args.snapshot_dir and ckpt_lib.latest_step(args.snapshot_dir) \
            is not None:
        engine = ServeEngine.from_snapshot(args.snapshot_dir,
                                           model_shards=args.model_shards,
                                           max_batch=args.batch,
                                           watchdog_cfg=wd_cfg,
                                           on_evict=_on_evict)
        print(f"[serve:engine] warm restart #{engine.restarts} from "
              f"{args.snapshot_dir} "
              f"(lifetime served: {engine.stats(seconds=1, busy_s=1)['lifetime']['served']})")
    else:
        engine = ServeEngine(max_batch=args.batch,
                             max_pending=args.max_pending,
                             modulus_bits=args.modulus_bits,
                             model_shards=args.model_shards,
                             auto=args.auto,
                             verified=args.verify,
                             fault_model=fault_model,
                             watchdog_cfg=wd_cfg,
                             on_evict=_on_evict)
    holder["engine"] = engine
    prev_term = None
    if args.snapshot_dir:
        import signal

        def _on_term(signum, frame):
            # drain-and-snapshot path: stop admitting; run() finishes the
            # admitted backlog and returns, then the snapshot lands below.
            # Installed BEFORE warmup: a preemption during compile still
            # drains (to an empty backlog) and snapshots. request_stop runs
            # on a SEPARATE thread: the handler executes on the main
            # thread's frame, which may be INSIDE the engine's condition
            # lock — taking it from the handler would self-deadlock.
            threading.Thread(target=holder["engine"].request_stop,
                             daemon=True).start()
        prev_term = signal.signal(signal.SIGTERM, _on_term)

    rng = np.random.default_rng(0)
    combos = [(op, n) for op in ops for n in ns]

    def serve_round(engine: ServeEngine, n_requests: int) -> dict:
        """One engine generation: register + warmup, produce, drain,
        verify one result per bucket. Returns the round's stats."""
        for op in ops:
            for n in ns:
                engine.register(op, n)
        _arm_chaos(engine, args)
        engine.warmup()
        kept: dict[tuple[str, int], tuple[int, object]] = {}
        # chaos runs verify EVERY delivered result against the numpy
        # oracle — the "zero incorrect results" half of the chaos pin
        kept_all: dict[int, tuple[str, int, object]] = {}

        def producer():
            try:
                for i in range(n_requests):
                    op, n = combos[i % len(combos)]
                    payload = engine.bound(op, n).random_payload(rng)
                    rid = engine.submit(op, n, payload)
                    if (op, n) not in kept:
                        kept[(op, n)] = (rid, payload)
                    if fault_model is not None:
                        kept_all[rid] = (op, n, payload)
            except EngineStopped:
                pass  # draining toward a snapshot: shed the rest

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        # sync marker for supervisors/tests: warmup done, handler armed
        print(f"[serve:engine] serving {n_requests} requests "
              f"across {len(combos)} buckets", flush=True)
        stats = engine.run(n_requests)
        th.join()
        for (op, n), (rid, payload) in kept.items():
            if rid in engine.results:   # absent only if shed in a drain
                engine.bound(op, n).verify(payload, engine.results[rid])
        for rid, (op, n, payload) in kept_all.items():
            if rid in engine.results:
                engine.bound(op, n).verify(payload, engine.results[rid])
        return stats

    try:
        remaining = args.requests
        while True:
            holder["evicted"] = False
            stats = serve_round(engine, remaining)
            remaining -= stats["served"]
            if args.elastic and holder["evicted"] and remaining > 0:
                new_shards = max(1, engine.ctx.model_shards // 2)
                print(f"[serve:engine] elastic restart: model_shards "
                      f"{engine.ctx.model_shards} -> {new_shards}, "
                      f"{remaining} requests left", flush=True)
                engine = engine.elastic_restart(args.snapshot_dir,
                                                model_shards=new_shards)
                holder["engine"] = engine
                continue
            break
        if fault_model is not None:
            integ = [b["integrity"] for b in stats["buckets"].values()]
            detected = sum(v["corrupted"] for v in integ)
            retried = sum(v["retried"] for v in integ)
            fell_back = sum(v["fell_back"] for v in integ)
            print(f"[serve:engine] chaos: detected={detected} "
                  f"retried={retried} fell_back={fell_back} "
                  f"quarantined={len(fault_model.quarantined)}", flush=True)
            if detected < 1 or retried < 1:
                raise SystemExit(
                    "chaos run produced no detected->retried event: the "
                    "injection settings are not exercising the ABFT "
                    "recovery path (raise --inject-faults or set "
                    "--inject-stuck)")
        if args.snapshot_dir:
            path = engine.snapshot(args.snapshot_dir)
            print(f"[serve:engine] snapshot -> {path}")
    finally:
        if prev_term is not None:
            # the handler closes over the engine holder — leaving it
            # installed would hijack SIGTERM for any later engine in the
            # process (e.g. an in-process warm restart or the test runner)
            import signal
            signal.signal(signal.SIGTERM, prev_term)

    lat = stats["latency_ms"]
    print(f"[serve:engine] buckets={len(stats['buckets'])} "
          f"served={stats['served']} in {stats['seconds']:.2f}s "
          f"-> {stats['throughput_per_s']:.1f} req/s "
          f"(compute-only {stats['compute_throughput_per_s']:.1f} req/s) "
          f"p50={lat['p50']:.2f}ms p90={lat['p90']:.2f}ms "
          f"p99={lat['p99']:.2f}ms")
    for name, b in stats["buckets"].items():
        pred = b.get("predicted_s_per_req")
        # predictions span ns (tiny local XLA) to ms (PIM waves): 3 sig figs
        cost = (f" predicted={pred * 1e6:.3g}us/req "
                f"({b['predicted_tier']}/{b['predicted_backend']})"
                if pred is not None else "")
        print(f"[serve:engine]   {name} route={b['route']} "
              f"served={b['served']} batches={b['batches']} "
              f"mean_batch={b['mean_batch']:.1f} "
              f"utilization={b['utilization']:.2f}{cost}")
    return stats


# ---------------------------------------------------------------------------
# LM decode service
# ---------------------------------------------------------------------------

def run_lm_service(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.scaled_down()
    params = lm.init_params(cfg, jax.random.key(0))
    B, S = args.batch, args.prompt_len
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    t0 = time.time()
    capacity = S + args.gen
    logits, state = lm.prefill(cfg, params, tokens,
                               cache_capacity=capacity)
    decode = jax.jit(lambda p, st, tok, pos: lm.decode_step(
        cfg, p, st, tok, pos))
    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits_i, state = decode(params, state, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits_i, axis=-1)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = B * args.gen
    print(f"[serve:lm] arch={cfg.name} batch={B} prompt={S} gen={args.gen} "
          f"-> {toks / dt:.1f} tok/s (incl. prefill, jit warmup)")
    return {"tokens_per_s": toks / dt, "generated": np.stack(out_tokens)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", choices=["fft", "engine", "lm"],
                    default="fft")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=32,
                    help="single-op batch / engine continuous-batching "
                         "block cap (tail batches run at actual size)")
    ap.add_argument("--requests", type=int, default=256)
    # the op surface is DERIVED from the registry: choices, help and
    # knob applicability can never drift from the dispatch table
    ap.add_argument("--op", default="fft", choices=op_registry.op_names(),
                    help=op_registry.cli_help())
    ap.add_argument("--ops", default="fft,rfft,polymul-real",
                    help="engine service: comma-separated op mix "
                         f"(choices: {', '.join(op_registry.op_names())})")
    ap.add_argument("--ns", default=None,
                    help="engine service: comma-separated sequence "
                         "lengths (default: --n)")
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="engine service: bounded admission queue — "
                         "producers block (backpressure) when full")
    ap.add_argument("--snapshot-dir", default=None,
                    help="engine service: preemption-safe state dir — "
                         "SIGTERM drains in-flight buckets and snapshots "
                         "engine stats + bucket config there; a restart "
                         "with the same dir warm-restarts from it "
                         "(docs/fault_tolerance.md)")
    ap.add_argument("--modulus-bits", type=int, default=None,
                    help=op_registry.cli_knob_help(
                        "modulus_bits",
                        "target modulus width; > 30 routes through the "
                        "multi-limb RNS/CRT layer (docs/ntt.md)"))
    ap.add_argument("--model-shards", type=int, default=1,
                    help=op_registry.cli_knob_help(
                        "model_shards",
                        "shard the sequence over this many devices via "
                        "the distributed four-step NTT/FFT tiers"))
    ap.add_argument("--auto", action="store_true",
                    help="cost-model auto-tiering (docs/planner.md): the "
                         "planner chooses tier and packing per bucket; "
                         "--model-shards becomes the AVAILABLE device "
                         "count, and stats report predicted-vs-observed "
                         "per-bucket cost")
    ap.add_argument("--elastic", action="store_true",
                    help="engine service: on a watchdog eviction, drain + "
                         "snapshot + warm-restart with --model-shards "
                         "halved (requires --snapshot-dir)")
    ap.add_argument("--watchdog-threshold", type=float, default=None,
                    help="engine service: straggler threshold (x EWMA)")
    ap.add_argument("--watchdog-evict-after", type=int, default=None,
                    help="engine service: consecutive breaches before "
                         "eviction")
    ap.add_argument("--watchdog-warmup", type=int, default=None,
                    help="engine service: EWMA warmup batches")
    ap.add_argument("--inject-straggler-ms", type=float, default=0.0,
                    help="chaos: sleep this long before each dispatch "
                         "after --inject-straggler-after batches "
                         "(first engine generation only; drives the "
                         "--elastic path deterministically in tests)")
    ap.add_argument("--inject-straggler-after", type=int, default=0,
                    help="chaos: batches served cleanly before the "
                         "injected straggling starts")
    ap.add_argument("--verify", action="store_true",
                    help="engine service: ABFT integrity gate "
                         "(docs/fault_tolerance.md) — every deliverable "
                         "batch passes its op's check before any client "
                         "sees a result; detected corruption triggers "
                         "bounded re-execution, then a circuit-breaker "
                         "re-bind with the PIM backend quarantined")
    ap.add_argument("--inject-faults", type=float, default=0.0,
                    metavar="RATE",
                    help="chaos: per-gate transient bit-flip rate for a "
                         "seeded fault model wrapping each engine bucket; "
                         "delivered rows are corrupted deterministically "
                         "per (seed, array, dispatch) (requires --verify)")
    ap.add_argument("--inject-fault-seed", type=int, default=0,
                    help="chaos: fault model seed (replays exactly)")
    ap.add_argument("--inject-stuck", type=int, default=0,
                    help="chaos: stuck-at cells per simulated array — a "
                         "PERMANENT fault, so the bucket's retries fail "
                         "and the circuit breaker must trip (requires "
                         "--verify)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)
    if args.ns is None:
        args.ns = str(args.n)
    if args.elastic and not args.snapshot_dir:
        ap.error("--elastic requires --snapshot-dir (the eviction path "
                 "is snapshot -> resize -> restore)")
    if (args.inject_faults or args.inject_stuck) and not args.verify:
        ap.error("--inject-faults/--inject-stuck without --verify would "
                 "deliver corrupted results: chaos injection requires "
                 "the ABFT gate")
    try:
        if args.service == "fft":
            return run_fft_service(args)
        if args.service == "engine":
            return run_engine_service(args)
    except op_registry.OpConfigError as e:
        # the registry's own validation message, as a clean CLI exit
        # instead of a deep traceback
        ap.error(str(e))
    return run_lm_service(args)


if __name__ == "__main__":
    main()
