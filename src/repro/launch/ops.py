"""Unified op-dispatch registry: the one source of op truth for serving.

Every transform endpoint the repo serves — fft / rfft / polymul /
polymul-real / polymul-mod, including their RNS and distributed
parameterizations — is described ONCE here as an :class:`OpSpec` and
resolved into a :class:`BoundOp` (plan + route + jitted batch fn + payload
conventions) by ``OpSpec.bind``. ``launch/serve.py`` (both the single-op
``FFTService`` and the continuous-batching ``--service engine``),
``launch/engine.py``, ``benchmarks/run.py --smoke`` and the serve tests all
dispatch through this table instead of carrying their own per-op ``if``
ladders, so adding an endpoint is one ``register_op`` call.

The OpSpec contract (docs/serving.md):

  * ``arity``            — payload operands per request (1 = transform,
                           2 = product); the engine stacks them host-side.
  * ``bind(n, ctx)``     — validate the ``(op, n, modulus_bits,
                           model_shards)`` combination (raising
                           :class:`OpConfigError`, a ``ValueError``
                           subclass, with the registry's own message — no
                           deep ``ValueError`` from three layers down) and
                           build the executable route: planner plan,
                           route tag, jitted batch fn, and any NTT/RNS
                           params or mesh the route needs.
  * ``warmup`` payload   — zeros of the route's payload dtype, so deploy
                           warmup compiles the steady-state shape.
  * ``random_payload``   — the honest traffic generator (complex payloads
                           for the complex endpoint, big-int coefficients
                           for RNS, ...) the producers draw from.
  * ``verify``           — a numpy-oracle check of one served result
                           (exact ``==`` for the modular routes).

Config knobs that an op does not consume are rejected by ``bind`` (strict
mode, the CLI single-op path) or stripped by ``OpSpec.narrow`` (the mixed
engine, where one process-level context feeds ops with different knobs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import numpy as np


class OpConfigError(ValueError):
    """Invalid (op, n, modulus_bits, model_shards) combination, raised at
    registry-validation time — before any mesh/jit work — with a message
    that names the offending knob."""


@dataclasses.dataclass(frozen=True)
class OpContext:
    """Process-level route parameterization shared by every op of a serve
    process (CLI flags); ``OpSpec.narrow`` strips the knobs an op ignores.

    ``auto=True`` hands tier/packing choice to the cost model
    (docs/planner.md): ``model_shards`` becomes the AVAILABLE device
    count rather than a demand, and each bind asks
    ``plan(n, batch, workload=<op name>, ...)`` for the predicted-cheapest
    executable route. Strict knob validation is unchanged — knobs an op
    cannot consume are still rejected, auto only picks among routes the
    op really has.

    ``verified=True`` binds the route with ABFT integrity pricing
    (docs/fault_tolerance.md): auto plans carry the check overhead in
    their cost breakdown, and the RNS route proves its modulus is
    checkable (factors over the limb primes) at bind time. ``pim_ok=
    False`` is the circuit-breaker context: the cost model plans with
    the PIM backend marked infeasible, the re-bind a serve bucket gets
    after its (simulated) crossbar array is quarantined. Both apply to
    every op, so ``narrow`` preserves them."""
    modulus_bits: int | None = None
    model_shards: int = 1
    auto: bool = False
    verified: bool = False
    pim_ok: bool = True


@dataclasses.dataclass
class BoundOp:
    """A resolved (op, n, context): everything an executor needs.

    ``fn`` takes the stacked operand arrays (``stack``'s output, one array
    per operand) at ANY batch size — tail batches run at their actual size;
    the kernels' ``_fit_block`` clamps the VMEM block instead of padding.
    """
    spec: "OpSpec"
    n: int
    ctx: OpContext
    plan: Any                       # core.fft.planner.FFTPlan
    route: str
    fn: Callable[..., Any]
    payload_dtype: Any              # numpy dtype, or object for big ints
    ntt_params: Any = None
    rns: Any = None
    mesh: Any = None

    @property
    def key(self) -> tuple[str, int]:
        return (self.spec.name, self.n)

    def stack(self, payloads: Sequence[Any]) -> tuple:
        """Host-side batch assembly: a list of per-request payloads ->
        the operand arrays ``fn`` consumes, at the list's actual length."""
        import jax.numpy as jnp
        if self.spec.arity == 1:
            rows = [np.asarray(p, self.payload_dtype) for p in payloads]
            return (jnp.asarray(np.stack(rows)),)
        cols = tuple(
            np.stack([np.asarray(p[i], self.payload_dtype)
                      for p in payloads])
            for i in range(self.spec.arity))
        if self.payload_dtype is object:      # RNS: stays host-side
            return cols
        return tuple(jnp.asarray(c) for c in cols)

    def execute(self, payloads: Sequence[Any]):
        """Dispatch one batch (async where the route is a jitted fn)."""
        return self.fn(*self.stack(payloads))

    def to_numpy(self, out) -> np.ndarray:
        """Materialize a dispatched batch (blocks until ready)."""
        import jax
        if self.payload_dtype is not object:
            out = jax.block_until_ready(out)
        return np.asarray(out)

    def warmup(self, batch: int) -> None:
        """Compile the route at the steady-state batch (deploy warmup)."""
        zeros = self.spec.warmup_payload(self, batch)
        self.to_numpy(self.fn(*zeros))

    def random_payload(self, rng: np.random.Generator):
        return self.spec.random_payload(self, rng)

    def verify(self, payload, result: np.ndarray) -> None:
        self.spec.verify(self, payload, result)

    def check_payload(self, payload) -> None:
        """Admission guard: reject non-finite float/complex operands with
        a structured :class:`OpConfigError` BEFORE they join a batch — a
        NaN poisons every row it batches with, and once ABFT is on it
        would masquerade as an integrity failure and burn the retry
        budget on a client bug."""
        operands = (payload,) if self.spec.arity == 1 else tuple(payload)
        for i, op in enumerate(operands):
            arr = np.asarray(op)
            if arr.dtype == object or not np.issubdtype(arr.dtype,
                                                        np.inexact):
                continue            # big ints / residues: no NaN to carry
            if not np.isfinite(arr).all():
                raise OpConfigError(
                    f"op {self.spec.name!r}: operand {i} contains "
                    f"non-finite values (NaN/Inf) — rejected at submit "
                    f"(it would poison the whole batch)")

    def integrity(self, payloads: Sequence[Any],
                  rows: np.ndarray):
        """Run the op's ABFT check on one DELIVERABLE batch: the stacked
        result rows against the request payloads (ft/abft.py). Returns
        an ``IntegrityVerdict``."""
        return self.spec.integrity(self, payloads, rows)


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Registry entry: the full contract of one serveable op."""
    name: str
    arity: int
    summary: str
    uses_modulus_bits: bool
    uses_model_shards: bool
    _validate: Callable[["OpSpec", int, OpContext], None]
    _bind: Callable[["OpSpec", int, OpContext, int], BoundOp]
    warmup_payload: Callable[[BoundOp, int], tuple]
    random_payload: Callable[[BoundOp, np.random.Generator], Any]
    verify: Callable[[BoundOp, Any, np.ndarray], None]
    #: batch-level ABFT check (ft/abft.py): ``integrity(bound, payloads,
    #: rows) -> IntegrityVerdict`` validating a whole result batch
    #: against its request payloads in O(n) per row — the gate the
    #: verified serve engine runs before delivering any result.
    integrity: Callable[[BoundOp, Sequence[Any], np.ndarray], Any] = None

    def validate(self, n: int, ctx: OpContext = OpContext()) -> None:
        """Raise :class:`OpConfigError` unless (n, ctx) is serveable."""
        if ctx.modulus_bits is not None and not self.uses_modulus_bits:
            raise OpConfigError(
                f"--modulus-bits applies to "
                f"{', '.join(ops_using('modulus_bits'))}; "
                f"op {self.name!r} has no modular route")
        if ctx.model_shards != 1 and not self.uses_model_shards:
            raise OpConfigError(
                f"--model-shards applies to "
                f"{', '.join(ops_using('model_shards'))}; "
                f"op {self.name!r} has no distributed route")
        self._validate(self, n, ctx)

    def narrow(self, ctx: OpContext) -> OpContext:
        """Strip the knobs this op ignores — the mixed engine resolves one
        process-level context against ops with different routes."""
        return OpContext(
            modulus_bits=ctx.modulus_bits if self.uses_modulus_bits else None,
            model_shards=ctx.model_shards if self.uses_model_shards else 1,
            auto=ctx.auto, verified=ctx.verified, pim_ok=ctx.pim_ok)

    def bind(self, n: int, ctx: OpContext = OpContext(), *,
             batch: int = 0, strict: bool = True) -> BoundOp:
        """Validate and resolve the executable route.

        ``strict=True`` (the single-op CLI path) rejects knobs this op
        does not consume; ``strict=False`` narrows them away first (the
        mixed engine's per-bucket bind).
        """
        if not strict:
            ctx = self.narrow(ctx)
        self.validate(n, ctx)
        return self._bind(self, n, ctx, batch)


_REGISTRY: dict[str, OpSpec] = {}


def register_op(**kw) -> OpSpec:
    spec = OpSpec(**kw)
    if spec.name in _REGISTRY:
        raise ValueError(f"op {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def registry() -> tuple[OpSpec, ...]:
    return tuple(_REGISTRY.values())


def op_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_op(name: str) -> OpSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise OpConfigError(
            f"unknown op {name!r}; registered: {', '.join(_REGISTRY)}"
        ) from None


def ops_using(knob: str) -> tuple[str, ...]:
    flag = {"modulus_bits": "uses_modulus_bits",
            "model_shards": "uses_model_shards"}[knob]
    return tuple(s.name for s in _REGISTRY.values() if getattr(s, flag))


def cli_help() -> str:
    """--op help text, derived from the registry (the argparse surface must
    never drift from the dispatch table)."""
    return "; ".join(f"{s.name}: {s.summary}" for s in _REGISTRY.values())


def cli_knob_help(knob: str, base: str) -> str:
    return f"{base} (applies to: {', '.join(ops_using(knob))})"


# ---------------------------------------------------------------------------
# Shared payload / verification helpers
# ---------------------------------------------------------------------------

def _rel_err(got: np.ndarray, want: np.ndarray) -> float:
    scale = max(1.0, float(np.max(np.abs(want))))
    return float(np.max(np.abs(np.asarray(got) - want))) / scale


def _float_verify(want_of: Callable[[np.ndarray], np.ndarray], tol: float,
                  bound: BoundOp, payload, result: np.ndarray) -> None:
    want = want_of(payload) if bound.spec.arity == 1 else want_of(*payload)
    err = _rel_err(result, want)
    assert err < tol, (f"{bound.spec.name} route {bound.route} diverged "
                      f"from the numpy oracle: rel err {err:.2e} >= {tol}")


def _zeros(bound: BoundOp, batch: int) -> tuple:
    if bound.payload_dtype is object:
        z = np.zeros((batch, bound.n), object) + 0   # python-int zeros
    else:
        z = np.zeros((batch, bound.n), bound.payload_dtype)
    return bound.stack([z[i] if bound.spec.arity == 1
                        else tuple(z[i] for _ in range(bound.spec.arity))
                        for i in range(batch)])


def _cnormal(rng: np.random.Generator, n: int) -> np.ndarray:
    return (rng.standard_normal(n)
            + 1j * rng.standard_normal(n)).astype(np.complex64)


def _circular_real(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.fft.ifft(np.fft.fft(a) * np.fft.fft(b)).real


def _circular_complex(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.fft.ifft(np.fft.fft(a) * np.fft.fft(b))


def _stack_operands(bound: BoundOp,
                    payloads: Sequence[Any]) -> tuple[np.ndarray, ...]:
    """Host-side numpy stacking for the integrity checks (mirrors
    ``BoundOp.stack`` without the device transfer)."""
    if bound.spec.arity == 1:
        return (np.stack([np.asarray(p, bound.payload_dtype)
                          for p in payloads]),)
    return tuple(
        np.stack([np.asarray(p[i], bound.payload_dtype) for p in payloads])
        for i in range(bound.spec.arity))


def _integrity_fft(bound: BoundOp, payloads, rows):
    from repro.ft import abft
    (x,) = _stack_operands(bound, payloads)
    return abft.check_fft(x, rows)


def _integrity_rfft(bound: BoundOp, payloads, rows):
    from repro.ft import abft
    (x,) = _stack_operands(bound, payloads)
    return abft.check_rfft(x, rows)


def _integrity_polymul(bound: BoundOp, payloads, rows):
    from repro.ft import abft
    a, b = _stack_operands(bound, payloads)
    return abft.check_polymul(a, b, rows)


def _integrity_polymul_real(bound: BoundOp, payloads, rows):
    from repro.ft import abft
    a, b = _stack_operands(bound, payloads)
    return abft.check_polymul_real(a, b, rows)


def _integrity_polymul_mod(bound: BoundOp, payloads, rows):
    from repro.ft import abft
    a, b = _stack_operands(bound, payloads)
    if bound.rns is not None:
        return abft.check_polymul_rns(a, b, rows, bound.rns)
    return abft.check_polymul_mod(a, b, rows, bound.ntt_params)


def _no_dist_route(spec: OpSpec, n: int, ctx: OpContext) -> None:
    pass


def _plan_or_config_error(**kw):
    """Run the planner, lifting its ValueError into the registry's own
    error type so callers see one failure surface."""
    from repro.core import fft as fft_core
    try:
        return fft_core.plan(**kw)
    except ValueError as e:
        raise OpConfigError(str(e)) from e


# ---------------------------------------------------------------------------
# fft — complex transform endpoint
# ---------------------------------------------------------------------------

def _bind_fft(spec: OpSpec, n: int, ctx: OpContext, batch: int) -> BoundOp:
    import jax
    from repro.core import fft as fft_core
    if ctx.auto:
        plan = _plan_or_config_error(n=n, batch=batch, workload="fft",
                                     verified=ctx.verified,
                                     pim_ok=ctx.pim_ok)
    else:
        plan = _plan_or_config_error(n=n, batch=batch)
    return BoundOp(spec=spec, n=n, ctx=ctx, plan=plan, route="fft",
                   fn=jax.jit(lambda x: fft_core.fft(x)),
                   payload_dtype=np.complex64)


register_op(
    name="fft", arity=1,
    summary="batched complex FFT (local Pallas/XLA tier)",
    uses_modulus_bits=False, uses_model_shards=False,
    _validate=_no_dist_route, _bind=_bind_fft,
    warmup_payload=_zeros,
    random_payload=lambda b, rng: _cnormal(rng, b.n),
    verify=functools.partial(_float_verify, np.fft.fft, 1e-3),
    integrity=_integrity_fft,
)


# ---------------------------------------------------------------------------
# rfft — real-Hermitian half-spectrum endpoint (two-for-one packed kernel)
# ---------------------------------------------------------------------------

def _bind_rfft(spec: OpSpec, n: int, ctx: OpContext, batch: int) -> BoundOp:
    import jax
    import jax.numpy as jnp
    from repro.core import fft as fft_core
    if ctx.auto:
        plan = _plan_or_config_error(n=n, batch=batch, workload="rfft",
                                     verified=ctx.verified,
                                     pim_ok=ctx.pim_ok)
        if not plan.real:
            # Cost model preferred complex packing (only reachable where
            # the real route is pruned): cast up, full transform, keep
            # the half spectrum — same payload/result contract.
            return BoundOp(
                spec=spec, n=n, ctx=ctx, plan=plan, route="rfft-complex",
                fn=jax.jit(lambda x: fft_core.fft(
                    x.astype(jnp.complex64))[..., :n // 2 + 1]),
                payload_dtype=np.float32)
    else:
        plan = _plan_or_config_error(n=n, batch=batch, real=True)
    return BoundOp(spec=spec, n=n, ctx=ctx, plan=plan, route="rfft-real",
                   fn=jax.jit(lambda x: fft_core.rfft(x)),
                   payload_dtype=np.float32)


register_op(
    name="rfft", arity=1,
    summary="real-input half-spectrum FFT (two-for-one Hermitian packing)",
    uses_modulus_bits=False, uses_model_shards=False,
    _validate=_no_dist_route, _bind=_bind_rfft,
    warmup_payload=_zeros,
    random_payload=lambda b, rng: rng.standard_normal(b.n).astype(np.float32),
    verify=functools.partial(_float_verify, np.fft.rfft, 1e-3),
    integrity=_integrity_rfft,
)


# ---------------------------------------------------------------------------
# polymul — complex circular product (three-transform path)
# ---------------------------------------------------------------------------

def _bind_polymul(spec: OpSpec, n: int, ctx: OpContext, batch: int) -> BoundOp:
    import jax
    import jax.numpy as jnp
    from repro.core import fft as fft_core
    if ctx.auto:
        plan = _plan_or_config_error(n=n, batch=batch, workload="polymul",
                                     verified=ctx.verified,
                                     pim_ok=ctx.pim_ok)
    else:
        plan = _plan_or_config_error(n=n, batch=batch)
    return BoundOp(
        spec=spec, n=n, ctx=ctx, plan=plan, route="polymul",
        fn=jax.jit(lambda a, b: fft_core.polymul(
            a.astype(jnp.complex64), b.astype(jnp.complex64),
            mode="circular")),
        payload_dtype=np.complex64)


register_op(
    name="polymul", arity=2,
    summary="complex circular polynomial product (convolution theorem)",
    uses_modulus_bits=False, uses_model_shards=False,
    _validate=_no_dist_route, _bind=_bind_polymul,
    warmup_payload=_zeros,
    random_payload=lambda b, rng: (_cnormal(rng, b.n), _cnormal(rng, b.n)),
    verify=functools.partial(_float_verify, _circular_complex, 1e-3),
    integrity=_integrity_polymul,
)


# ---------------------------------------------------------------------------
# polymul-real — paired-inverse real product; distributed four-step route
# with model_shards > 1 (odd batches padded internally, docs/fourier.md)
# ---------------------------------------------------------------------------

def _validate_polymul_real(spec: OpSpec, n: int, ctx: OpContext) -> None:
    if ctx.auto:
        # Auto mode: model_shards is the AVAILABLE device count; the
        # chooser may keep the sequence local, so only fail when no
        # candidate at all is executable (the planner's pruned-list
        # error names each constraint).
        _plan_or_config_error(n=n, batch=0, workload="polymul-real",
                              model_shards=ctx.model_shards,
                              verified=ctx.verified, pim_ok=ctx.pim_ok)
    elif ctx.model_shards > 1:
        _plan_or_config_error(n=n, batch=0, real=True,
                              model_shards=ctx.model_shards,
                              force_distributed=True)


def _bind_polymul_real(spec: OpSpec, n: int, ctx: OpContext,
                       batch: int) -> BoundOp:
    import jax
    import jax.numpy as jnp
    from repro.core import fft as fft_core
    if ctx.auto:
        plan = _plan_or_config_error(n=n, batch=batch,
                                     workload="polymul-real",
                                     model_shards=ctx.model_shards,
                                     verified=ctx.verified,
                                     pim_ok=ctx.pim_ok)
    elif ctx.model_shards > 1:
        plan = _plan_or_config_error(n=n, batch=batch, real=True,
                                     model_shards=ctx.model_shards,
                                     force_distributed=True)
    else:
        plan = _plan_or_config_error(n=n, batch=batch, real=True)
    if plan.tier == "distributed":
        from repro.core.fft import distributed as dfft
        mesh = jax.make_mesh((ctx.model_shards,), ("model",))
        return BoundOp(
            spec=spec, n=n, ctx=ctx, plan=plan,
            route="polymul-real-distributed",
            fn=jax.jit(dfft.make_sharded_polymul_real(mesh, batch_axes=())),
            payload_dtype=np.float32, mesh=mesh)
    if not plan.real:
        # Complex-packing fallback (auto only): full-width product on
        # cast-up operands, real part back — the route the cost model
        # priced as the "complex" packing candidate.
        return BoundOp(
            spec=spec, n=n, ctx=ctx, plan=plan,
            route="polymul-real-complex",
            fn=jax.jit(lambda a, b: fft_core.polymul(
                a.astype(jnp.complex64), b.astype(jnp.complex64),
                mode="circular").real),
            payload_dtype=np.float32)
    return BoundOp(
        spec=spec, n=n, ctx=ctx, plan=plan, route="polymul-real-packed",
        fn=jax.jit(lambda a, b: fft_core.polymul_real(a, b,
                                                      mode="circular")),
        payload_dtype=np.float32)


register_op(
    name="polymul-real", arity=2,
    summary="real circular product via the paired-inverse Hermitian fast "
            "path; --model-shards > 1 runs the distributed four-step tier",
    uses_modulus_bits=False, uses_model_shards=True,
    _validate=_validate_polymul_real, _bind=_bind_polymul_real,
    warmup_payload=_zeros,
    random_payload=lambda b, rng: (
        rng.standard_normal(b.n).astype(np.float32),
        rng.standard_normal(b.n).astype(np.float32)),
    verify=functools.partial(_float_verify, _circular_real, 1e-3),
    integrity=_integrity_polymul_real,
)


# ---------------------------------------------------------------------------
# polymul-mod — exact negacyclic product mod (x^n + 1, q); parameterized
# routes: single-word fused NTT kernel, multi-limb RNS (> 30-bit Q),
# distributed four-step NTT (model_shards > 1, single-limb only)
# ---------------------------------------------------------------------------

def _validate_polymul_mod(spec: OpSpec, n: int, ctx: OpContext) -> None:
    bits = ctx.modulus_bits
    if bits is not None and bits > 30 and ctx.model_shards > 1:
        raise OpConfigError(
            "distributed polymul-mod is single-limb: RNS "
            "(modulus_bits > 30) shards limbs, not the sequence — drop "
            "--model-shards or use modulus_bits <= 30")
    if ctx.auto:
        # RNS shards limbs, not the sequence: the chooser only sees the
        # local tier for multi-limb moduli.
        shards = 1 if (bits is not None and bits > 30) else ctx.model_shards
        _plan_or_config_error(n=n, batch=0, workload="polymul-mod",
                              model_shards=shards,
                              verified=ctx.verified, pim_ok=ctx.pim_ok)
    elif ctx.model_shards > 1:
        _plan_or_config_error(n=n, batch=0, exact=True,
                              model_shards=ctx.model_shards,
                              force_distributed=True)
    try:
        if bits is not None and bits > 30:
            from repro.core.ntt import RNSParams
            RNSParams.make(n, modulus_bits=bits)
        else:
            from repro.core.ntt import NTTParams
            NTTParams.make(n, bits=30 if bits is None else bits)
    except ValueError as e:
        raise OpConfigError(
            f"no NTT modulus for n={n}, modulus_bits={bits}: {e}") from e


def _bind_polymul_mod(spec: OpSpec, n: int, ctx: OpContext,
                      batch: int) -> BoundOp:
    bits = ctx.modulus_bits
    rns_route = bits is not None and bits > 30
    if ctx.auto:
        plan = _plan_or_config_error(
            n=n, batch=batch, workload="polymul-mod",
            model_shards=1 if rns_route else ctx.model_shards,
            verified=ctx.verified, pim_ok=ctx.pim_ok)
    elif ctx.model_shards > 1:
        plan = _plan_or_config_error(n=n, batch=batch, exact=True,
                                     model_shards=ctx.model_shards,
                                     force_distributed=True)
    else:
        plan = _plan_or_config_error(n=n, batch=batch, exact=True)
    if plan.tier == "distributed":
        import jax
        from repro.core.ntt import NTTParams
        from repro.core.ntt import distributed as dntt
        params = NTTParams.make(n, bits=30 if bits is None else bits)
        mesh = jax.make_mesh((ctx.model_shards,), ("data",))
        return BoundOp(
            spec=spec, n=n, ctx=ctx, plan=plan,
            route="polymul-mod-distributed",
            fn=jax.jit(dntt.make_sharded_ntt_polymul(mesh, params)),
            payload_dtype=np.uint32, ntt_params=params, mesh=mesh)
    if bits is not None and bits > 30:
        from repro.core.ntt import RNSParams, rns_polymul
        rns = RNSParams.make(n, modulus_bits=bits)
        if ctx.verified:
            # A verified bind must be CHECKABLE: the per-factor
            # eval-at-psi check needs Q to factor over the limb primes.
            # Prove it here, not on the first served batch.
            from repro.ft import abft
            try:
                abft.check_limbs_for(rns)
            except abft.ABFTUnsupportedModulus as e:
                raise OpConfigError(
                    f"verified polymul-mod (RNS) bind rejected: {e}"
                ) from e
        return BoundOp(spec=spec, n=n, ctx=ctx, plan=plan,
                       route="polymul-mod-rns",
                       fn=functools.partial(rns_polymul, rns=rns),
                       payload_dtype=object, rns=rns)
    from repro.core.ntt import NTTParams
    from repro.kernels import ntt as kntt
    params = NTTParams.make(n, bits=30 if bits is None else bits)
    return BoundOp(spec=spec, n=n, ctx=ctx, plan=plan,
                   route="polymul-mod-single",
                   fn=functools.partial(kntt.ntt_polymul, params=params),
                   payload_dtype=np.uint32, ntt_params=params)


def _random_mod_payload(bound: BoundOp, rng: np.random.Generator):
    if bound.rns is not None:
        from repro.core.ntt.rns import random_poly
        return (random_poly(rng, bound.n, bound.rns.modulus),
                random_poly(rng, bound.n, bound.rns.modulus))
    q = bound.ntt_params.q
    return (rng.integers(0, q, bound.n).astype(np.uint32),
            rng.integers(0, q, bound.n).astype(np.uint32))


def _verify_mod(bound: BoundOp, payload, result: np.ndarray) -> None:
    a, b = payload
    if bound.rns is not None:
        from repro.core.ntt import rns_polymul_reference
        want = rns_polymul_reference(np.asarray(a, object),
                                     np.asarray(b, object), bound.rns)
    else:
        from repro.core.ntt import negacyclic_polymul
        want = negacyclic_polymul(np.asarray(a), np.asarray(b),
                                  bound.ntt_params)
    assert (np.asarray(result) == want).all(), \
        f"{bound.route} is not bit-exact against the reference NTT"


register_op(
    name="polymul-mod", arity=2,
    summary="exact negacyclic product mod (x^n+1, q); --modulus-bits > 30 "
            "routes through multi-limb RNS/CRT, --model-shards > 1 the "
            "distributed four-step NTT",
    uses_modulus_bits=True, uses_model_shards=True,
    _validate=_validate_polymul_mod, _bind=_bind_polymul_mod,
    warmup_payload=_zeros,
    random_payload=_random_mod_payload,
    verify=_verify_mod,
    integrity=_integrity_polymul_mod,
)
