"""End-to-end training driver.

Runs on CPU at reduced scale (--smoke) and, unchanged, on a real mesh at
production scale (the dry-run validates those configs compile). Features:
checkpoint/auto-resume (atomic, elastic), straggler watchdog, prefetched
synthetic data, optional cross-pod gradient compression.

Example (a few hundred steps of a ~10M-param qwen3-family model on CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 300 --ckpt-dir /tmp/ckpt

With ``--mesh DxM`` (e.g. under forced host devices) the run enters a
``repro.dist`` mesh context: the model's ``constrain`` annotations become
real sharding constraints and the batch is device_put over the data axis.

With ``--mesh PxDxM --compress-grads`` the step becomes the pod-mesh
variant (``train.step.make_train_step(pod_axis="pod")`` inside shard_map):
gradients mean-reduce across pods through the int8 error-feedback
compressed psum, with the quantization residual carried step to step.

Resume safety (docs/fault_tolerance.md): the checkpoint payload carries
the error-feedback residual ``grad_err`` alongside params/opt (with its
explicit leading pod axis, restored under a ``P("pod")`` sharding so an
elastic re-shard cannot collapse pod-local residuals), and the manifest
``extra`` carries the watchdog EWMA/event state plus the data-pipeline
step cursor. A SIGKILLed ``--compress-grads`` run resumed from its last
checkpoint follows a loss trajectory bitwise-identical to the
uninterrupted run (pinned by the kill-and-resume subprocess test).
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.registry import get_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.dist import batching, compat, sharding
from repro.ft import checkpoint as ckpt_lib
from repro.ft.watchdog import StepWatchdog
from repro.launch.mesh import make_dev_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train import step as step_lib


def _batch_sharding(mesh, v):
    spec = sharding.logical_to_spec(
        ("batch",) + (None,) * (v.ndim - 1), v.shape, mesh)
    return NamedSharding(mesh, spec)


def _tree_shardings(tree):
    """The live placement of every leaf, for an explicit-sharding restore:
    without it ``ckpt_lib.restore`` materializes unsharded host arrays and
    the first step reshards implicitly (an invisible all-gather + scatter
    on a multi-device mesh)."""
    return jax.tree.map(lambda x: x.sharding, tree)


def _check_resume_stream(extra: dict, args, start_step: int) -> None:
    """Refuse a resume that would silently switch the data stream: the
    synthetic pipeline is deterministic per (seed, step, batch, seq), so a
    changed knob means the resumed trajectory is not a continuation."""
    cursor = extra.get("data")
    if not cursor:
        return
    want = {"seed": args.seed, "global_batch": args.batch, "seq": args.seq}
    got = {k: cursor.get(k) for k in want}
    if got != want:
        raise RuntimeError(
            f"checkpoint data cursor {got} does not match the resume flags "
            f"{want}; resuming would replay a DIFFERENT stream — restart "
            f"with matching --seed/--batch/--seq or a fresh --ckpt-dir")
    if cursor.get("next_step") is not None \
            and int(cursor["next_step"]) != start_step:
        raise RuntimeError(
            f"checkpoint step {start_step} disagrees with its own data "
            f"cursor {cursor['next_step']} — corrupt manifest")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--loss-log", default=None, metavar="PATH",
                    help="append one 'step <float.hex>' line per step "
                         "(flushed per step — survives SIGKILL); the "
                         "kill-and-resume test compares these bitwise")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DxM|PxDxM",
                    help="enter a (data, model) dev-mesh context, e.g. 2x4; "
                         "a three-part PxDxM spec adds a leading pod axis "
                         "(e.g. 2x2x1) for --compress-grads")
    ap.add_argument("--compress-grads", action="store_true",
                    help="reduce gradients across the pod axis through the "
                         "int8 error-feedback compressed psum "
                         "(dist.collectives; ~4x fewer DCN bytes than an "
                         "f32 all-reduce). Requires a pod axis: "
                         "--mesh PxDxM")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        try:
            sizes = tuple(int(v) for v in args.mesh.lower().split("x"))
            if len(sizes) not in (2, 3):
                raise ValueError
        except ValueError:
            ap.error(f"--mesh wants DxM or PxDxM (e.g. 2x4 or 2x2x1), "
                     f"got {args.mesh!r}")
        if len(sizes) == 2:
            mesh = make_dev_mesh(*sizes)
        else:
            mesh = compat.make_mesh(sizes, ("pod", "data", "model"),
                                    axis_types=compat.axis_types_auto(3))
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n_batch = 1
        for a in batch_axes:
            n_batch *= int(mesh.shape[a])
        if args.batch % n_batch:
            # constrain would silently drop the non-dividing data axis and
            # replicate the batch; refuse rather than pretend to shard
            ap.error(f"--batch {args.batch} must divide the batch axes "
                     f"({n_batch})")
        plan = batching.shard_batch(args.batch, mesh, axes=batch_axes)
        print(f"[train] mesh={dict(mesh.shape)} per-device batch="
              f"{plan.per_device} utilization={plan.utilization:.2f}")
    if args.compress_grads and (mesh is None or "pod" not in mesh.shape):
        ap.error("--compress-grads needs a pod axis: use --mesh PxDxM "
                 "(e.g. 2x2x1)")
    if args.compress_grads:
        # The pod step runs INSIDE shard_map over the whole mesh (the
        # compressed psum is a manual collective), so the ambient-mesh
        # context must stay off: `constrain` then no-ops instead of
        # emitting sharding constraints on manual axes.
        return _run(args, mesh)
    with compat.set_mesh(mesh) if mesh is not None \
            else contextlib.nullcontext():
        return _run(args, mesh)


def _restore_state(args, mesh, params, opt_state, grad_err, watchdog):
    """Resume from the newest checkpoint with the step's EXPLICIT
    shardings: without them the arrays land unsharded on the default
    device and the first step reshards them implicitly (an invisible
    broadcast from device 0 on every multi-device mesh). Params/opt are
    replicated state in both step variants, so on a mesh their sharding is
    P() over the WHOLE mesh; the residual tree additionally pins P("pod")
    over its leading axis — it is pod-LOCAL state, and an elastic re-shard
    that treated it as replicated would silently collapse every pod's
    residual to one pod's values. Also restores the watchdog baseline and
    validates the data-stream cursor from the manifest ``extra``."""
    from jax.sharding import PartitionSpec as P

    like = {"params": params, "opt": opt_state}
    if mesh is not None:
        repl = NamedSharding(mesh, P())
        shardings = {"params": jax.tree.map(lambda _: repl, params),
                     "opt": jax.tree.map(lambda _: repl, opt_state)}
    else:
        shardings = {"params": _tree_shardings(params),
                     "opt": _tree_shardings(opt_state)}
    if grad_err is not None:
        like["grad_err"] = grad_err
        shardings["grad_err"] = jax.tree.map(
            lambda _: NamedSharding(mesh, P("pod")), grad_err)
    step0, restored = ckpt_lib.restore_latest(args.ckpt_dir, like,
                                              shardings)
    if step0 is None:
        return 0, params, opt_state, grad_err
    params, opt_state = restored["params"], restored["opt"]
    if grad_err is not None:
        grad_err = restored["grad_err"]
    extra = ckpt_lib.read_extra(args.ckpt_dir, step0)
    _check_resume_stream(extra, args, step0)
    if extra.get("watchdog"):
        watchdog.load_state_dict(extra["watchdog"])
    print(f"[train] resumed from step {step0}"
          + (" (grad_err restored)" if grad_err is not None else ""))
    return step0, params, opt_state, grad_err


def _run(args, mesh):

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.scaled_down()
        cfg = dataclasses.replace(cfg, max_seq_len=args.seq)
    opt_cfg = adamw.OptConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps)

    params = lm.init_params(cfg, jax.random.key(args.seed))
    opt_state = adamw.init_state(params, opt_cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    watchdog = StepWatchdog()
    grad_err = None
    if args.compress_grads:
        from jax.sharding import PartitionSpec as P

        from repro.dist import collectives
        n_pods = int(mesh.shape["pod"])
        # The error-feedback residual carries an explicit leading pod axis
        # from birth (see pod_body below for why P("pod") and not P()).
        grad_err = jax.tree.map(
            lambda z: jnp.broadcast_to(z[None], (n_pods, *z.shape)),
            collectives.zeros_like_errs(params))

    start_step = 0
    if args.ckpt_dir:
        start_step, params, opt_state, grad_err = _restore_state(
            args, mesh, params, opt_state, grad_err, watchdog)

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    prefetch = Prefetcher(data, start_step=start_step)

    if args.compress_grads:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        bspec = P(batch_axes)
        # The batch shards over (pod, data): the step must mean-reduce
        # gradients over the data axis too (intra-pod, uncompressed)
        # before the cross-pod compressed psum.
        pod_step = step_lib.make_train_step(
            cfg, opt_cfg, pod_axis="pod",
            data_axis="data" if "data" in batch_axes else None)

        def pod_body(p, o, err_blk, batch):
            # The error-feedback residual is POD-LOCAL (compressed_psum's
            # contract), so it carries an explicit leading pod axis and a
            # P("pod") spec — declaring it replicated (P()) would mark
            # divergent per-pod buffers as identical, and any reshard or
            # host read would silently collapse them to one pod's values.
            err = jax.tree.map(lambda e: e[0], err_blk)
            p, o, err, m = pod_step(p, o, err, batch)
            return p, o, jax.tree.map(lambda e: e[None], err), m

        train_step = jax.jit(
            compat.shard_map(pod_body, mesh=mesh,
                             in_specs=(P(), P(), P("pod"), bspec),
                             out_specs=(P(), P(), P("pod"), P()),
                             check_vma=False),
            donate_argnums=(0, 1, 2))
    else:
        train_step = jax.jit(step_lib.make_train_step(cfg, opt_cfg),
                             donate_argnums=(0, 1))

    def save_ckpt(at_step: int) -> None:
        # One payload for every save site: params + opt + (under
        # --compress-grads) the error-feedback residual, with the manifest
        # ``extra`` carrying the host-side state a resume needs — the
        # watchdog's EWMA/event baseline and the data-pipeline cursor
        # (docs/fault_tolerance.md pins this contract).
        tree = {"params": params, "opt": opt_state}
        if grad_err is not None:
            tree["grad_err"] = grad_err
        ckpt_lib.save(args.ckpt_dir, at_step, tree, extra={
            "watchdog": watchdog.state_dict(),
            "data": {"next_step": at_step, "seed": args.seed,
                     "global_batch": args.batch, "seq": args.seq},
            "compress_grads": bool(args.compress_grads),
        })

    losses = []
    batch_shardings: dict = {}
    loss_log = open(args.loss_log, "a") if args.loss_log else None
    t_start = time.time()
    try:
        for step in range(start_step, args.steps):
            got_step, batch = prefetch.next()
            assert got_step == step, (got_step, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.frontend == "embeddings":
                # frontend stub: deterministic pseudo-embeddings per token
                key = jax.random.fold_in(jax.random.key(7), step)
                batch["embeds"] = jax.random.normal(
                    key, (*batch["tokens"].shape, cfg.d_model),
                    jnp.float32) * 0.02
                batch.pop("tokens")
            if cfg.mrope_sections is not None:
                B, S = batch["labels"].shape
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
            if (mesh is not None and mesh.size > 1
                    and not args.compress_grads):
                for k, v in batch.items():  # shapes are fixed across steps
                    if k not in batch_shardings:
                        batch_shardings[k] = _batch_sharding(mesh, v)
                batch = {k: jax.device_put(v, batch_shardings[k])
                         for k, v in batch.items()}
            watchdog.start_step()
            if args.compress_grads:
                params, opt_state, grad_err, metrics = train_step(
                    params, opt_state, grad_err, batch)
            else:
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
            jax.block_until_ready(metrics["loss"])
            flagged = watchdog.end_step(step)
            losses.append(float(metrics["loss"]))
            if loss_log is not None:
                loss_log.write(f"{step} {losses[-1].hex()}\n")
                loss_log.flush()
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step={step} loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}"
                      + (" STRAGGLER" if flagged else ""))
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_ckpt(step + 1)
    finally:
        prefetch.stop()
        if loss_log is not None:
            loss_log.close()

    dt = time.time() - t_start
    steps_done = args.steps - start_step
    span = f"loss {losses[0]:.4f} -> {losses[-1]:.4f}" if losses \
        else "already complete"
    print(f"[train] done: {steps_done} steps in {dt:.1f}s "
          f"({steps_done / max(dt, 1e-9):.2f} steps/s); {span}")
    if args.ckpt_dir:
        save_ckpt(args.steps)
    return losses


if __name__ == "__main__":
    main()
