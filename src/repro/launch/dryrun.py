import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective statistics.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST precede any jax import (device count locks on
first init). Nothing here allocates device memory: inputs are
ShapeDtypeStructs and compilation is AOT.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (ASSIGNED, SHAPES, cell_supported,
                                    get_config)
from repro.dist import compat
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train import step as step_lib

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|c64)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "c64": 8}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in (post-SPMD) HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(type_str):
            dtype, dims = sm.group(1), sm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _BYTES[dtype]
        out[kind] += total
        counts[kind] += 1
    out["counts"] = counts
    return out


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict (older jax returns a
    one-entry list of per-program dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _lower_cell(cfg, shape, mesh):
    """Build abstract inputs + shardings for a cell and lower it."""
    params_abs = lm.abstract_params(cfg)
    pspecs = S.sanitize_tree(lm.param_specs(cfg), params_abs, mesh)
    psh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                       is_leaf=lambda x: isinstance(x, P))

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            opt_cfg = S.opt_config_for(cfg)
            opt_abs = S.abstract_opt_state(cfg, opt_cfg)
            ospecs = S.sanitize_tree(
                adamw.state_specs(pspecs, opt_cfg), opt_abs, mesh)
            osh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), ospecs,
                               is_leaf=lambda x: isinstance(x, P))
            bsds, bsp = S.batch_specs(cfg, shape, with_labels=True)
            bsds = {k: v for k, v in bsds.items() if v is not None}
            bsp = {k: NamedSharding(mesh, S.sanitize_spec(
                v, bsds[k].shape, mesh)) for k, v in bsp.items()
                if k in bsds}
            fn = step_lib.make_train_step(cfg, opt_cfg)
            jfn = jax.jit(fn, in_shardings=(psh, osh, bsp),
                          out_shardings=(psh, osh, None),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(params_abs, opt_abs, bsds)
        elif shape.kind == "prefill":
            bsds, bsp = S.batch_specs(cfg, shape, with_labels=False)
            bsds = {k: v for k, v in bsds.items() if v is not None}
            bsp = {k: NamedSharding(mesh, S.sanitize_spec(
                v, bsds[k].shape, mesh)) for k, v in bsp.items()
                if k in bsds}
            fn = step_lib.make_prefill_step(cfg)
            jfn = jax.jit(fn, in_shardings=(psh, bsp))
            lowered = jfn.lower(params_abs, bsds)
        else:  # decode
            state_abs = S.abstract_decode_state(cfg, shape)
            sspecs = S.sanitize_tree(lm.decode_state_specs(cfg), state_abs,
                                     mesh)
            ssh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), sspecs,
                               is_leaf=lambda x: isinstance(x, P))
            dsds, dsp = S.decode_input_specs(cfg, shape)
            tok_sh = (NamedSharding(mesh, S.sanitize_spec(
                dsp["token"], dsds["token"].shape, mesh))
                if dsds.get("token") is not None else None)
            emb_sh = (NamedSharding(mesh, S.sanitize_spec(
                dsp["embed"], dsds["embed"].shape, mesh))
                if dsds.get("embed") is not None else None)
            pos_stream_sh = (NamedSharding(mesh, S.sanitize_spec(
                dsp["positions"], dsds["positions"].shape, mesh))
                if "positions" in dsds else None)

            fn = step_lib.make_decode_step(cfg)
            jfn = jax.jit(
                fn,
                in_shardings=(psh, ssh, tok_sh,
                              NamedSharding(mesh, P()), pos_stream_sh,
                              emb_sh),
                out_shardings=(None, ssh),
                donate_argnums=(1,))
            lowered = jfn.lower(params_abs, state_abs, dsds.get("token"),
                                dsds["pos"], dsds.get("positions"),
                                dsds.get("embed"))
    return lowered


def _probe_period(cfg) -> int:
    """Probe layer-count unit: the attention-pattern period (gemma3's 5:1
    layout needs whole periods so per-layer averages match the real mix)."""
    if cfg.attention == "local_global":
        return cfg.local_global_ratio + 1
    return 2


def _cost_probe(cfg, shape, mesh) -> dict | None:
    """XLA's cost_analysis counts while-loop bodies ONCE, so scanned-layer
    (and scanned-KV-block) FLOPs/bytes are undercounted by the trip count.
    Probe: lower UNROLLED variants at L = k and L = 2k layers with the KV
    scan collapsed to a single block, then extrapolate affinely in L —
    exact for costs of the form fixed + per_layer * L.
    """
    k = _probe_period(cfg)
    if cfg.num_layers < 2 * k:
        return None
    vals = {}
    for L in (k, 2 * k):
        cfg_p = dataclasses.replace(
            cfg, num_layers=L, scan_layers=False,
            attn_kv_block=shape.seq_len)
        lowered = _lower_cell(cfg_p, shape, mesh)
        compiled = lowered.compile()
        ca = _cost_dict(compiled)
        coll = collective_bytes(compiled.as_text())
        vals[L] = {"flops": ca.get("flops", 0.0),
                   "bytes": ca.get("bytes accessed", 0.0),
                   "coll": coll}
    L_real = cfg.num_layers
    lo, hi = vals[k], vals[2 * k]

    def extrap(a, b):
        per_layer = (b - a) / k
        return max(0.0, a + per_layer * (L_real - k))

    # the grad-accumulation microbatch scan is itself a while loop counted
    # once — scale per-step costs back up by the trip count
    accum = max(1, getattr(cfg, "grad_accum_steps", 1))
    coll_out = {}
    for key in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"):
        coll_out[key] = extrap(lo["coll"][key], hi["coll"][key]) * accum
    return {
        "flops_per_device": extrap(lo["flops"], hi["flops"]) * accum,
        "bytes_accessed_per_device":
            extrap(lo["bytes"], hi["bytes"]) * accum,
        "collective_bytes": coll_out,
        "probe_layers": [k, 2 * k],
    }


def run_cell(arch: str, shape_name: str, mesh, *, verbose: bool = True,
             probe: bool = True, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}

    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = _lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.size
    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": dict(mesh.shape),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": ca.get("flops", 0.0),
        "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
        "argument_bytes_per_device": ma.argument_size_in_bytes,
        "output_bytes_per_device": ma.output_size_in_bytes,
        "temp_bytes_per_device": ma.temp_size_in_bytes,
        "alias_bytes_per_device": ma.alias_size_in_bytes,
        "peak_bytes_per_device": (ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        "collective_bytes": coll,
        "model_params": get_config(arch).param_count(),
        "active_params": get_config(arch).active_param_count(),
    }
    if probe:
        try:
            with compat.set_mesh(mesh):
                pr = _cost_probe(cfg, shape, mesh)
            if pr is not None:
                result["probe"] = pr
        except Exception as e:
            result["probe_error"] = f"{type(e).__name__}: {e}"
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={tuple(mesh.shape.values())}"
              f" lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"flops/dev={result['flops_per_device']:.3e} "
              f"peak_bytes/dev={result['peak_bytes_per_device']:.3e}")
        print(f"  memory_analysis: {ma}")
        print(f"  collectives: { {k: v for k, v in coll.items() if k != 'counts'} }")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tag = "multipod" if args.multi_pod else "singlepod"
    outdir = os.path.join(args.out, tag)
    os.makedirs(outdir, exist_ok=True)

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        path = os.path.join(outdir, f"{arch}__{shape_name}.json")
        try:
            res = run_cell(arch, shape_name, mesh)
        except Exception as e:  # a failure here is a bug in our sharding
            traceback.print_exc()
            res = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures.append((arch, shape_name))
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
    if failures:
        raise SystemExit(f"FAILED cells: {failures}")
    print(f"dry-run complete: {len(cells)} cells -> {outdir}")


if __name__ == "__main__":
    main()
