"""Layer zoo: attention, recurrent, MoE, common primitives."""
