"""Recurrent mixers: RWKV6 (Finch) time/channel mixing and a Mamba-style
selective SSM branch (Hymba's parallel hybrid heads).

Both are linear-time in sequence length via lax.scan (training/prefill) and
O(1)-state single-step updates (decode) — the sub-quadratic property the
long_500k shape requires. Neither recurrence is an LTI convolution (the
decay is data-dependent), so the paper's FFT convolution theorem does NOT
apply to them — see DESIGN.md §Arch-applicability; they run without the
FourierPIM primitive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers.common import rms_norm


# ---------------------------------------------------------------------------
# RWKV6 time mixing (data-dependent decay, per-head matrix state)
# ---------------------------------------------------------------------------

RWKV_HEAD_DIM = 64


def init_rwkv_params(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = d // RWKV_HEAD_DIM
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    lora = 64
    return {
        "mu": jnp.full((5, d), 0.5, dtype),                # token-shift mix
        "wr": jax.random.normal(ks[0], (d, d), dtype) * std,
        "wk": jax.random.normal(ks[1], (d, d), dtype) * std,
        "wv": jax.random.normal(ks[2], (d, d), dtype) * std,
        "wg": jax.random.normal(ks[3], (d, d), dtype) * std,
        "wo": jax.random.normal(ks[4], (d, d), dtype) * std,
        "w0": jnp.full((d,), -6.0, jnp.float32),           # decay bias
        "ww1": jax.random.normal(ks[5], (d, lora), dtype) * std,
        "ww2": jax.random.normal(ks[6], (lora, d), dtype) * lora ** -0.5,
        "u": jax.random.normal(ks[7], (H, RWKV_HEAD_DIM), jnp.float32) * 0.1,
    }


def _rwkv_inputs(params, x, x_prev):
    """Token-shifted projections. x: (B, S, d); x_prev: (B, d) last token of
    the previous chunk (zeros at sequence start)."""
    dtype = x.dtype
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mu = params["mu"].astype(dtype)
    xs = [x + mu[i] * (shifted - x) for i in range(5)]
    r = xs[0] @ params["wr"].astype(dtype)
    k = xs[1] @ params["wk"].astype(dtype)
    v = xs[2] @ params["wv"].astype(dtype)
    g = xs[3] @ params["wg"].astype(dtype)
    w_raw = (xs[4].astype(jnp.float32) @ params["ww1"].astype(jnp.float32)
             @ params["ww2"].astype(jnp.float32)) + params["w0"]
    w = jnp.exp(-jnp.exp(w_raw))                           # (B, S, d) decay
    return r, k, v, g, w


def rwkv_time_mix(params: dict, x: jax.Array, state: dict | None = None):
    """x: (B, S, d). Returns (y, new_state). state = {"prev_x": (B, d),
    "S": (B, H, hd, hd)} carried across chunks / decode steps."""
    B, S, d = x.shape
    H = d // RWKV_HEAD_DIM
    hd = RWKV_HEAD_DIM
    dtype = x.dtype
    if state is None:
        state = {"prev_x": jnp.zeros((B, d), dtype),
                 "S": jnp.zeros((B, H, hd, hd), jnp.float32)}
    r, k, v, g, w = _rwkv_inputs(params, x, state["prev_x"])
    rh = r.reshape(B, S, H, hd).astype(jnp.float32)
    kh = k.reshape(B, S, H, hd).astype(jnp.float32)
    vh = v.reshape(B, S, H, hd).astype(jnp.float32)
    wh = w.reshape(B, S, H, hd)
    u = params["u"]

    def step(Sst, inp):
        rt, kt, vt, wt = inp                                # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,hd,hd)
        out = jnp.einsum("bhi,bhij->bhj", rt, Sst + u[..., :, None] * kv)
        Sst = wt[..., :, None] * Sst + kv
        return Sst, out

    Sfin, outs = jax.lax.scan(
        step, state["S"],
        (jnp.moveaxis(rh, 1, 0), jnp.moveaxis(kh, 1, 0),
         jnp.moveaxis(vh, 1, 0), jnp.moveaxis(wh, 1, 0)))
    y = jnp.moveaxis(outs, 0, 1).reshape(B, S, d).astype(dtype)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(dtype)
    y = y @ params["wo"].astype(dtype)
    new_state = {"prev_x": x[:, -1], "S": Sfin}
    return constrain(y, "batch", None, None), new_state


def init_rwkv_channel_params(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std = d ** -0.5
    return {
        "mu_c": jnp.full((2, d), 0.5, dtype),
        "wk": jax.random.normal(k1, (d, f), dtype) * std,
        "wv": jax.random.normal(k2, (f, d), dtype) * f ** -0.5,
        "wr": jax.random.normal(k3, (d, d), dtype) * std,
    }


def rwkv_channel_mix(params: dict, x: jax.Array, prev_x: jax.Array):
    """RWKV squared-ReLU channel mixing with token shift."""
    dtype = x.dtype
    shifted = jnp.concatenate([prev_x[:, None], x[:, :-1]], axis=1)
    mu = params["mu_c"].astype(dtype)
    xk = x + mu[0] * (shifted - x)
    xr = x + mu[1] * (shifted - x)
    k = jnp.square(jax.nn.relu((xk @ params["wk"].astype(dtype))
                               .astype(jnp.float32))).astype(dtype)
    r = jax.nn.sigmoid((xr @ params["wr"].astype(dtype))
                       .astype(jnp.float32)).astype(dtype)
    y = r * (k @ params["wv"].astype(dtype))
    return constrain(y, "batch", None, None), x[:, -1]


# ---------------------------------------------------------------------------
# Mamba-style selective SSM branch (Hymba parallel heads)
# ---------------------------------------------------------------------------

def init_ssm_params(key, cfg, dtype) -> dict:
    d, n = cfg.d_model, cfg.ssm_state
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    return {
        "w_dt": jax.random.normal(ks[0], (d, d), dtype) * std,
        "w_b": jax.random.normal(ks[1], (d, n), dtype) * std,
        "w_c": jax.random.normal(ks[2], (d, n), dtype) * std,
        "a_log": jnp.log(jnp.linspace(1.0, float(n), n))[None, :]
                 * jnp.ones((d, 1), jnp.float32),          # (d, n)
        "d_skip": jnp.ones((d,), jnp.float32),
        "dt_bias": jnp.full((d,), -4.0, jnp.float32),
    }


def ssm_mix(params: dict, x: jax.Array, state: jax.Array | None = None):
    """Selective SSM: h_t = exp(dt_t A) h_{t-1} + dt_t * x_t B_t;
    y_t = h_t . C_t + D x_t.   x: (B, S, d); state: (B, d, n)."""
    B, S, d = x.shape
    n = params["w_b"].shape[-1]
    dtype = x.dtype
    if state is None:
        state = jnp.zeros((B, d, n), jnp.float32)
    xf = x.astype(jnp.float32)
    dt = jax.nn.softplus(xf @ params["w_dt"].astype(jnp.float32)
                         + params["dt_bias"])              # (B,S,d)
    bt = xf @ params["w_b"].astype(jnp.float32)            # (B,S,n)
    ct = xf @ params["w_c"].astype(jnp.float32)            # (B,S,n)
    a = -jnp.exp(params["a_log"])                          # (d,n) negative

    def step(h, inp):
        xt, dtt, btt, ctt = inp
        decay = jnp.exp(dtt[..., None] * a)                # (B,d,n)
        h = decay * h + (dtt * xt)[..., None] * btt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ctt)
        return h, y

    hfin, ys = jax.lax.scan(
        step, state,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(bt, 1, 0), jnp.moveaxis(ct, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + xf * params["d_skip"]
    return constrain(y.astype(dtype), "batch", None, None), hfin
