"""Grouped-query attention: flash-style blockwise softmax for train/prefill,
cache-based single-step decode, with full / sliding-window / local:global
masking — all config-driven.

The blockwise (online-softmax) formulation keeps activation memory at
O(S * block) instead of O(S^2), which is what makes the 32K-prefill and
500K-decode dry-run cells compile within HBM. It is the pure-JAX analogue of
a fused attention kernel: XLA lowers the scan over KV blocks into a loop
with resident accumulators (one HBM pass over K/V), the same single-residency
structure as the FourierPIM-adapted FFT kernel (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers.common import apply_mrope, apply_rope, rms_norm

NEG = -1e30


def _qkv(params, x, cfg, positions):
    """Project + rope. Returns q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dtype = x.dtype
    q = (x @ params["wq"].astype(dtype)).reshape(B, S, H, hd)
    k = (x @ params["wk"].astype(dtype)).reshape(B, S, KV, hd)
    v = (x @ params["wv"].astype(dtype)).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.mrope_sections is not None:
        assert positions.ndim == 3, "M-RoPE needs (B, S, 3) positions"
        q = apply_mrope(q, positions, sections=cfg.mrope_sections,
                        theta=cfg.rope_theta)
        k = apply_mrope(k, positions, sections=cfg.mrope_sections,
                        theta=cfg.rope_theta)
    else:
        pos2 = positions if positions.ndim == 2 else positions[..., 0]
        q = apply_rope(q, pos2, theta=cfg.rope_theta)
        k = apply_rope(k, pos2, theta=cfg.rope_theta)
    # q: heads shard cleanly on the model axis for most archs (constrain
    # drops the axis when H doesn't divide, e.g. hymba's 25 heads). k/v are
    # left to propagation: KV < model_parallelism for GQA, and forcing a
    # conflicting layout causes SPMD resharding churn every layer.
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    return q, k, v


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: jax.Array | int, q_start: int = 0,
                        kv_block: int = 1024) -> jax.Array:
    """Online-softmax attention with causal + window mask.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); H = KV * G.
    window: effective lookback (scalar; >= Sk means full causal).
    Returns (B, Sq, H, hd) in q.dtype; accumulation in fp32.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    qh = (q.reshape(B, Sq, KV, G, hd) * scale).astype(jnp.float32)
    blk = min(kv_block, Sk)
    n_blk = (Sk + blk - 1) // blk
    pad = n_blk * blk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blk, blk, KV, hd)
    vb = v.reshape(B, n_blk, blk, KV, hd)
    qpos = q_start + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        kj = kj.astype(jnp.float32)
        vj = vj.astype(jnp.float32)
        s = jnp.einsum("bqkgh,bnkh->bkgqn", qh, kj)       # (B,KV,G,Sq,blk)
        kpos = j * blk + jnp.arange(blk)
        valid = (kpos[None, :] <= qpos[:, None]) & \
                (kpos[None, :] > qpos[:, None] - window) & \
                (kpos[None, :] < Sk)
        s = jnp.where(valid[None, None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqn,bnkh->bkgqh", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(n_blk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    # (B, KV, G, Sq, hd) -> (B, Sq, KV, G, hd) -> (B, Sq, H, hd); h = kv*G+g
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def attention_train(params: dict, x: jax.Array, cfg, *,
                    positions: jax.Array,
                    window: jax.Array | int) -> jax.Array:
    """Full-sequence attention for train/prefill. window: per-layer scalar
    (big value = full causal; cfg.window for SWA/local layers)."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg, positions)
    blk = cfg.attn_kv_block or min(1024, S)
    out = blockwise_attention(q, k, v, window=window, kv_block=blk)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    rdt = jnp.bfloat16 if cfg.reduce_dtype == "bfloat16" else jnp.float32
    y = jnp.matmul(out, params["wo"].astype(x.dtype),
                   preferred_element_type=rdt).astype(x.dtype)
    return constrain(y, "batch", None, None)


def attention_decode(params: dict, x: jax.Array, cfg, *,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, window: jax.Array | int,
                     positions: Optional[jax.Array] = None):
    """Single-token decode with a KV cache.

    x: (B, 1, d); cache_k/v: (B, C, KV, hd) (C = cache length, either
    max_seq or the sliding window); pos: scalar int32 current position.
    Sliding-window caches are rings indexed by pos % C.
    Returns (y (B,1,d), new_cache_k, new_cache_v).
    """
    B, one, _ = x.shape
    C = cache_k.shape[1]
    if positions is None:
        positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)
    slot = jnp.mod(pos, C)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    KV, hd, H = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    G = H // KV
    qh = (q.reshape(B, KV, G, hd) * hd ** -0.5).astype(jnp.float32)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    s = jnp.einsum("bkgh,bckh->bkgc", qh, kf)
    # ring position of slot c holds absolute index: for pos < C it is c;
    # for a full ring, absolute = pos - ((slot - c) mod C)
    cidx = jnp.arange(C)
    absolute = pos - jnp.mod(slot - cidx, C)
    valid = (absolute >= 0) & (absolute <= pos) & (absolute > pos - window)
    s = jnp.where(valid[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckh->bkgh", p, vf)
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    y = out @ params["wo"].astype(x.dtype)
    return constrain(y, "batch", None, None), cache_k, cache_v


def init_attention_params(key, cfg, dtype) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, H * hd), dtype) * std,
        "wk": jax.random.normal(k2, (d, KV * hd), dtype) * std,
        "wv": jax.random.normal(k3, (d, KV * hd), dtype) * std,
        "wo": jax.random.normal(k4, (H * hd, d), dtype) * (H * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p
