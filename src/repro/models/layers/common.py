"""Common layers: RMSNorm, rotary embeddings (incl. M-RoPE), SwiGLU MLP,
and the FourierPIM-derived token-mixing layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.kernels import ops as kops


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., dim/2)."""
    freq = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    return positions.astype(jnp.float32)[..., None] * freq


def apply_rope(x: jax.Array, positions: jax.Array, *,
               theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, N, hd); positions: (B, S)."""
    hd = x.shape[-1]
    ang = _rope_angles(positions, hd, theta)          # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, *,
                sections: tuple[int, int, int],
                theta: float = 10_000.0) -> jax.Array:
    """Qwen2-VL multimodal rotary: positions (B, S, 3) = (t, h, w) streams.

    The rotary feature dim is split into three sections, each rotated by its
    own position stream (temporal / height / width). Text tokens carry
    identical t=h=w indices, reducing to standard RoPE.
    """
    hd = x.shape[-1]
    half = hd // 2
    s0, s1, s2 = sections
    assert s0 + s1 + s2 == half, (sections, half)
    ang_parts = []
    for i, sec in enumerate((s0, s1, s2)):
        freq_idx = sum((s0, s1, s2)[:i]) * 2 + jnp.arange(0, 2 * sec, 2,
                                                          dtype=jnp.float32)
        freq = theta ** (-freq_idx / hd)
        ang_parts.append(positions[..., i].astype(jnp.float32)[..., None]
                         * freq)
    ang = jnp.concatenate(ang_parts, axis=-1)          # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu_mlp(params: dict, x: jax.Array,
               reduce_dtype=None) -> jax.Array:
    """params: w_gate (d, f), w_up (d, f), w_down (f, d).

    reduce_dtype: output dtype of the TP-partial down-projection (its
    partial sums are what the model axis all-reduces)."""
    dtype = x.dtype
    gate = x @ params["w_gate"].astype(dtype)
    up = x @ params["w_up"].astype(dtype)
    gate = constrain(gate, "batch", None, "model")
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    out = jnp.matmul(h, params["w_down"].astype(dtype),
                     preferred_element_type=reduce_dtype or jnp.float32)
    return constrain(out.astype(dtype), "batch", None, None)


# ---------------------------------------------------------------------------
# FourierPIM token mixing (paper §5 as a sequence-model primitive)
# ---------------------------------------------------------------------------

def fourier_mixing(params: dict, x: jax.Array) -> jax.Array:
    """Causal depthwise long convolution over the sequence via the paper's
    O(log n)-style FFT convolution (kernels.ops.fft_causal_conv).

    params: taps (K, d) learned filter, gate (d, d) output gate projection.
    x: (B, S, d). Sub-quadratic (O(S log S)) token mixing — the FourierPIM
    primitive integrated as a model layer (DESIGN.md §Arch-applicability).
    """
    dtype = x.dtype
    taps = params["taps"].astype(jnp.float32)          # (K, d)
    xt = jnp.swapaxes(x.astype(jnp.float32), -1, -2)   # (B, d, S)
    kt = jnp.swapaxes(taps, 0, 1)                      # (d, K)
    y = kops.fft_causal_conv(xt, kt[None], backend="xla")
    y = jnp.swapaxes(y, -1, -2).astype(dtype)          # (B, S, d)
    gate = jax.nn.sigmoid((x @ params["gate"].astype(dtype))
                          .astype(jnp.float32)).astype(dtype)
    return y * gate
