"""Mixture-of-Experts FFN: GShard-style grouped top-k dispatch.

Tokens are routed in groups (cfg.moe_group_size) with a per-group expert
capacity C = ceil(g * k / E * capacity_factor); dispatch/combine are dense
one-hot einsums (the standard TPU formulation — MXU-friendly, no gathers).
Experts are tensor-sharded on their f dimension over the `model` axis;
activations stay batch-sharded (dispatch is local). FLOPs per token =
k * FFN (+ router), matching the 6*N_active*D roofline accounting.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain


def init_moe_params(key, cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "router": jax.random.normal(k1, (d, E), jnp.float32) * std,
        "w_gate": jax.random.normal(k2, (E, d, f), dtype) * std,
        "w_up": jax.random.normal(k3, (E, d, f), dtype) * std,
        "w_down": jax.random.normal(k4, (E, f, d), dtype) * f ** -0.5,
    }


def moe_ffn(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Load-balancing aux loss per GShard."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    g = min(cfg.moe_group_size, S)
    pad = (-S) % g
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    nG = x.shape[1] // g
    xg = x.reshape(B, nG, g, d)

    logits = (xg.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))      # (B,nG,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                # (B,nG,g,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # aux load-balance loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1, 2))                    # (E,)
    assign1 = jax.nn.one_hot(idx[..., 0], E)
    fe = jnp.mean(assign1, axis=(0, 1, 2))
    aux = E * jnp.sum(me * fe)

    C = max(1, math.ceil(g * k / E * cfg.capacity_factor))
    ddt = {"float32": jnp.float32,
           "bfloat16": jnp.bfloat16}[cfg.moe_dispatch_dtype]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)      # (B,nG,g,k,E)
    # priority: slot 0 of every token first, then slot 1, ... (GShard order)
    flat = jnp.swapaxes(onehot, 3, 2).reshape(B, nG, g * k, E)
    pos = jnp.cumsum(flat, axis=2) - flat                   # queue position
    keep = pos < C
    flat = flat * keep
    posoh = (jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
             * flat[..., None]).astype(ddt)
    posoh = posoh.reshape(B, nG, k, g, E, C).transpose(0, 1, 3, 2, 4, 5)
    gates_k = jnp.swapaxes(gate_vals, -1, -1)               # (B,nG,g,k)
    combine = jnp.einsum("bngkec,bngk->bngec", posoh,
                         gates_k.astype(ddt))
    dispatch = (combine > 0.0).astype(x.dtype)

    rdt = (jnp.bfloat16 if cfg.reduce_dtype == "bfloat16"
           else jnp.float32)
    xe = jnp.einsum("bngd,bngec->ebncd", xg.astype(x.dtype), dispatch,
                    preferred_element_type=rdt).astype(x.dtype)
    xe = constrain(xe, None, "batch", None, None, None)
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    gate = jnp.einsum("ebncd,edf->ebncf", xe, wg,
                      preferred_element_type=rdt).astype(x.dtype)
    up = jnp.einsum("ebncd,edf->ebncf", xe, wu,
                    preferred_element_type=rdt).astype(x.dtype)
    gate = constrain(gate, None, "batch", None, None, "model")
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    ye = jnp.einsum("ebncf,efd->ebncd", h, wd,
                    preferred_element_type=rdt).astype(x.dtype)
    y = jnp.einsum("ebncd,bngec->bngd", ye, combine.astype(x.dtype),
                   preferred_element_type=rdt).astype(x.dtype)
    y = y.reshape(B, nG * g, d)
    if pad:
        y = y[:, :S]
    return constrain(y, "batch", None, None), aux
