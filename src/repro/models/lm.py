"""Config-driven decoder LM covering the whole architecture zoo.

One implementation serves all 10 assigned architectures:
  * mixer: GQA attention (full / SWA / gemma3 local:global), RWKV6, Hymba
    parallel attn+SSM heads, or FourierPIM token mixing;
  * FFN: dense SwiGLU or grouped top-k MoE;
  * embeddings: token table or precomputed frontend embeddings (audio/VLM
    stubs per the shape contract);
  * positions: RoPE or M-RoPE (B, S, 3).

Layers are stacked (leading L dim on every block leaf) and executed with
lax.scan so HLO size / compile time are depth-independent — required for the
126-layer x 512-device dry-runs. Remat policy per config (none|block|full).

Three entry points (all pure, jit/pjit-friendly):
  loss_fn / train-style forward   (B, S) tokens -> scalar loss
  prefill                         builds KV caches at full sequence length
  decode_step                     one token with cache (serve_step)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import moe as moe_lib
from repro.models.layers import recurrent as rec_lib
from repro.models.layers.common import fourier_mixing, rms_norm, swiglu_mlp

BIG_WINDOW = 1 << 30


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# Parameter initialization (smoke/example scale only; dry-run uses eval_shape)
# ---------------------------------------------------------------------------

def init_block_params(cfg: ModelConfig, key) -> dict:
    """Params for ONE layer (un-stacked); stacked by init_params via vmap."""
    pdt = _dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = iter(jax.random.split(key, 16))
    p: dict[str, Any] = {"ln1": jnp.zeros((d,), pdt),
                         "ln2": jnp.zeros((d,), pdt)}
    if cfg.mixer in ("attn", "hymba"):
        p["attn"] = attn_lib.init_attention_params(next(ks), cfg, pdt)
    if cfg.mixer == "hymba":
        p["ssm"] = rec_lib.init_ssm_params(next(ks), cfg, pdt)
        p["ln_attn_out"] = jnp.zeros((d,), pdt)
        p["ln_ssm_out"] = jnp.zeros((d,), pdt)
    if cfg.mixer == "rwkv6":
        p["rwkv_t"] = rec_lib.init_rwkv_params(next(ks), cfg, pdt)
        p["rwkv_c"] = rec_lib.init_rwkv_channel_params(next(ks), cfg, pdt)
    if cfg.mixer == "fourier":
        p["fourier"] = {
            "taps": jax.random.normal(next(ks), (cfg.fourier_taps, d), pdt)
            * 0.02,
            "gate": jax.random.normal(next(ks), (d, d), pdt) * d ** -0.5,
        }
    if cfg.mixer != "rwkv6":
        if cfg.is_moe:
            p["moe"] = moe_lib.init_moe_params(next(ks), cfg, pdt)
        else:
            p["mlp"] = {
                "w_gate": jax.random.normal(next(ks), (d, cfg.d_ff), pdt)
                * d ** -0.5,
                "w_up": jax.random.normal(next(ks), (d, cfg.d_ff), pdt)
                * d ** -0.5,
                "w_down": jax.random.normal(next(ks), (cfg.d_ff, d), pdt)
                * cfg.d_ff ** -0.5,
            }
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    pdt = _dtype(cfg.param_dtype)
    k_emb, k_head, k_blocks = jax.random.split(key, 3)
    blocks = jax.vmap(
        lambda k: init_block_params(cfg, k))(
            jax.random.split(k_blocks, cfg.num_layers))
    params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_padded, cfg.d_model),
                                   pdt) * 0.02,
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "lm_head": jax.random.normal(k_head, (cfg.d_model, cfg.vocab_padded),
                                     pdt) * cfg.d_model ** -0.5,
    }
    return params


def abstract_params(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree (no allocation) — dry-run entry."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# Sharding specs (logical rules; sanitized against the bound mesh at launch)
# ---------------------------------------------------------------------------

FSDP = ("pod", "data")
TP = "model"


def param_specs(cfg: ModelConfig) -> Any:
    """PartitionSpec pytree matching init_params' structure."""
    def blk(spec):  # block leaves carry a leading layer dim
        return P(*([None] + list(spec)))

    b: dict[str, Any] = {"ln1": blk([FSDP]), "ln2": blk([FSDP])}
    if cfg.mixer in ("attn", "hymba"):
        a = {"wq": blk([FSDP, TP]), "wk": blk([FSDP, TP]),
             "wv": blk([FSDP, TP]), "wo": blk([TP, FSDP])}
        if cfg.qk_norm:
            a["q_norm"] = blk([None])
            a["k_norm"] = blk([None])
        b["attn"] = a
    if cfg.mixer == "hymba":
        b["ssm"] = {"w_dt": blk([FSDP, TP]), "w_b": blk([FSDP, None]),
                    "w_c": blk([FSDP, None]), "a_log": blk([FSDP, None]),
                    "d_skip": blk([FSDP]), "dt_bias": blk([FSDP])}
        b["ln_attn_out"] = blk([FSDP])
        b["ln_ssm_out"] = blk([FSDP])
    if cfg.mixer == "rwkv6":
        b["rwkv_t"] = {"mu": blk([None, FSDP]), "wr": blk([FSDP, TP]),
                       "wk": blk([FSDP, TP]), "wv": blk([FSDP, TP]),
                       "wg": blk([FSDP, TP]), "wo": blk([TP, FSDP]),
                       "w0": blk([FSDP]), "ww1": blk([FSDP, TP]),
                       "ww2": blk([TP, FSDP]), "u": blk([TP, None])}
        b["rwkv_c"] = {"mu_c": blk([None, FSDP]), "wk": blk([FSDP, TP]),
                       "wv": blk([TP, FSDP]), "wr": blk([FSDP, TP])}
    if cfg.mixer == "fourier":
        b["fourier"] = {"taps": blk([None, FSDP]), "gate": blk([FSDP, TP])}
    if cfg.mixer != "rwkv6":
        if cfg.is_moe:
            b["moe"] = {"router": blk([FSDP, None]),
                        "w_gate": blk([None, FSDP, TP]),
                        "w_up": blk([None, FSDP, TP]),
                        "w_down": blk([None, TP, FSDP])}
        else:
            b["mlp"] = {"w_gate": blk([FSDP, TP]), "w_up": blk([FSDP, TP]),
                        "w_down": blk([TP, FSDP])}
    return {
        "embed": P(TP, FSDP),
        "blocks": b,
        "final_norm": P(FSDP),
        "lm_head": P(FSDP, TP),
    }


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _layer_windows(cfg: ModelConfig, seq_len: int) -> jnp.ndarray:
    """Per-layer effective attention window (L,) int32."""
    if cfg.attention == "full" or cfg.mixer in ("rwkv6", "fourier"):
        w = [BIG_WINDOW] * cfg.num_layers
    elif cfg.attention == "swa":
        w = [cfg.window] * cfg.num_layers
    elif cfg.attention == "local_global":
        w = [BIG_WINDOW if cfg.layer_is_global(i) else cfg.window
             for i in range(cfg.num_layers)]
    else:
        w = [BIG_WINDOW] * cfg.num_layers
    return jnp.asarray(w, jnp.int32)


def block_forward(cfg: ModelConfig, p: dict, x: jax.Array, *,
                  positions: jax.Array, window: jax.Array,
                  want_cache: bool = False):
    """One transformer block (train/prefill). Returns (x, aux, cache)."""
    aux = jnp.zeros((), jnp.float32)
    cache = ()
    if cfg.sequence_parallel:
        # carry (and its saved stack) lives sequence-sharded; the mixer's
        # projections trigger the gather internally
        x = constrain(x, "batch", "model", None)
    h = rms_norm(x, p["ln1"])
    if cfg.mixer == "attn":
        y = attn_lib.attention_train(p["attn"], h, cfg, positions=positions,
                                     window=window)
        if want_cache:
            # recompute k/v cheaply for the cache (prefill)
            _, k, v = attn_lib._qkv(p["attn"], h, cfg, positions)
            cache = (k, v)
    elif cfg.mixer == "hymba":
        y_attn = attn_lib.attention_train(p["attn"], h, cfg,
                                          positions=positions, window=window)
        y_ssm, ssm_state = rec_lib.ssm_mix(p["ssm"], h)
        y = 0.5 * (rms_norm(y_attn, p["ln_attn_out"])
                   + rms_norm(y_ssm, p["ln_ssm_out"]))
        if want_cache:
            _, k, v = attn_lib._qkv(p["attn"], h, cfg, positions)
            cache = (k, v, ssm_state)
    elif cfg.mixer == "rwkv6":
        y, rwkv_state = rec_lib.rwkv_time_mix(p["rwkv_t"], h)
        if want_cache:
            cache = (rwkv_state["prev_x"], rwkv_state["S"])
    elif cfg.mixer == "fourier":
        y = fourier_mixing(p["fourier"], h)
        if want_cache:
            K = cfg.fourier_taps
            S = h.shape[1]
            if S >= K:
                ring = h[:, -K:]        # slots line up when S % K == 0
            else:
                ring = jnp.pad(h, ((0, 0), (0, K - S), (0, 0)))
            cache = (ring,)
    else:
        raise ValueError(cfg.mixer)
    x = x + y

    h2 = rms_norm(x, p["ln2"])
    if cfg.mixer == "rwkv6":
        y2, prev_c = rec_lib.rwkv_channel_mix(p["rwkv_c"], h2,
                                              jnp.zeros_like(h2[:, 0]))
        if want_cache:
            cache = cache + (prev_c,)
    elif cfg.is_moe:
        y2, aux = moe_lib.moe_ffn(p["moe"], h2, cfg)
    else:
        y2 = swiglu_mlp(p["mlp"], h2,
                        reduce_dtype=jnp.bfloat16
                        if cfg.reduce_dtype == "bfloat16" else None)
    x = x + y2
    if cfg.sequence_parallel:
        x = constrain(x, "batch", "model", None)
    return x, aux, cache


def _best_outer(L: int) -> int:
    """Largest divisor of L closest to sqrt(L)."""
    import math
    root = int(math.sqrt(L))
    for d in range(root, 0, -1):
        if L % d == 0:
            return d
    return 1


def _scan_blocks(cfg: ModelConfig, params: dict, x: jax.Array, *,
                 positions: jax.Array, want_cache: bool):
    windows = _layer_windows(cfg, x.shape[1])

    def body(carry, inp):
        p, w = inp
        xc = carry
        fn = functools.partial(block_forward, cfg, want_cache=want_cache)
        if cfg.remat in ("block", "full"):
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
                if cfg.remat == "full" else
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        xc, aux, cache = fn(p, xc, positions=positions, window=w)
        return xc, (aux, cache)

    if cfg.scan_layers and cfg.remat == "sqrt":
        # sqrt(L) nested remat: the outer scan checkpoints only block-group
        # boundaries, so the saved carry stack is O(sqrt(L)) instead of
        # O(L); the inner scan recomputes its group in the backward pass.
        L = cfg.num_layers
        Lo = _best_outer(L)
        Li = L // Lo
        blocks_r = jax.tree.map(
            lambda a: a.reshape(Lo, Li, *a.shape[1:]), params["blocks"])
        windows_r = windows.reshape(Lo, Li)

        inner_fn = jax.checkpoint(
            functools.partial(block_forward, cfg, want_cache=False),
            policy=jax.checkpoint_policies.nothing_saveable)

        def inner(carry, inp):
            p, w = inp
            xc, aux_acc = carry
            xc, aux, _ = inner_fn(p, xc, positions=positions, window=w)
            return (xc, aux_acc + aux), None

        @jax.checkpoint
        def outer_body(carry, inp):
            ps, ws = inp
            (xc, aux_acc), _ = jax.lax.scan(inner, carry, (ps, ws))
            return (xc, aux_acc), None

        (x, aux), _ = jax.lax.scan(
            outer_body, (x, jnp.zeros((), jnp.float32)),
            (blocks_r, windows_r))
        caches = ()
        assert not want_cache, "sqrt remat is a train-path policy"
        return x, aux, caches

    if cfg.scan_layers:
        x, (auxs, caches) = jax.lax.scan(body, x,
                                         (params["blocks"], windows))
        aux = jnp.sum(auxs)
    elif cfg.remat == "sqrt":
        # unrolled sqrt-remat (cost probes): same two-level checkpoint
        # structure as the scanned path so recompute FLOPs are counted.
        L = cfg.num_layers
        Lo = _best_outer(L)
        Li = L // Lo
        inner_fn = jax.checkpoint(
            functools.partial(block_forward, cfg, want_cache=False),
            policy=jax.checkpoint_policies.nothing_saveable)
        aux = jnp.zeros((), jnp.float32)

        def group(xc, aux_acc, idx0, ps):
            for j in range(Li):
                p_j = jax.tree.map(lambda a: a[j], ps)
                xc, a_j, _ = inner_fn(p_j, xc, positions=positions,
                                      window=windows[idx0 + j])
                aux_acc = aux_acc + a_j
            return xc, aux_acc

        for g in range(Lo):
            ps = jax.tree.map(
                lambda a: a[g * Li:(g + 1) * Li], params["blocks"])
            x, aux = jax.checkpoint(
                functools.partial(group, idx0=g * Li, ps=ps))(x, aux)
        return x, aux, ()
    else:
        caches_list = []
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            x, (a_i, c_i) = body(x, (p_i, windows[i]))
            aux = aux + a_i
            caches_list.append(c_i)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches_list) \
            if caches_list and caches_list[0] != () else ()
    return x, aux, caches


def forward(cfg: ModelConfig, params: dict, tokens: Optional[jax.Array], *,
            positions: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            want_cache: bool = False):
    """Returns (logits, aux_loss, caches)."""
    adt = _dtype(cfg.dtype)
    if cfg.frontend == "embeddings":
        assert embeds is not None
        x = embeds.astype(adt)
    else:
        x = params["embed"].astype(adt)[tokens]
        x = x * jnp.sqrt(float(cfg.d_model)).astype(adt)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    x = constrain(x, "batch", "sp", None)
    x, aux, caches = _scan_blocks(cfg, params, x, positions=positions,
                                  want_cache=want_cache)
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"].astype(adt)
    logits = constrain(logits, "batch", None, "model")
    return logits, aux, caches


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Next-token cross entropy (labels = batch['labels'], -1 = ignore)."""
    logits, aux, _ = forward(
        cfg, params, batch.get("tokens"),
        positions=batch.get("positions"), embeds=batch.get("embeds"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    # mask padded vocab entries with an additive bias (fusable, keeps the
    # vocab axis sharded — a gather here would force a 40 GB all-gather)
    if cfg.vocab_padded != cfg.vocab_size:
        pad_bias = jnp.where(jnp.arange(cfg.vocab_padded) >= cfg.vocab_size,
                             -1e9, 0.0)
        logits = logits + pad_bias[None, None]
    logits = constrain(logits, "batch", None, "model")
    lse = jax.nn.logsumexp(logits, axis=-1)
    # label pick via fused one-hot contraction (shard-friendly: reduces over
    # the sharded vocab axis instead of gathering along it)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), cfg.vocab_padded,
                            dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """SWA archs keep a ring of window size; others the full sequence."""
    if cfg.attention == "swa" and cfg.mixer in ("attn", "hymba"):
        return min(seq_len, cfg.window)
    return seq_len


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                      dtype=None) -> dict:
    adt = dtype or _dtype(cfg.dtype)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    C = cache_len(cfg, seq_len)
    st: dict[str, Any] = {}
    if cfg.mixer in ("attn", "hymba"):
        st["cache_k"] = jnp.zeros((L, batch, C, KV, hd), adt)
        st["cache_v"] = jnp.zeros((L, batch, C, KV, hd), adt)
    if cfg.mixer == "hymba":
        st["ssm_h"] = jnp.zeros((L, batch, cfg.d_model, cfg.ssm_state),
                                jnp.float32)
    if cfg.mixer == "rwkv6":
        H = cfg.d_model // rec_lib.RWKV_HEAD_DIM
        st["prev_x"] = jnp.zeros((L, batch, cfg.d_model), adt)
        st["S"] = jnp.zeros((L, batch, H, rec_lib.RWKV_HEAD_DIM,
                             rec_lib.RWKV_HEAD_DIM), jnp.float32)
        st["prev_x_c"] = jnp.zeros((L, batch, cfg.d_model), adt)
    if cfg.mixer == "fourier":
        st["ring"] = jnp.zeros((L, batch, cfg.fourier_taps, cfg.d_model), adt)
    return st


def decode_state_specs(cfg: ModelConfig) -> dict:
    sp: dict[str, Any] = {}
    if cfg.mixer in ("attn", "hymba"):
        sp["cache_k"] = P(None, ("pod", "data"), None, None, TP)
        sp["cache_v"] = P(None, ("pod", "data"), None, None, TP)
    if cfg.mixer == "hymba":
        sp["ssm_h"] = P(None, ("pod", "data"), TP, None)
    if cfg.mixer == "rwkv6":
        sp["prev_x"] = P(None, ("pod", "data"), TP)
        sp["S"] = P(None, ("pod", "data"), TP, None, None)
        sp["prev_x_c"] = P(None, ("pod", "data"), TP)
    if cfg.mixer == "fourier":
        sp["ring"] = P(None, ("pod", "data"), None, TP)
    return sp


def _block_decode(cfg: ModelConfig, p: dict, x: jax.Array, st: dict, *,
                  pos: jax.Array, window: jax.Array,
                  positions: Optional[jax.Array]):
    """One block, one token. st holds this layer's slice (no leading L)."""
    new_st = dict(st)
    h = rms_norm(x, p["ln1"])
    if cfg.mixer in ("attn", "hymba"):
        y_attn, ck, cv = attn_lib.attention_decode(
            p["attn"], h, cfg, cache_k=st["cache_k"], cache_v=st["cache_v"],
            pos=pos, window=window, positions=positions)
        new_st["cache_k"], new_st["cache_v"] = ck, cv
    if cfg.mixer == "attn":
        y = y_attn
    elif cfg.mixer == "hymba":
        y_ssm, hnew = rec_lib.ssm_mix(p["ssm"], h, state=st["ssm_h"])
        new_st["ssm_h"] = hnew
        y = 0.5 * (rms_norm(y_attn, p["ln_attn_out"])
                   + rms_norm(y_ssm, p["ln_ssm_out"]))
    elif cfg.mixer == "rwkv6":
        state = {"prev_x": st["prev_x"], "S": st["S"]}
        y, ns = rec_lib.rwkv_time_mix(p["rwkv_t"], h, state=state)
        new_st["prev_x"], new_st["S"] = ns["prev_x"], ns["S"]
    elif cfg.mixer == "fourier":
        ring = st["ring"]
        K = cfg.fourier_taps
        slot = jnp.mod(pos, K)
        ring = jax.lax.dynamic_update_slice(
            ring, h.astype(ring.dtype)[:, :1], (0, slot, 0))
        taps = p["fourier"]["taps"].astype(jnp.float32)      # (K, d)
        cidx = jnp.arange(K)
        lag = jnp.mod(slot - cidx, K)                        # age of slot
        w = jnp.where(lag[:, None] <= pos, taps[lag], 0.0)
        y = jnp.einsum("bkd,kd->bd", ring.astype(jnp.float32), w)[:, None]
        gate = jax.nn.sigmoid(
            (h @ p["fourier"]["gate"].astype(h.dtype)).astype(jnp.float32))
        y = (y * gate).astype(x.dtype)
        new_st["ring"] = ring
    x = x + y
    h2 = rms_norm(x, p["ln2"])
    if cfg.mixer == "rwkv6":
        y2, prev_c = rec_lib.rwkv_channel_mix(p["rwkv_c"], h2, st["prev_x_c"])
        new_st["prev_x_c"] = prev_c
    elif cfg.is_moe:
        y2, _ = moe_lib.moe_ffn(p["moe"], h2, cfg)
    else:
        y2 = swiglu_mlp(p["mlp"], h2,
                        reduce_dtype=jnp.bfloat16
                        if cfg.reduce_dtype == "bfloat16" else None)
    return x + y2, new_st


def decode_step(cfg: ModelConfig, params: dict, state: dict,
                token: jax.Array, pos: jax.Array, *,
                positions: Optional[jax.Array] = None,
                embed: Optional[jax.Array] = None):
    """serve_step: one new token for the whole batch.

    token: (B,) int32 (or embed (B, 1, d) for frontend archs); pos: scalar.
    Returns (logits (B, vocab_padded), new_state).
    """
    adt = _dtype(cfg.dtype)
    if cfg.frontend == "embeddings":
        x = embed.astype(adt)
    else:
        x = params["embed"].astype(adt)[token][:, None]
        x = x * jnp.sqrt(float(cfg.d_model)).astype(adt)
    windows = _layer_windows(cfg, cfg.max_seq_len)

    def body(xc, inp):
        p, w, st = inp
        xn, st_new = _block_decode(cfg, p, xc, st, pos=pos, window=w,
                                   positions=positions)
        return xn, st_new

    if cfg.scan_layers:
        x, new_state = jax.lax.scan(body, x,
                                    (params["blocks"], windows, state))
    else:
        new_states = []
        for i in range(cfg.num_layers):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            st_i = jax.tree.map(lambda a: a[i], state)
            x, st_new = body(x, (p_i, windows[i], st_i))
            new_states.append(st_new)
        new_state = jax.tree.map(lambda *xs: jnp.stack(xs), *new_states)
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"].astype(adt))[:, 0]
    return logits.astype(jnp.float32), new_state


def prefill(cfg: ModelConfig, params: dict, tokens: Optional[jax.Array], *,
            positions: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            cache_capacity: Optional[int] = None):
    """Full-sequence forward returning (last_logits, decode_state).

    cache_capacity: KV slots to allocate (>= S for full attention so decode
    can append; defaults to the prefilled length)."""
    logits, _, caches = forward(cfg, params, tokens, positions=positions,
                                embeds=embeds, want_cache=True)
    B = logits.shape[0]
    S = (tokens if tokens is not None else embeds).shape[1]
    state = init_decode_state(cfg, B, cache_capacity or S)
    if cfg.mixer in ("attn", "hymba"):
        k, v = caches[0], caches[1]                  # (L, B, S, KV, hd)
        C = state["cache_k"].shape[2]
        if C >= S:
            # slots p % C == p for p < S <= C
            state["cache_k"] = jax.lax.dynamic_update_slice(
                state["cache_k"], k.astype(state["cache_k"].dtype),
                (0, 0, 0, 0, 0))
            state["cache_v"] = jax.lax.dynamic_update_slice(
                state["cache_v"], v.astype(state["cache_v"].dtype),
                (0, 0, 0, 0, 0))
        else:
            # ring: keep the last C; slots line up when S % C == 0
            assert S % C == 0, (S, C)
            state["cache_k"] = k[:, :, -C:].astype(state["cache_k"].dtype)
            state["cache_v"] = v[:, :, -C:].astype(state["cache_v"].dtype)
    if cfg.mixer == "hymba":
        state["ssm_h"] = caches[2]
    if cfg.mixer == "rwkv6":
        state["prev_x"] = caches[0]
        state["S"] = caches[1]
        state["prev_x_c"] = caches[2]
    if cfg.mixer == "fourier":
        assert S % cfg.fourier_taps == 0 or S < cfg.fourier_taps, \
            "fourier ring alignment needs S % taps == 0"
        state["ring"] = caches[0].astype(state["ring"].dtype)
    return logits[:, -1], state
