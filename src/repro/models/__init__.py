"""LM assembly over the layer zoo."""
