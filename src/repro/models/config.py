"""Model configuration dataclass for the architecture zoo.

Every assigned architecture instantiates this one config (see
src/repro/configs/<id>.py); the decoder in models/lm.py is entirely
config-driven. Reduced smoke-test variants use .scaled_down().
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # --- attention structure ---
    attention: str = "full"       # full | swa | local_global | none
    window: int = 4096            # sliding-window size (swa / local layers)
    local_global_ratio: int = 0   # gemma3: 5 local layers per 1 global
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # --- mixer selection ---
    mixer: str = "attn"           # attn | rwkv6 | hymba (parallel attn+ssm)
    ssm_state: int = 0            # state size for mamba-style heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 1024    # GShard-style grouped dispatch
    capacity_factor: float = 1.25
    # dtype of the dispatch/combine one-hot tensors (router logits stay
    # fp32). bf16 halves the dominant MoE collective payloads — §Perf knob.
    moe_dispatch_dtype: str = "float32"
    # Output dtype of TP-partial matmuls (down-proj / out-proj / expert
    # einsums). jnp defaults bf16 dots to f32 outputs, so XLA all-reduces
    # f32 partial sums; "bfloat16" halves every TP collective payload
    # (fwd and bwd) at the standard mixed-precision accuracy trade.
    reduce_dtype: str = "float32"

    # --- FourierPIM tie-in (paper §5 primitive as a token-mixing layer) ---
    use_fourier_mixing: bool = False
    fourier_taps: int = 128

    # --- modality frontend stub (audio/vlm: precomputed embeddings) ---
    frontend: str = "none"        # none | embeddings

    # --- numerics / memory ---
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "block"          # none | block | full | sqrt
    scan_layers: bool = True
    # Sequence-parallel residual stream (Megatron-SP): the scan carry and
    # its saved per-layer stack are sharded over the model axis on the
    # sequence dim; attention/MLP re-gather internally. Memory-term lever
    # traded against the collective term (see EXPERIMENTS.md §Perf).
    sequence_parallel: bool = False
    # Gradient accumulation: the train step scans over this many
    # microbatches, accumulating fp32 grads — activations scale 1/k while
    # arithmetic is unchanged (memory-term lever at fixed global batch).
    grad_accum_steps: int = 1

    # --- serving ---
    max_seq_len: int = 32768

    # --- attention micro-tiling (0 = default: min(1024, S)). The dry-run's
    # cost probes set this to S so the flash KV-scan unrolls to one step
    # and XLA's cost_analysis counts its FLOPs exactly. ---
    attn_kv_block: int = 0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.num_heads))

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the logits axis shards over 16-way TP."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def n_global_layers(self) -> int:
        if self.attention != "local_global":
            return self.num_layers
        return self.num_layers // (self.local_global_ratio + 1)

    def layer_is_global(self, i: int) -> bool:
        """gemma3 pattern: every (ratio+1)-th layer is global."""
        if self.attention != "local_global":
            return self.attention == "full"
        return (i + 1) % (self.local_global_ratio + 1) == 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, H, KV, hd = (self.d_model, self.d_ff, self.num_heads,
                           self.num_kv_heads, self.head_dim)
        per_layer = 0
        if self.mixer in ("attn", "hymba"):
            per_layer += d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.mixer == "rwkv6":
            per_layer += 4 * d * d + d * d  # r,k,v,g,o projections
        if self.mixer == "hymba":
            per_layer += 2 * d * d // 2 + d * self.ssm_state * 2  # ssm branch
        if self.is_moe:
            per_layer += d * self.num_experts            # router
            per_layer += self.num_experts * 3 * d * f    # expert FFNs
        else:
            per_layer += 3 * d * f
        per_layer += 2 * d                                # norms
        total = self.num_layers * per_layer
        total += self.vocab_padded * d                    # embedding
        total += d * self.vocab_padded                    # lm head
        total += d                                        # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_experts = self.num_experts * 3 * d * f
        active_experts = self.experts_per_token * 3 * d * f
        return (self.param_count()
                - self.num_layers * (dense_experts - active_experts))

    def scaled_down(self) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        mrope = None
        if self.mrope_sections is not None:
            half = 32 // 2  # reduced head_dim = 32
            s0 = half // 4
            s1 = (half - s0) // 2
            mrope = (s0, s1, half - s0 - s1)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            window=64,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_group_size=32,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            mrope_sections=mrope,
            fourier_taps=16,
            max_seq_len=128,
            dtype="float32",
            param_dtype="float32",
        )
