"""FourierPIM reproduction package (src layout; see ROADMAP.md)."""
