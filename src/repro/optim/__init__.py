"""Optimizers (AdamW with optional int8 moments)."""
