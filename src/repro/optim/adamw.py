"""AdamW with optional 8-bit (block-quantized) moments.

Dependency-free (no optax). The int8 state path is the distributed-training
memory trick that lets 405B-scale optimizer state fit the v5e HBM budget in
the dry-run (DESIGN.md §6): m and v are stored int8 with a float scale per
block of 128 along the last axis, dequantized on use, requantized after the
update (error stays bounded because Adam moments are smooth EWMAs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # float32 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


BLOCK = 128


def _quantize(x: jax.Array) -> dict:
    """Blockwise symmetric int8 quantization along the last axis."""
    if x.ndim == 0:
        x = x.reshape(1)
    shape = x.shape
    last = shape[-1]
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = x.reshape(*x.shape[:-1], x.shape[-1] // BLOCK, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    q = jnp.round(xb / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32),
            "orig_last": jnp.asarray(last)}


def _dequantize(d: dict, last: int, scalar: bool = False) -> jax.Array:
    x = d["q"].astype(jnp.float32) * d["scale"]
    x = x.reshape(*x.shape[:-2], -1)
    x = x[..., :last]
    return x.reshape(()) if scalar else x


def init_state(params: Any, cfg: OptConfig) -> dict:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_dtype == "int8":
            return _quantize(z)
        return z
    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    g = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * factor
                                   ).astype(x.dtype), grads), g


def apply(params: Any, grads: Any, state: dict, cfg: OptConfig
          ) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    int8 = cfg.state_dtype == "int8"

    def upd(p, g, m, v):
        last = p.shape[-1] if p.ndim else 1
        scalar = p.ndim == 0
        gf = g.astype(jnp.float32)
        mf = _dequantize(m, last, scalar) if int8 else m
        vf = _dequantize(v, last, scalar) if int8 else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, (_quantize(mf) if int8 else mf,
                      _quantize(vf) if int8 else vf)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    if int8:
        # m/v leaves are dicts; flatten against the params treedef
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
    else:
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1][0] for o in out])
    new_v = treedef.unflatten([o[1][1] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}


def state_specs(param_specs: Any, cfg: OptConfig) -> dict:
    """PartitionSpecs for the optimizer state mirroring the params' specs."""
    from jax.sharding import PartitionSpec as P
    is_spec = lambda x: isinstance(x, P)  # noqa: E731 (P is a tuple subclass)
    if cfg.state_dtype == "int8":
        def qspec(ps):
            # quantize splits the last axis into (blocks, BLOCK=128): put
            # the original last-axis sharding on the BLOCK axis (always
            # divisible by the mesh axes) — the block-count axis (e.g.
            # 6144/128 = 48) often isn't divisible by a 32-way fsdp axis.
            parts = list(ps) if ps else []
            last = parts[-1] if parts else None
            lead = parts[:-1] if parts else []
            return {"q": P(*lead, None, last), "scale": P(*lead, None, None),
                    "orig_last": P()}
        m = jax.tree.map(qspec, param_specs, is_leaf=is_spec)
        return {"m": m, "v": m, "step": P()}
    return {"m": param_specs, "v": param_specs, "step": P()}
