"""Version shims over jax's distribution APIs.

The distribution layer is written against present-day jax (``jax.shard_map``
with ``check_vma``, ``jax.set_mesh``, ``jax.sharding.AxisType``); the pinned
toolchain may be an older 0.4.x jaxlib where those live under different
names (``jax.experimental.shard_map`` with ``check_rep``, the resource-env
``with mesh:`` context, no axis types). Everything in repro that touches
meshes or shard_map goes through this module so call sites read like
current jax and keep working unchanged when the toolchain moves.
"""
from __future__ import annotations

import contextlib
import inspect

import jax

# ---------------------------------------------------------------------------
# shard_map: jax.shard_map(check_vma=) vs jax.experimental check_rep=
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on old jax
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with the replication-check kwarg name papered over."""
    kwargs = {}
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


# ---------------------------------------------------------------------------
# Mesh construction: axis_types appeared with sharding-in-types
# ---------------------------------------------------------------------------

_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)


def axis_types_auto(n: int):
    """``(AxisType.Auto,) * n`` on jax versions that have axis types."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return (axis_type.Auto,) * n if axis_type is not None else None


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# ---------------------------------------------------------------------------
# Ambient mesh context: jax.set_mesh vs the legacy resource env
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def set_mesh(mesh):
    """Activate ``mesh`` for jit tracing under it.

    On new jax this is ``jax.set_mesh``; on old jax it is the legacy
    ``with mesh:`` resource env. Both additionally push the mesh onto
    ``repro.dist.sharding``'s ambient stack so ``constrain`` resolves it.
    """
    from repro.dist import sharding
    with contextlib.ExitStack() as stack:
        if hasattr(jax, "set_mesh"):
            stack.enter_context(jax.set_mesh(mesh))
        else:
            stack.enter_context(mesh)
        stack.enter_context(sharding.use_mesh(mesh))
        yield mesh
