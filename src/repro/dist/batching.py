"""Crossbar-batch scheduling (paper §6) on arrays and meshes.

FourierPIM's throughput headline comes from running one transform per
crossbar array, all arrays in parallel; a batch of B transforms therefore
executes in ``ceil(B / num_arrays)`` *waves*, and the last (tail) wave
leaves arrays idle. The same shape appears one level up on the TPU mesh:
B transforms map onto the ``(pod, data)`` device axes, then onto each
device's local arrays (crossbars for the PIM model, flop units for the XLA
path).

This module is pure scheduling arithmetic — no jax ops — so both the
numpy-based PIM simulator (``core.pim.fft_pim``) and the shard_map path
(``core.fft.distributed``) use it to report per-array utilization, and
benchmarks use it to convert single-transform latency into batched
throughput.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["WaveSchedule", "MeshBatchPlan", "CrossbarBatchPlan",
           "schedule_waves", "shard_batch", "plan_crossbar_batch"]


@dataclasses.dataclass(frozen=True)
class WaveSchedule:
    """B transforms over ``num_arrays`` parallel arrays, in waves."""
    batch: int
    num_arrays: int
    waves: int
    tail: int               # transforms in the final partial wave (0 = none)

    @property
    def wave_sizes(self) -> tuple[int, ...]:
        full = [self.num_arrays] * (self.batch // self.num_arrays)
        return tuple(full + ([self.tail] if self.tail else []))

    @property
    def utilization(self) -> float:
        """Busy array-waves / provisioned array-waves."""
        if self.batch == 0:
            return 0.0
        return self.batch / (self.waves * self.num_arrays)

    def latency(self, wave_latency: float) -> float:
        return self.waves * wave_latency

    def throughput(self, wave_latency: float) -> float:
        """Completed transforms per unit time at ``wave_latency`` each."""
        if self.batch == 0:
            return 0.0
        return self.batch / self.latency(wave_latency)


def schedule_waves(batch: int, num_arrays: int) -> WaveSchedule:
    if batch < 0 or num_arrays < 1:
        raise ValueError(f"bad schedule: batch={batch} arrays={num_arrays}")
    waves = max(1, math.ceil(batch / num_arrays)) if batch else 0
    tail = batch % num_arrays if batch else 0
    return WaveSchedule(batch=batch, num_arrays=num_arrays,
                        waves=waves, tail=tail)


@dataclasses.dataclass(frozen=True)
class MeshBatchPlan:
    """B transforms over the mesh's batch-bearing device axes."""
    global_batch: int
    axes: tuple[str, ...]   # mesh axes actually present and used
    n_devices: int          # product of their sizes
    per_device: int         # ceil share per device
    pad: int                # ghost transforms added to even out the shards

    @property
    def utilization(self) -> float:
        if self.global_batch == 0:
            return 0.0
        return self.global_batch / (self.per_device * self.n_devices)


def shard_batch(batch: int, mesh, axes=("pod", "data")) -> MeshBatchPlan:
    """Partition ``batch`` transforms over the mesh axes in ``axes``.

    Axes absent from the mesh are skipped (single-pod meshes have no "pod"),
    mirroring the ``sharding.sanitize_spec`` contract. A batch that doesn't
    divide is padded up; the pad shows up as lost utilization, not an error.
    """
    present = tuple(a for a in axes if a in mesh.shape)
    n_dev = 1
    for a in present:
        n_dev *= int(mesh.shape[a])
    per_device = math.ceil(batch / n_dev) if batch else 0
    return MeshBatchPlan(global_batch=batch, axes=present, n_devices=n_dev,
                         per_device=per_device,
                         pad=per_device * n_dev - batch)


@dataclasses.dataclass(frozen=True)
class CrossbarBatchPlan:
    """Combined plan: mesh sharding, then per-device waves over arrays."""
    mesh_plan: MeshBatchPlan
    wave: WaveSchedule      # the per-device schedule

    @property
    def waves(self) -> int:
        return self.wave.waves

    @property
    def utilization(self) -> float:
        """Fraction of provisioned array-waves doing real work, across the
        whole installation (mesh padding x tail-wave idling)."""
        if self.mesh_plan.global_batch == 0:
            return 0.0
        provisioned = (self.mesh_plan.n_devices * self.wave.waves
                       * self.wave.num_arrays)
        return self.mesh_plan.global_batch / provisioned

    def latency(self, wave_latency: float) -> float:
        return self.wave.latency(wave_latency)

    def throughput(self, wave_latency: float) -> float:
        """Global transforms/sec: every device runs its waves in parallel."""
        if self.mesh_plan.global_batch == 0:
            return 0.0
        return self.mesh_plan.global_batch / self.latency(wave_latency)

    def report(self) -> dict:
        return {
            "global_batch": self.mesh_plan.global_batch,
            "mesh_axes": list(self.mesh_plan.axes),
            "n_devices": self.mesh_plan.n_devices,
            "per_device_batch": self.mesh_plan.per_device,
            "arrays_per_device": self.wave.num_arrays,
            "waves": self.wave.waves,
            "tail": self.wave.tail,
            "utilization": self.utilization,
        }


def plan_crossbar_batch(batch: int, *, num_arrays: int, mesh=None,
                        axes=("pod", "data")) -> CrossbarBatchPlan:
    """Plan B transforms onto (optionally) a mesh, then onto per-device
    arrays. ``mesh=None`` plans for a single device's arrays — the paper's
    §6 setting, where ``num_arrays`` is the crossbar count."""
    if mesh is not None:
        mp = shard_batch(batch, mesh, axes)
        per_device = mp.per_device
    else:
        mp = MeshBatchPlan(global_batch=batch, axes=(), n_devices=1,
                           per_device=batch, pad=0)
        per_device = batch
    return CrossbarBatchPlan(mesh_plan=mp,
                             wave=schedule_waves(per_device, num_arrays))
