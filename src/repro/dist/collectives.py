"""Collectives with byte accounting + error-feedback compressed psum.

Two things live here:

* Thin wrappers over ``jax.lax`` collectives (``psum``, ``all_gather``,
  ``all_to_all``, ``ppermute``) that record moved bytes into a trace-time
  ledger. ``benchmarks/roofline.py`` folds the ledger into its collective
  term for code paths (shard_map kernels) whose HLO isn't captured by the
  dry-run artifacts. Byte counts are recorded once per *trace*, so a jitted
  step contributes its per-call bytes exactly once.

* ``compressed_psum_leaf``: the cross-pod gradient reduction. Each device
  adds its carried residual to the leaf, quantizes to int8 with one f32
  scale per leaf, exchanges the int8 payload + scales (4x fewer wire bytes
  than an f32 ring all-reduce), dequantizes, and returns the *mean* across
  the axis plus the new residual (what quantization dropped). The residual
  is fed back on the next step, so the quantization error is carried, not
  lost (error-feedback / EF-SGD style).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import numpy as np

import jax
import jax.numpy as jnp

KINDS = ("psum", "all-gather", "all-to-all", "ppermute", "compressed-psum")


# ---------------------------------------------------------------------------
# Byte ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ledger:
    """Accumulated collective traffic, by kind, in result-bytes per device
    (the same accounting unit as ``launch.dryrun.collective_bytes``)."""
    bytes_by_kind: dict = dataclasses.field(
        default_factory=lambda: dict.fromkeys(KINDS, 0))
    counts: dict = dataclasses.field(
        default_factory=lambda: dict.fromkeys(KINDS, 0))

    def record(self, kind: str, nbytes: int) -> None:
        self.bytes_by_kind[kind] += int(nbytes)
        self.counts[kind] += 1

    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def as_dict(self) -> dict:
        return {"bytes": dict(self.bytes_by_kind),
                "counts": dict(self.counts),
                "total_bytes": self.total_bytes()}


class _State(threading.local):
    def __init__(self):
        self.stack: list[Ledger] = []


_STATE = _State()


@contextlib.contextmanager
def ledger():
    """Collect byte counts from every wrapper traced inside the block."""
    led = Ledger()
    _STATE.stack.append(led)
    try:
        yield led
    finally:
        _STATE.stack.pop()


def _nbytes(x) -> int:
    shape = getattr(x, "shape", ())
    dtype = getattr(x, "dtype", None)
    itemsize = np.dtype(dtype).itemsize if dtype is not None else 4
    n = 1
    for d in shape:
        n *= int(d)
    return n * itemsize


def _record(kind: str, nbytes: int) -> None:
    if _STATE.stack:
        _STATE.stack[-1].record(kind, nbytes)


def _axis_size(axis_name: str) -> int | None:
    """Static size of a shard_map/pmap axis at trace time, if resolvable."""
    try:
        return int(jax.lax.psum(1, axis_name))
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Plain wrappers (byte-accounted)
# ---------------------------------------------------------------------------

def psum(x: jax.Array, axis_name: str) -> jax.Array:
    _record("psum", _nbytes(x))
    return jax.lax.psum(x, axis_name)


def pmean(x: jax.Array, axis_name: str) -> jax.Array:
    _record("psum", _nbytes(x))
    return jax.lax.pmean(x, axis_name)


def all_to_all(x: jax.Array, axis_name: str, split_axis: int,
               concat_axis: int, *, tiled: bool = True) -> jax.Array:
    _record("all-to-all", _nbytes(x))
    return jax.lax.all_to_all(x, axis_name, split_axis, concat_axis,
                              tiled=tiled)


def all_gather(x: jax.Array, axis_name: str, *, axis: int = 0,
               tiled: bool = False) -> jax.Array:
    d = _axis_size(axis_name) or 1
    _record("all-gather", _nbytes(x) * d)
    return jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


def ppermute(x: jax.Array, axis_name: str, perm) -> jax.Array:
    _record("ppermute", _nbytes(x))
    return jax.lax.ppermute(x, axis_name, perm)


# ---------------------------------------------------------------------------
# Error-feedback compressed psum
# ---------------------------------------------------------------------------

def _quantize_leaf(c: jax.Array):
    """(int8 payload, f32 scale) with one scale per leaf."""
    scale = jnp.maximum(jnp.max(jnp.abs(c)), jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(c / scale * 127.0), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * (scale / 127.0)


def compressed_psum_leaf(grad: jax.Array, err: jax.Array, axis_name: str
                         ) -> tuple[jax.Array, jax.Array]:
    """Mean-reduce one gradient leaf across ``axis_name`` in int8.

    Must be called inside ``shard_map``/``pmap``. Returns
    ``(mean_across_axis, new_residual)``; the caller carries the residual
    into the next call's ``err``. The reduced mean is identical on every
    device; the residual is device-local.
    """
    compensated = grad.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize_leaf(compensated)
    deq = _dequantize_leaf(q, scale)
    new_err = compensated - deq

    d = _axis_size(axis_name)
    # Wire format: int8 payload + one f32 scale per device.
    _record("compressed-psum", (_nbytes(q) + 4) * (d or 1))
    qs = jax.lax.all_gather(q, axis_name)            # (D, *leaf)
    scales = jax.lax.all_gather(scale, axis_name)    # (D,)
    bshape = (scales.shape[0],) + (1,) * grad.ndim
    deq_all = qs.astype(jnp.float32) * (scales.reshape(bshape) / 127.0)
    red = jnp.mean(deq_all, axis=0).astype(grad.dtype)
    return red, new_err.astype(grad.dtype)


def compressed_psum(grads, errs, axis_name: str):
    """Tree-mapped ``compressed_psum_leaf`` over a gradient pytree."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out = [compressed_psum_leaf(g, e, axis_name)
           for g, e in zip(flat_g, flat_e)]
    red = treedef.unflatten([r for r, _ in out])
    new_err = treedef.unflatten([e for _, e in out])
    return red, new_err


def zeros_like_errs(grads):
    """Initial (all-zero) error-feedback residual tree for ``grads``."""
    return jax.tree.map(jnp.zeros_like, grads)
