"""Logical-axis sharding: ``constrain`` + the logical->mesh rules table.

Model and kernel code annotates activations with *logical* axis names
("batch", "model", "sp", ...); a registerable rules table (MaxText-style)
maps each logical name to one or more *mesh* axes, and ``constrain`` turns
the result into ``with_sharding_constraint`` against the ambient mesh.

Outside any mesh context — single-device tests, CPU smoke training,
``launch/dryrun.py`` helpers before the mesh is entered — ``constrain``
validates its arguments and returns the array unchanged, so annotated code
runs everywhere.

Mesh axes named by a rule that are absent from the ambient mesh, or that do
not divide the corresponding dimension, are dropped (same sanitization
contract as ``launch.specs``): a rule like ``batch -> (pod, data)`` works
on single-pod and multi-pod meshes alike.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: Default logical->mesh rules. ``batch`` spans the pure-data axes (FSDP and
#: the paper-§6 batch-over-arrays dimension both extend over (pod, data));
#: ``sp`` (sequence parallel) reuses the tensor axis, as do experts/vocab.
_DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "data": ("data",),
    "pod": ("pod",),
    "model": ("model",),
    "sp": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
}


class _State(threading.local):
    def __init__(self):
        self.mesh_stack: list[Mesh] = []
        self.rules: dict[str, tuple[str, ...]] = dict(_DEFAULT_RULES)


_STATE = _State()


# ---------------------------------------------------------------------------
# Rules table
# ---------------------------------------------------------------------------

def register_rule(logical: str, *mesh_axes: str) -> None:
    """Add or override one logical->mesh rule (process-wide for this thread).

    ``register_rule("expert", "data", "model")`` shards the expert dimension
    over both axes; ``register_rule("sp")`` makes "sp" a no-op.
    """
    if not isinstance(logical, str) or not logical:
        raise ValueError(f"logical axis must be a non-empty str: {logical!r}")
    for a in mesh_axes:
        if not isinstance(a, str):
            raise ValueError(f"mesh axes must be strs: {mesh_axes!r}")
    _STATE.rules[logical] = tuple(mesh_axes)


def current_rules() -> dict[str, tuple[str, ...]]:
    """Snapshot of the active logical->mesh rules table."""
    return dict(_STATE.rules)


@contextlib.contextmanager
def axis_rules(rules: dict[str, tuple[str, ...]], *, extend: bool = True):
    """Temporarily override the rules table (extend=False replaces it)."""
    saved = _STATE.rules
    merged = {**saved, **rules} if extend else dict(rules)
    _STATE.rules = {k: tuple(v) for k, v in merged.items()}
    try:
        yield current_rules()
    finally:
        _STATE.rules = saved


def reset_rules() -> None:
    """Restore the built-in default rules table."""
    _STATE.rules = dict(_DEFAULT_RULES)


# ---------------------------------------------------------------------------
# Ambient mesh
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Make ``mesh`` the ambient mesh ``constrain`` resolves against."""
    _STATE.mesh_stack.append(mesh)
    try:
        yield mesh
    finally:
        _STATE.mesh_stack.pop()


def current_mesh() -> Mesh | None:
    """The ambient mesh: ``use_mesh`` stack, else jax's own mesh context."""
    if _STATE.mesh_stack:
        return _STATE.mesh_stack[-1]
    # New jax: a concrete mesh activated by jax.set_mesh.
    get_mesh = getattr(jax.sharding, "get_mesh", None)
    if get_mesh is not None:
        try:
            m = get_mesh()
            if isinstance(m, Mesh) and not m.empty:
                return m
        except Exception:
            pass
    # Old jax: the legacy resource env filled by ``with mesh:``.
    try:
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes that don't exist in ``mesh`` or don't divide the dim.

    For tuple entries the longest dividing prefix of present axes is kept,
    so ``("pod", "data")`` degrades gracefully on a single-pod mesh.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept: list[str] = []
        prod = 1
        for a in axes:
            if a not in mesh.shape:
                continue
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
            else:
                break
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def _validate(logical_axes: tuple, rules: dict) -> None:
    for a in logical_axes:
        if a is None:
            continue
        if not isinstance(a, str):
            raise ValueError(
                f"logical axis must be a str or None, got {a!r}")
        if a not in rules:
            raise ValueError(
                f"unknown logical axis {a!r}; known: {sorted(rules)} "
                f"(register_rule() to add)")


def logical_to_spec(logical_axes: tuple, shape: tuple,
                    mesh: Mesh) -> P:
    """Resolve logical names through the rules table into a sanitized
    ``PartitionSpec`` for an array of ``shape`` on ``mesh``."""
    rules = _STATE.rules
    _validate(tuple(logical_axes), rules)
    if len(logical_axes) != len(shape):
        raise ValueError(
            f"{len(logical_axes)} logical axes for rank-{len(shape)} array")
    raw = []
    for a in logical_axes:
        if a is None:
            raw.append(None)
            continue
        mesh_axes = rules[a]
        if len(mesh_axes) == 0:
            raw.append(None)
        elif len(mesh_axes) == 1:
            raw.append(mesh_axes[0])
        else:
            raw.append(mesh_axes)
    return sanitize_spec(P(*raw), tuple(shape), mesh)


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names, one per dimension.

    ``constrain(x, "batch", None, "model")`` shards dim 0 over the mesh axes
    the "batch" rule names and dim 2 over the "model" rule's axes. A no-op
    (after validation) outside any mesh context or on a 1-device mesh.
    """
    _validate(logical_axes, _STATE.rules)
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"constrain got {len(logical_axes)} logical axes for a rank-"
            f"{x.ndim} array (shape {x.shape})")
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
