"""Distribution subsystem: logical-axis sharding, byte-accounted
collectives, and the crossbar-batch scheduler.

Layout (see docs/distributed.md):
  compat      version shims over jax mesh / shard_map API drift
  sharding    ``constrain`` + the registerable logical->mesh axis-rules table
  collectives error-feedback compressed psum, byte-ledger wrappers
  batching    paper-§6 batch-over-arrays scheduling on crossbars and meshes
"""
from repro.dist import batching, collectives, compat, sharding  # noqa: F401
