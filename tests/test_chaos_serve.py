"""Chaos tier: the verified serve engine under injected faults
(docs/fault_tolerance.md).

The pin this file owns: under seeded stuck-at / bit-flip / dead-array
injection, a mixed-op engine run serves EVERY request with a result that
matches the fault-free oracle — corruption is always detected by the ABFT
gate, recovery is bounded (retry cap, then the circuit breaker re-binds
the bucket onto the XLA backend and quarantines the array), and no
corrupted batch is ever delivered. Plus the robustness satellites:
per-request deadlines, non-finite rejection at submit, request_stop
racing a blocked submit, and checked/atomic checkpoint manifests.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core.pim import FaultModel
from repro.ft import checkpoint as ckpt_lib
from repro.launch.engine import EngineStopped, ServeEngine
from repro.launch.ops import OpConfigError

N = 128


def _chaos_model(**kw):
    defaults = dict(seed=1, stuck_per_array=1, n_arrays=4, spares=4)
    defaults.update(kw)
    return FaultModel(**defaults)


def _run_verified(engine, combos, rng, per_bucket=6):
    """Submit per_bucket requests to each (op, n), run, and oracle-verify
    EVERY delivered result (the zero-incorrect-results half of the pin)."""
    kept = {}
    already = engine._served   # run() targets the absolute served count
    for op, n in combos:
        bound = engine.register(op, n)
        for _ in range(per_bucket):
            payload = bound.random_payload(rng)
            kept[engine.submit(op, n, payload)] = (op, n, payload)
    stats = engine.run(already + len(kept))
    for rid, (op, n, payload) in kept.items():
        assert rid in engine.results
        engine.bound(op, n).verify(payload, engine.results[rid])
    return stats


# ---------------------------------------------------------------------------
# The chaos pin: mixed ops, permanent faults, every result correct
# ---------------------------------------------------------------------------

def test_chaos_mixed_ops_all_results_match_oracles(rng):
    """fft + polymul-real + polymul-mod (RNS) under permanent stuck-cell
    faults on every array: detection -> bounded retries -> breaker ->
    clean re-execution; every delivered result matches the registry's
    fault-free numpy oracle bit-for-bit (mod) / within float tol."""
    fm = _chaos_model(bitflip_per_gate=1e-4)
    engine = ServeEngine(max_batch=4, auto=True, modulus_bits=60,
                         verified=True, fault_model=fm,
                         collect_timeout_s=0.01)
    combos = [("fft", N), ("polymul-real", N), ("polymul-mod", N)]
    assert engine.bound("polymul-mod", N).rns is not None  # RNS route
    stats = _run_verified(engine, combos, rng)
    total = {k: sum(b["integrity"][k] for b in stats["buckets"].values())
             for k in ("checked", "corrupted", "retried", "fell_back")}
    # permanent faults: every bucket detects, exhausts retries, trips
    assert total["corrupted"] >= len(combos)
    assert total["retried"] >= len(combos)
    assert total["fell_back"] == len(combos)
    for key, b in stats["buckets"].items():
        assert b["integrity"]["breaker_open"], key
    assert len(fm.quarantined) == len(combos)


def test_chaos_single_limb_mod(rng):
    fm = _chaos_model()
    engine = ServeEngine(max_batch=4, auto=True, verified=True,
                         fault_model=fm, collect_timeout_s=0.01)
    assert engine.bound("polymul-mod", N).rns is None   # single-limb route
    stats = _run_verified(engine, [("polymul-mod", N)], rng)
    b = stats["buckets"][f"polymul-mod/n={N}"]["integrity"]
    assert b["corrupted"] >= 1 and b["fell_back"] == 1 and b["breaker_open"]


def test_permanent_fault_pins_breaker_to_xla(rng):
    """Forced dead array: after the breaker the bucket's re-bound plan has
    the PIM backend marked infeasible by the quarantine reason — the
    fallback is pinned to XLA, not re-planned onto the faulty array."""
    fm = FaultModel(seed=5, dead_arrays=(0,), n_arrays=2, spares=1)
    engine = ServeEngine(max_batch=4, auto=True, verified=True,
                         fault_model=fm, collect_timeout_s=0.01)
    _run_verified(engine, [("fft", N)], rng, per_bucket=4)
    assert fm.is_quarantined(0)
    rebound = engine.bound("fft", N)
    best = rebound.plan.cost["best"]
    assert best["backend_best"] == "xla"
    assert "quarantined" in best["backends"]["pim"]["infeasible"]
    # breaker is sticky: later batches serve cleanly on the re-bound op
    stats = _run_verified(engine, [("fft", N)], rng, per_bucket=3)
    b = stats["buckets"][f"fft/n={N}"]["integrity"]
    assert b["breaker_open"] and b["fell_back"] == 1


def test_fault_model_requires_verified():
    with pytest.raises(ValueError, match="verified"):
        ServeEngine(fault_model=_chaos_model())


def test_clean_verified_run_counts_checks_only(rng):
    engine = ServeEngine(max_batch=4, auto=True, verified=True,
                         collect_timeout_s=0.01)
    stats = _run_verified(engine, [("fft", N), ("polymul", N)], rng)
    for b in stats["buckets"].values():
        integ = b["integrity"]
        assert integ["checked"] >= 1
        assert integ["corrupted"] == integ["retried"] == 0
        assert integ["fell_back"] == 0 and not integ["breaker_open"]


def test_unverified_stats_report_zero_integrity(rng):
    engine = ServeEngine(max_batch=4, auto=True, collect_timeout_s=0.01)
    stats = _run_verified(engine, [("fft", N)], rng, per_bucket=2)
    integ = stats["buckets"][f"fft/n={N}"]["integrity"]
    assert integ == {"checked": 0, "corrupted": 0, "retried": 0,
                     "fell_back": 0, "breaker_open": False}


def test_verified_survives_snapshot_roundtrip(tmp_path, rng):
    d = str(tmp_path / "snap")
    engine = ServeEngine(max_batch=4, auto=True, verified=True,
                         collect_timeout_s=0.01)
    _run_verified(engine, [("fft", N)], rng, per_bucket=2)
    engine.snapshot(d)
    restored = ServeEngine.from_snapshot(d)
    assert restored.verified and restored.ctx.verified


# ---------------------------------------------------------------------------
# Satellites: deadlines, non-finite rejection, stop race
# ---------------------------------------------------------------------------

def test_deadline_expired_request_gets_structured_error(rng):
    engine = ServeEngine(max_batch=4, auto=True, collect_timeout_s=0.01)
    bound = engine.register("fft", N)
    rid_ok = engine.submit("fft", N, bound.random_payload(rng))
    rid_exp = engine.submit("fft", N, bound.random_payload(rng),
                            deadline_s=1e-4)
    time.sleep(0.01)    # both expire-eligible before the loop starts
    stats = engine.run(2)
    assert stats["expired"] == 1
    assert stats["buckets"][f"fft/n={N}"]["expired"] == 1
    err = engine.errors[rid_exp]
    assert err["error"] == "deadline_exceeded"
    assert err["op"] == "fft" and err["n"] == N and err["waited_s"] > 0
    assert rid_exp not in engine.results and rid_ok in engine.results
    # expired requests never enter the latency record: p99 describes
    # delivered results only
    assert len(engine._latencies_s) == 1
    with pytest.raises(ValueError):
        engine.submit("fft", N, bound.random_payload(rng), deadline_s=0)


def test_nonfinite_payload_rejected_at_submit(rng):
    engine = ServeEngine(max_batch=4, auto=True, collect_timeout_s=0.01)
    bad = np.zeros(N, np.complex64)
    bad[3] = np.nan
    with pytest.raises(OpConfigError, match="non-finite"):
        engine.submit("fft", N, bad)
    a = np.zeros(N, np.float32)
    b = np.zeros(N, np.float32)
    b[0] = np.inf
    with pytest.raises(OpConfigError, match="operand 1"):
        engine.submit("polymul-real", N, (a, b))
    # integer/object payloads have no NaN to carry: admitted untouched
    engine.register("polymul-mod", N)
    p = engine.bound("polymul-mod", N).random_payload(rng)
    engine.submit("polymul-mod", N, p)
    engine.run(1)


def test_request_stop_unblocks_waiting_submit(rng):
    """A submit blocked on a FULL queue must raise EngineStopped promptly
    when request_stop lands — not wait out its backpressure timeout."""
    engine = ServeEngine(max_batch=4, max_pending=1, auto=True,
                         collect_timeout_s=0.01)
    bound = engine.register("fft", N)
    engine.submit("fft", N, bound.random_payload(rng))   # fills the queue
    outcome: list = []

    def blocked_submit():
        try:
            engine.submit("fft", N, bound.random_payload(rng))
            outcome.append("admitted")
        except EngineStopped:
            outcome.append("stopped")

    th = threading.Thread(target=blocked_submit, daemon=True)
    th.start()
    time.sleep(0.15)                 # let it reach the cv.wait loop
    assert th.is_alive()             # genuinely blocked on backpressure
    t0 = time.perf_counter()
    engine.request_stop()
    th.join(timeout=5.0)
    assert not th.is_alive() and outcome == ["stopped"]
    assert time.perf_counter() - t0 < 1.0, "stop must interrupt promptly"
    engine.run(1)                    # drain the admitted request


# ---------------------------------------------------------------------------
# Satellite: checked, durable checkpoint manifests
# ---------------------------------------------------------------------------

def test_checkpoint_refuses_truncated_manifest(tmp_path):
    d = str(tmp_path / "ck")
    ckpt_lib.save(d, 3, {"w": np.arange(4.0)}, extra={"k": 1})
    man = os.path.join(d, "step_3", "manifest.json")
    with open(man, "rb") as f:
        raw = f.read()
    with open(man, "wb") as f:
        f.write(raw[:len(raw) // 2])        # torn write
    with pytest.raises(ckpt_lib.CheckpointCorruptError, match="truncated"):
        ckpt_lib.read_manifest(d, 3)
    with pytest.raises(ckpt_lib.CheckpointCorruptError):
        ckpt_lib.restore(d, 3, {"w": np.zeros(4)})


def test_checkpoint_refuses_partial_manifest(tmp_path):
    d = str(tmp_path / "ck")
    ckpt_lib.save(d, 1, {"w": np.arange(4.0)})
    man = os.path.join(d, "step_1", "manifest.json")
    with open(man, "w") as f:
        json.dump({"extra": {}}, f)         # parses, but missing keys
    with pytest.raises(ckpt_lib.CheckpointCorruptError, match="missing"):
        ckpt_lib.read_manifest(d, 1)


def test_checkpoint_save_publishes_manifest_last_and_clean(tmp_path):
    d = str(tmp_path / "ck")
    path = ckpt_lib.save(d, 2, {"w": np.arange(8.0)}, extra={"s": "x"})
    # no .part residue: every file landed via its atomic rename
    assert not [f for f in os.listdir(path) if f.endswith(".part")]
    assert sorted(os.listdir(path)) == ["arrays.npz", "manifest.json"]
    man = ckpt_lib.read_manifest(d, 2)
    assert man["step"] == 2 and man["extra"] == {"s": "x"}
    _, tree = ckpt_lib.restore_latest(d, {"w": np.zeros(8)})
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(8.0))
