"""Exact-NTT tier: reference properties, modulus selection, and
Pallas-kernel-vs-reference BIT-EXACT equality (the crypto contract — a
single wrong residue breaks an RLWE pipeline, so every comparison here is
``==``, never allclose)."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.ntt import ref
from repro.kernels import ntt as kntt


def _params(n, bits=30):
    return ref.NTTParams.make(n, bits=bits)


def _naive_negacyclic(a, b, q):
    """Independent pure-python O(n^2) oracle (no numpy, no roots)."""
    n = len(a)
    out = [0] * n
    for i in range(n):
        for j in range(n):
            k = i + j
            t = int(a[i]) * int(b[j]) % q
            if k < n:
                out[k] = (out[k] + t) % q
            else:
                out[k - n] = (out[k - n] - t) % q
    return np.array(out, np.uint64)


# ---------------------------------------------------------------------------
# Modulus / root selection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 1024, 4096])
def test_modulus_selection_rules(n):
    q = ref.choose_modulus(n)
    assert ref.is_prime(q)
    assert q % (2 * n) == 1          # 2n-th roots of unity exist
    assert q < 1 << 31               # single uint32 word, 2q < 2^32
    p = _params(n)
    assert p.q == q
    # w is a primitive n-th root, psi a primitive 2n-th root with psi^2 = w
    assert pow(p.w, n, q) == 1 and pow(p.w, n // 2, q) != 1
    assert p.psi * p.psi % q == p.w
    assert pow(p.psi, n, q) == q - 1          # psi^n = -1: the negacyclic sign
    assert p.n_inv * n % q == 1
    assert (p.qinv * q) % (1 << 32) == (1 << 32) - 1   # -q^-1 mod 2^32


def test_param_validation_raises():
    with pytest.raises(ValueError):
        ref.NTTParams.make(48)                 # non-power-of-two
    with pytest.raises(ValueError):
        ref.NTTParams.make(256, q=257)         # 257 != 1 mod 512
    with pytest.raises(ValueError):
        ref.NTTParams.make(256, q=3 * 2048 + 1)  # 6145 = 5*1229, composite
    with pytest.raises(TypeError):
        ref.ntt(np.ones(256, np.float32), _params(256))   # floats rejected


# ---------------------------------------------------------------------------
# Reference properties (hypothesis, via tests/_hypothesis_fallback.py when
# the real library is absent)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([256, 512, 1024]),
       bits=st.sampled_from([20, 24, 30]),
       seed=st.integers(0, 2**31 - 1))
def test_ref_roundtrip_property(n, bits, seed):
    """intt(ntt(x)) == x over random moduli and sizes, exactly."""
    p = _params(n, bits=bits)
    r = np.random.default_rng(seed)
    x = r.integers(0, p.q, size=(2, n))
    assert (ref.intt(ref.ntt(x, p), p) == x.astype(np.uint64)).all()


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([64, 128]), seed=st.integers(0, 2**31 - 1))
def test_ref_negacyclic_vs_schoolbook_property(n, seed):
    p = _params(n)
    r = np.random.default_rng(seed)
    a = r.integers(0, p.q, size=n)
    b = r.integers(0, p.q, size=n)
    want = ref.schoolbook_polymul(a, b, p.q, negacyclic=True)
    assert (ref.negacyclic_polymul(a, b, p) == want).all()


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([64, 128]), seed=st.integers(0, 2**31 - 1))
def test_ref_cyclic_vs_schoolbook_property(n, seed):
    p = _params(n)
    r = np.random.default_rng(seed)
    a = r.integers(0, p.q, size=n)
    b = r.integers(0, p.q, size=n)
    want = ref.schoolbook_polymul(a, b, p.q, negacyclic=False)
    assert (ref.cyclic_polymul(a, b, p) == want).all()


def test_ref_linearity_mod_q(rng):
    """NTT is F_q-linear: ntt(c1 a + c2 b) == c1 ntt(a) + c2 ntt(b)."""
    n = 256
    p = _params(n)
    q = np.uint64(p.q)
    a = rng.integers(0, p.q, size=n)
    b = rng.integers(0, p.q, size=n)
    c1, c2 = np.uint64(17), np.uint64(3001)
    lhs = ref.ntt((c1 * a.astype(np.uint64) + c2 * b.astype(np.uint64)) % q, p)
    rhs = (c1 * ref.ntt(a, p) + c2 * ref.ntt(b, p)) % q
    assert (lhs == rhs).all()


def test_schoolbook_sign_wraparound():
    """x^(n-1) * x = x^n = -1 mod x^n+1 (+1 in the cyclic ring)."""
    n = 8
    p = _params(n)
    a = np.zeros(n, np.uint64)
    b = np.zeros(n, np.uint64)
    a[n - 1] = 1
    b[1] = 1
    nega = ref.negacyclic_polymul(a, b, p)
    assert nega[0] == p.q - 1 and (nega[1:] == 0).all()
    cyc = ref.cyclic_polymul(a, b, p)
    assert cyc[0] == 1 and (cyc[1:] == 0).all()


def test_naive_oracle_agrees_with_schoolbook(rng):
    n = 32
    p = _params(n)
    a = rng.integers(0, p.q, size=n)
    b = rng.integers(0, p.q, size=n)
    assert (ref.schoolbook_polymul(a, b, p.q, negacyclic=True)
            == _naive_negacyclic(a, b, p.q)).all()


# ---------------------------------------------------------------------------
# Pallas kernel vs reference: bit-exact, n in {256..4096}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("inverse", [False, True])
def test_kernel_matches_ref_exactly(rng, n, inverse):
    p = _params(n)
    x = rng.integers(0, p.q, size=(3, n)).astype(np.uint32)
    got = np.asarray(kntt.ntt_batched(jnp.asarray(x), p, inverse=inverse))
    want = (ref.intt if inverse else ref.ntt)(x, p)
    assert (got == want.astype(np.uint32)).all()


@pytest.mark.parametrize("n", [256, 1024, 4096])
def test_kernel_roundtrip_exact(rng, n):
    p = _params(n)
    x = rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
    f = kntt.ntt_batched(jnp.asarray(x), p)
    back = np.asarray(kntt.ntt_batched(f, p, inverse=True))
    assert (back == x).all()


@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("negacyclic", [True, False])
def test_kernel_polymul_matches_ref_exactly(rng, n, negacyclic):
    p = _params(n)
    a = rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
    b = rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
    got = np.asarray(kntt.ntt_polymul(jnp.asarray(a), jnp.asarray(b), p,
                                      negacyclic=negacyclic))
    fn = ref.negacyclic_polymul if negacyclic else ref.cyclic_polymul
    assert (got == fn(a, b, p).astype(np.uint32)).all()


def test_kernel_polymul_matches_schoolbook(rng):
    """End to end vs the O(n^2) oracle — no transform code shared at all."""
    n = 256
    p = _params(n)
    a = rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
    b = rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
    got = np.asarray(kntt.ntt_polymul(jnp.asarray(a), jnp.asarray(b), p))
    want = ref.schoolbook_polymul(a, b, p.q, negacyclic=True)
    assert (got == want.astype(np.uint32)).all()


def test_kernel_nondivisible_batch(rng):
    """Batch not a multiple of the block: wrapper pads and strips."""
    n = 256
    p = _params(n)
    x = rng.integers(0, p.q, size=(5, n)).astype(np.uint32)
    got = np.asarray(kntt.ntt_batched(jnp.asarray(x), p, block_b=4))
    assert (got == ref.ntt(x, p).astype(np.uint32)).all()


def test_kernel_rejects_float_input():
    p = _params(256)
    with pytest.raises(TypeError):
        kntt.ntt_batched(jnp.zeros((2, 256), jnp.float32), p)


def test_kernel_reduces_unreduced_input(rng):
    """Signed / >= q integer coefficients must reduce mod q, matching the
    reference — not wrap through uint32 (regression: the kernel once cast
    without reducing, silently corrupting unreduced RLWE input)."""
    n = 256
    p = _params(n)
    signed = rng.integers(-(p.q - 1), p.q, size=(2, n)).astype(np.int32)
    got = np.asarray(kntt.ntt_batched(jnp.asarray(signed), p))
    assert (got == ref.ntt(signed, p).astype(np.uint32)).all()
    big = (rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
           + np.uint32(p.q))          # in [q, 2q): valid uint32, unreduced
    got_big = np.asarray(kntt.ntt_batched(jnp.asarray(big), p))
    assert (got_big == ref.ntt(big, p).astype(np.uint32)).all()


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([64, 256]), seed=st.integers(0, 2**31 - 1))
def test_kernel_equals_ref_property(n, seed):
    p = _params(n)
    r = np.random.default_rng(seed)
    x = r.integers(0, p.q, size=(2, n)).astype(np.uint32)
    got = np.asarray(kntt.ntt_batched(jnp.asarray(x), p))
    assert (got == ref.ntt(x, p).astype(np.uint32)).all()
