"""Serve-engine tier: op-dispatch registry + continuous batching.

Unit half:
  * the registry (``launch/ops.py``) is the ONLY dispatch surface — the
    serve module carries no per-op ladder, and its CLI choices/help derive
    from the registry;
  * registry parity: every op served through the engine bit-matches the
    direct BoundOp call on the same payloads (the old single-op path);
  * mixed-op / mixed-n bucketing correctness against the numpy oracles;
  * tail batches execute at their ACTUAL size (never padded to the block);
  * latency percentiles are monotone (p50 <= p90 <= p99 <= max);
  * bounded-queue admission raises Backpressure when full;
  * registry validation errors exit the CLI cleanly (argparse error).

Dist half (subprocess, 8 virtual devices):
  * odd-batch distributed real tier pinned vs numpy (the ROADMAP
    leftover: internal pad + slice instead of the even-batch guard);
  * a mixed stream including both distributed routes served from one
    engine process.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_in_subprocess_devices
from repro.launch import ops as op_registry
from repro.launch import serve
from repro.launch.engine import Backpressure, ServeEngine


# ---------------------------------------------------------------------------
# Registry is the one source of op truth
# ---------------------------------------------------------------------------

def test_registry_covers_all_ops_and_serve_has_no_ladder():
    names = op_registry.op_names()
    assert set(names) == {"fft", "rfft", "polymul", "polymul-real",
                          "polymul-mod"}
    # PR 10 promoted the old string grep ("elif op ==" in serve's source,
    # dodgeable by renaming the variable) to the AST dispatch-ladder lint
    # rule: the whole launch/ package must carry ZERO op-name string
    # ladders outside the ops.py registry (docs/static_analysis.md).
    from repro import analysis
    launch_dir = os.path.dirname(op_registry.__file__)
    res = analysis.analyze_paths([launch_dir])
    ladders = [f for f in res.findings if f.rule == "dispatch-ladder"]
    assert ladders == [], \
        "serve must dispatch through the registry, not a per-op ladder:\n" \
        + "\n".join(f.format() for f in ladders)
    # CLI surface derives from the registry
    help_text = op_registry.cli_help()
    for name in names:
        assert name in help_text
    assert set(op_registry.ops_using("modulus_bits")) == {"polymul-mod"}
    assert set(op_registry.ops_using("model_shards")) == {"polymul-real",
                                                          "polymul-mod"}
    for spec in op_registry.registry():
        assert spec.summary and spec.arity in (1, 2)


def test_registry_rejects_unknown_op_and_foreign_knobs():
    with pytest.raises(op_registry.OpConfigError):
        op_registry.get_op("polymul-imaginary")
    for op, ctx in (("fft", op_registry.OpContext(modulus_bits=40)),
                    ("rfft", op_registry.OpContext(model_shards=4)),
                    ("polymul", op_registry.OpContext(model_shards=2))):
        with pytest.raises(op_registry.OpConfigError):
            op_registry.get_op(op).bind(64, ctx)
    # narrow() strips exactly those knobs, so the mixed engine can feed one
    # process-level context to every op
    ctx = op_registry.OpContext(modulus_bits=100, model_shards=8)
    assert op_registry.get_op("fft").narrow(ctx) == op_registry.OpContext()
    assert op_registry.get_op("polymul-real").narrow(ctx) == \
        op_registry.OpContext(model_shards=8)
    assert op_registry.get_op("polymul-mod").narrow(ctx) == ctx


def test_registry_rns_plus_shards_is_a_config_error():
    with pytest.raises(op_registry.OpConfigError, match="single-limb"):
        op_registry.get_op("polymul-mod").bind(
            1024, op_registry.OpContext(modulus_bits=100, model_shards=8))


# ---------------------------------------------------------------------------
# Registry parity: engine == direct BoundOp call, per op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op,kw", [
    ("fft", {}),
    ("rfft", {}),
    ("polymul", {}),
    ("polymul-real", {}),
    ("polymul-mod", {}),
    ("polymul-mod", {"modulus_bits": 100}),
])
def test_engine_parity_with_direct_dispatch(op, kw, rng):
    """Each op served through the continuous-batching engine bit-matches
    the direct BoundOp batch call on the same payloads (batches [4, 2]:
    the tail exercises actual-size dispatch)."""
    n, cap, total = 64, 4, 6
    svc = serve.FFTService(n, cap, op, **kw)
    payloads = [svc.bound.random_payload(rng) for _ in range(total)]
    for rid, p in enumerate(payloads):
        svc.submit(rid, p)
    stats = svc.run(total)
    assert stats["served"] == total
    sizes = stats["buckets"][f"{op}/n={n}"]["batch_sizes"]
    assert sizes == [4, 2], sizes
    # direct dispatch at the SAME batch boundaries the scheduler used
    direct = [svc.bound.to_numpy(svc.bound.execute(payloads[:4])),
              svc.bound.to_numpy(svc.bound.execute(payloads[4:]))]
    flat = [row for arr in direct for row in arr]
    for rid in range(total):
        got, want = svc.results[rid], flat[rid]
        if got.dtype == object or np.issubdtype(got.dtype, np.integer):
            assert (got == want).all(), f"rid={rid}"
        else:
            np.testing.assert_array_equal(got, want)
        svc.bound.verify(payloads[rid], got)


# ---------------------------------------------------------------------------
# Mixed-op / mixed-n bucketing
# ---------------------------------------------------------------------------

def test_mixed_stream_bucketing_correctness(rng):
    """One engine, 3 ops x 2 lengths interleaved: every request lands in
    its shape bucket and every served result passes its op's oracle."""
    ops = ("fft", "rfft", "polymul-real")
    lens = (64, 128)
    engine = ServeEngine(max_batch=4, max_pending=64)
    combos = [(op, n) for op in ops for n in lens]
    for op, n in combos:
        engine.register(op, n)
    engine.warmup()
    kept = {}
    total = 18
    for rid in range(total):
        op, n = combos[rid % len(combos)]
        p = engine.bound(op, n).random_payload(rng)
        kept[rid] = (op, n, p)
        engine.submit(op, n, p, rid=rid)
    stats = engine.run(total)
    assert stats["served"] == total
    assert len(stats["buckets"]) == len(combos)
    assert sum(b["served"] for b in stats["buckets"].values()) == total
    for b in stats["buckets"].values():
        assert all(1 <= s <= 4 for s in b["batch_sizes"]), b
        assert 0 < b["utilization"] <= 1.0
    for rid, (op, n, p) in kept.items():
        engine.bound(op, n).verify(p, engine.results[rid])
        # results keep their bucket's shape: no cross-bucket mixups
        width = {"fft": n, "rfft": n // 2 + 1, "polymul-real": n}[op]
        assert engine.results[rid].shape == (width,)


def test_tail_batch_runs_at_actual_size(rng):
    """11 requests through a cap-8 bucket must dispatch as [8, 3] — the
    tail batch executes at 3 rows, never padded to the block."""
    engine = ServeEngine(max_batch=8, max_pending=64)
    engine.register("rfft", 64)
    engine.warmup()
    for rid in range(11):
        engine.submit("rfft", 64,
                      rng.standard_normal(64).astype(np.float32), rid=rid)
    stats = engine.run(11)
    sizes = stats["buckets"]["rfft/n=64"]["batch_sizes"]
    assert sizes == [8, 3], sizes
    assert sum(sizes) == 11 and max(sizes) <= 8
    util = stats["buckets"]["rfft/n=64"]["utilization"]
    assert abs(util - (11 / 16)) < 1e-9


def test_latency_percentiles_monotone(rng):
    engine = ServeEngine(max_batch=4, max_pending=64)
    engine.register("fft", 64)
    engine.warmup()
    for rid in range(10):
        engine.submit(
            "fft", 64,
            (rng.standard_normal(64)
             + 1j * rng.standard_normal(64)).astype(np.complex64), rid=rid)
    stats = engine.run(10)
    lat = stats["latency_ms"]
    assert 0 < lat["p50"] <= lat["p90"] <= lat["p99"] <= lat["max"]
    assert lat["p50"] <= lat["mean"] * 10   # sanity: same order of magnitude
    assert stats["throughput_per_s"] > 0
    assert stats["compute_throughput_per_s"] >= stats["throughput_per_s"]


def test_backpressure_bounded_queue(rng):
    engine = ServeEngine(max_batch=4, max_pending=3)
    engine.register("fft", 64)
    x = (rng.standard_normal(64) + 0j).astype(np.complex64)
    for rid in range(3):
        engine.submit("fft", 64, x, rid=rid)
    with pytest.raises(Backpressure):
        engine.submit("fft", 64, x, rid=99, block=False)
    with pytest.raises(Backpressure):
        engine.submit("fft", 64, x, rid=99, timeout=0.05)
    stats = engine.run(3)         # draining frees the queue again
    assert stats["served"] == 3
    engine.submit("fft", 64, x, rid=3, block=False)
    assert engine.run(4)["served"] == 4


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

def test_cli_engine_service_mixed_stream():
    stats = serve.main(["--service", "engine",
                        "--ops", "fft,rfft,polymul-real",
                        "--ns", "64,128", "--requests", "12",
                        "--batch", "4"])
    assert stats["served"] == 12
    assert len(stats["buckets"]) == 6
    lat = stats["latency_ms"]
    assert lat["p50"] <= lat["p99"]


def test_cli_exits_with_registry_validation_error(capsys):
    for argv in (["--op", "polymul-mod", "--modulus-bits", "100",
                  "--model-shards", "8"],
                 ["--op", "fft", "--modulus-bits", "40"],
                 ["--service", "engine", "--ops", "fft,nope", "--ns", "64"]):
        with pytest.raises(SystemExit) as exc:
            serve.main(argv)
        assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "single-limb" in err          # the registry's own message


def test_fft_service_legacy_surface(rng):
    """The single-op wrapper keeps the pre-engine surface (plan / route /
    _fn / ntt_params / rns) that callers and older tests assert against."""
    svc = serve.FFTService(64, 2, "polymul-mod")
    assert svc.route == "polymul-mod-single"
    assert svc.plan.exact and svc.ntt_params is not None and svc.rns is None
    rns_svc = serve.FFTService(64, 2, "polymul-mod", modulus_bits=100)
    assert rns_svc.route == "polymul-mod-rns"
    assert rns_svc.rns is not None and rns_svc.rns.k > 1
    a = rng.integers(0, svc.ntt_params.q, (2, 64)).astype(np.uint32)
    b = rng.integers(0, svc.ntt_params.q, (2, 64)).astype(np.uint32)
    out = np.asarray(svc._fn(jnp.asarray(a), jnp.asarray(b)))
    from repro.core.ntt import negacyclic_polymul
    assert (out == negacyclic_polymul(a, b, svc.ntt_params)).all()


# ---------------------------------------------------------------------------
# Dist half: odd-batch distributed real tier + mixed distributed stream
# ---------------------------------------------------------------------------

@pytest.mark.dist
def test_odd_batch_distributed_real_vs_numpy():
    """The distributed real tier serves ODD batches (internal zeros-row
    pad + slice, replacing the even-batch guard) and stays pinned to the
    f64 numpy oracle; rfft/irfft roundtrip at odd B too."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import distributed as dfft
from repro.launch import serve

mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
for B in (1, 3, 5):
    a = rng.standard_normal((B, 1024)).astype(np.float32)
    b = rng.standard_normal((B, 1024)).astype(np.float32)
    got = np.asarray(jax.jit(
        dfft.make_sharded_polymul_real(mesh, batch_axes=()))(a, b))
    want = np.fft.ifft(np.fft.fft(a.astype(np.float64))
                       * np.fft.fft(b.astype(np.float64))).real
    err = np.max(np.abs(got - want))
    assert got.shape == (B, 1024) and err < 1e-3, (B, err)
    x = rng.standard_normal((B, 1024)).astype(np.float32)
    pk = jax.jit(dfft.make_sharded_rfft(mesh, batch_axes=()))(x)
    back = np.asarray(jax.jit(
        dfft.make_sharded_irfft(mesh, batch_axes=()))(pk))
    assert back.shape == (B, 1024)
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4)

# the serve route accepts odd batches end-to-end now
svc = serve.FFTService(1024, 3, "polymul-real", model_shards=8)
assert svc.route == "polymul-real-distributed"
stats = serve.main(["--service", "fft", "--n", "1024", "--batch", "3",
                    "--requests", "7", "--op", "polymul-real",
                    "--model-shards", "8"])
assert stats["served"] == 7, stats
print("OK")
""", n_devices=8)
    assert "OK" in out


@pytest.mark.dist
def test_engine_mixed_stream_with_distributed_routes():
    """One engine process: local fft/rfft buckets next to the distributed
    polymul-real and polymul-mod tiers, all drained with continuous
    batching and verified against their oracles."""
    out = run_in_subprocess_devices("""
from repro.launch import serve

stats = serve.main(["--service", "engine",
                    "--ops", "fft,rfft,polymul-real,polymul-mod",
                    "--ns", "512", "--model-shards", "8",
                    "--requests", "16", "--batch", "4"])
assert stats["served"] == 16, stats
routes = {b["route"] for b in stats["buckets"].values()}
assert "polymul-real-distributed" in routes, routes
assert "polymul-mod-distributed" in routes, routes
assert "fft" in routes and "rfft-real" in routes, routes
lat = stats["latency_ms"]
assert 0 < lat["p50"] <= lat["p99"], lat
print("OK")
""", n_devices=8)
    assert "OK" in out
