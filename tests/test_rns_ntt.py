"""RNS/CRT differential tier: the multi-limb exact-polymul contract.

Everything here is ``==``, never allclose — a single wrong residue breaks
an RLWE/FHE pipeline. Three differential layers pin each other:

  big-int schoolbook (pure python, no transforms, no CRT)
    == rns_polymul_reference (numpy NTT per limb + Garner/CRT)
    == rns_polymul (limb-batched Pallas kernel, ONE launch for all limbs)

plus the CRT algebra itself (round-trip identity, limb-permutation
invariance, uint64 Garner == object-dtype oracle), the planner's exact
distributed route, and the first cross-stack differential: float-FFT
polymul vs exact-NTT polymul on small-coefficient inputs.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import fft as fft_core
from repro.core.ntt import ref, rns


def _rns(n, bits):
    return rns.RNSParams.make(n, modulus_bits=bits)


# ---------------------------------------------------------------------------
# Limb selection rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [40, 100, 120])
def test_limb_selection_rules(bits):
    n = 256
    r = _rns(n, bits)
    # Every limb is a distinct NTT-friendly prime < 2^30 (hence coprime).
    assert len(set(r.qs)) == r.k
    for q in r.qs:
        assert ref.is_prime(q) and q % (2 * n) == 1 and q < 1 << 30
    # Q >= the requested width; the limb product covers the exact-lift bound.
    assert r.modulus.bit_length() >= bits
    assert r.limb_product > 2 * n * r.modulus ** 2
    # Q >= 2^100 needs >= 4 limbs of <= 30 bits — the acceptance floor.
    if bits >= 100:
        assert r.k >= 4


def test_rns_params_validation():
    with pytest.raises(ValueError):
        rns.RNSParams.make(256)                       # neither Q nor bits
    with pytest.raises(ValueError):
        rns.RNSParams.make(256, modulus=97, modulus_bits=40)   # both
    with pytest.raises(ValueError):
        rns.RNSParams.make(255, modulus_bits=40)      # non-power-of-two n
    with pytest.raises(TypeError):
        rns.to_rns(np.ones(8, np.float32), _rns(8, 40))  # floats rejected


# ---------------------------------------------------------------------------
# CRT algebra (hypothesis, deterministic fallback when the lib is absent)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(bits=st.sampled_from([40, 70, 100, 120]),
       seed=st.integers(0, 2**31 - 1))
def test_crt_roundtrip_identity_property(bits, seed):
    """to_rns -> Garner/CRT == identity on [0, M), exactly."""
    n = 64
    r = _rns(n, bits)
    rng = np.random.default_rng(seed)
    x = rns.random_poly(rng, n, r.limb_product)   # full CRT range
    back = rns.crt_reconstruct(rns.to_rns(x, r), r)
    assert (back == x).all()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), perm_seed=st.integers(0, 2**31 - 1))
def test_limb_permutation_invariance_property(seed, perm_seed):
    """CRT reconstruction is invariant under permuting the limb order —
    Garner's mixed-radix digits differ per ordering, the value must not."""
    n = 64
    r = _rns(n, 100)
    rng = np.random.default_rng(seed)
    x = rns.random_poly(rng, n, r.limb_product)
    res = rns.to_rns(x, r)
    perm = np.random.default_rng(perm_seed).permutation(r.k)
    r_perm = dataclasses.replace(r, limbs=tuple(r.limbs[i] for i in perm))
    back = rns.crt_reconstruct(res[perm], r_perm)
    assert (back == x).all()


def test_garner_u64_path_matches_object_oracle(rng):
    """The vectorized uint64 assembly == the python-int path when M < 2^64
    (two 30-bit limbs), and recovers raw uint64 inputs exactly."""
    n = 64
    r = rns.RNSParams.make(n, modulus=65537)      # bound 2^39 -> 2 limbs
    assert r.k == 2 and r.limb_product < 1 << 64
    x = rng.integers(0, r.limb_product, size=(3, n), dtype=np.uint64)
    res = rns.to_rns(x, r)
    u64 = rns.crt_reconstruct_u64(res, r)
    assert (u64 == x).all()
    assert (u64.astype(object) == rns.crt_reconstruct(res, r)).all()
    big = _rns(n, 100)
    with pytest.raises(ValueError):
        rns.crt_reconstruct_u64(rns.to_rns(x, big), big)


def test_centered_lift_recovers_negative_values():
    """crt_to_modulus must treat residue stacks of negative integers as
    negative (centered lift), not as their huge mod-M representatives."""
    n = 8
    r = _rns(n, 60)
    vals = np.array([-5, -1, 0, 1, 7, -(1 << 61), 1 << 61, 3], object)
    out = rns.crt_to_modulus(rns.to_rns(vals, r), r)
    assert (out == np.array([int(v) % r.modulus for v in vals], object)).all()


# ---------------------------------------------------------------------------
# Polymul: schoolbook == reference == fused limb-batched kernel
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(bits=st.sampled_from([60, 100, 120]),
       negacyclic=st.sampled_from([True, False]),
       seed=st.integers(0, 2**31 - 1))
def test_rns_polymul_vs_bigint_schoolbook_property(bits, negacyclic, seed):
    """Kernel product mod Q (up to ~120-bit Q, >= 4 limbs) == the big-int
    O(n^2) oracle — no transforms, no CRT, no numpy shared."""
    n = 64
    r = _rns(n, bits)
    rng = np.random.default_rng(seed)
    a = rns.random_poly(rng, n, r.modulus)
    b = rns.random_poly(rng, n, r.modulus)
    want = rns.schoolbook_polymul_mod(a, b, r.modulus, negacyclic=negacyclic)
    mid = rns.rns_polymul_reference(a, b, r, negacyclic=negacyclic)
    got = rns.rns_polymul(a, b, r, negacyclic=negacyclic)
    assert (mid == want).all()
    assert (got == want).all()


def test_rns_kernel_batched_and_shapes(rng):
    """(B, n) batches through one launch; 1-D convenience shape preserved."""
    n, B = 128, 3
    r = _rns(n, 100)
    a = np.stack([rns.random_poly(rng, n, r.modulus) for _ in range(B)])
    b = np.stack([rns.random_poly(rng, n, r.modulus) for _ in range(B)])
    got = rns.rns_polymul(a, b, r)
    assert got.shape == (B, n)
    for i in range(B):
        want = rns.schoolbook_polymul_mod(a[i], b[i], r.modulus)
        assert (got[i] == want).all()
    one = rns.rns_polymul(a[0], b[0], r)
    assert one.shape == (n,) and (one == got[0]).all()


def test_rns_kernel_single_limb_degenerates_to_ntt_polymul(rng):
    """k == 1 RNS == the plain single-word kernel: same modulus, same
    residues, same launch machinery."""
    from repro.kernels.ntt import ntt_polymul, rns_ntt_polymul
    n = 256
    r = rns.RNSParams.make(n, modulus=17)        # tiny Q: one limb covers it
    assert r.k == 1
    p = r.limbs[0]
    a = rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
    b = rng.integers(0, p.q, size=(2, n)).astype(np.uint32)
    via_rns = np.asarray(rns_ntt_polymul(a[None], b[None], r))[0]
    via_ntt = np.asarray(ntt_polymul(jnp.asarray(a), jnp.asarray(b), p))
    assert (via_rns == via_ntt).all()


@pytest.mark.parametrize("negacyclic", [True, False])
def test_rns_scalar_prefetch_bit_exact(rng, negacyclic):
    """The scalar-prefetch layout (PrefetchScalarGridSpec, per-limb q/qinv/
    r2 resident in SMEM before the body runs — the on-TPU default) is
    bit-identical to the scalar-Ref fallback, and both still match the
    big-int schoolbook oracle. Forced explicitly so interpret mode pins
    BOTH layouts."""
    from repro.kernels.ntt import rns_ntt_polymul
    n, B = 64, 2
    r = _rns(n, 100)
    assert r.k > 1                        # multiple limbs exercise program_id
    ar = np.stack([np.stack([rng.integers(0, p.q, n).astype(np.uint32)
                             for p in r.limbs]) for _ in range(B)], axis=1)
    br = np.stack([np.stack([rng.integers(0, p.q, n).astype(np.uint32)
                             for p in r.limbs]) for _ in range(B)], axis=1)
    fallback = np.asarray(rns_ntt_polymul(
        jnp.asarray(ar), jnp.asarray(br), r, negacyclic=negacyclic,
        scalar_prefetch=False))
    prefetch = np.asarray(rns_ntt_polymul(
        jnp.asarray(ar), jnp.asarray(br), r, negacyclic=negacyclic,
        scalar_prefetch=True))
    assert (fallback == prefetch).all()
    # cross-check one limb against its own single-modulus reference
    from repro.core.ntt.ref import cyclic_polymul, negacyclic_polymul
    fn = negacyclic_polymul if negacyclic else cyclic_polymul
    for li in (0, r.k - 1):
        p = r.limbs[li]
        want = fn(ar[li], br[li], p).astype(np.uint32)
        assert (prefetch[li] == want).all()


# ---------------------------------------------------------------------------
# Cross-stack differential: float FFT vs exact NTT
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 256, 1024, 4096])
def test_float_fft_polymul_agrees_with_exact_ntt(rng, n):
    """The two subsystems pinned against each other for the first time:
    circular float-FFT polymul, rounded to integers, == exact cyclic NTT
    polymul on small-coefficient inputs (peak coefficient ~n·9 << q, and
    far below the fp32 rounding half-unit at these magnitudes)."""
    p = ref.NTTParams.make(n)
    a = rng.integers(0, 4, size=(2, n))
    b = rng.integers(0, 4, size=(2, n))
    fa = jnp.asarray(a, jnp.float32)
    fb = jnp.asarray(b, jnp.float32)
    via_fft = np.asarray(fft_core.polymul(fa, fb, mode="circular"))
    rounded = np.rint(np.real(via_fft)).astype(np.int64)
    via_ntt = ref.cyclic_polymul(a, b, p)
    assert (rounded >= 0).all() and (rounded < p.q).all()
    assert (rounded.astype(np.uint64) == via_ntt).all()


# ---------------------------------------------------------------------------
# Planner: the exact tier now has a distributed route
# ---------------------------------------------------------------------------

def test_planner_routes_exact_distributed():
    small = fft_core.plan(4096, 64, model_shards=8, exact=True)
    assert small.tier == "local" and small.exact
    big = fft_core.plan(1 << 20, 8, model_shards=8, exact=True)
    assert big.tier == "distributed" and big.exact and big.seq_shards == 8
    assert "NTT" in big.describe()
    # without shards the exact tier stays local at any n
    solo = fft_core.plan(1 << 20, 8, model_shards=1, exact=True)
    assert solo.tier == "local" and solo.exact
