"""Distributed four-step FFT: multi-device correctness (subprocess meshes)."""
import pytest

from conftest import run_in_subprocess_devices

pytestmark = pytest.mark.dist


def test_four_step_fft_and_polymul_8dev():
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.fft import distributed as dfft

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, n = 4, 256
x = rng.standard_normal((B, n)) + 1j * rng.standard_normal((B, n))
sh = NamedSharding(mesh, P("data", "model"))
xj = jax.device_put(jnp.asarray(x, jnp.complex64), sh)

y = jax.jit(dfft.make_sharded_fft(mesh))(xj)
err = np.max(np.abs(np.asarray(y) - np.fft.fft(x)))
assert err < 1e-3, f"fwd err {err}"

z = jax.jit(dfft.make_sharded_fft(mesh, inverse=True))(y)
err = np.max(np.abs(np.asarray(z) - x))
assert err < 1e-4, f"roundtrip err {err}"

a = rng.standard_normal((B, n)); b = rng.standard_normal((B, n))
aj = jax.device_put(jnp.asarray(a, jnp.complex64), sh)
bj = jax.device_put(jnp.asarray(b, jnp.complex64), sh)
c = jax.jit(dfft.make_sharded_polymul(mesh))(aj, bj)
want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b))
err = np.max(np.abs(np.asarray(c) - want))
assert err < 1e-3, f"polymul err {err}"
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_four_step_zorder_saves_collectives():
    """The unordered (Z-order) path must contain fewer all-to-alls."""
    out = run_in_subprocess_devices("""
import re, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.fft import distributed as dfft

mesh = jax.make_mesh((1, 8), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
spec = jax.ShapeDtypeStruct((2, 512), jnp.complex64)

def count_a2a(fn, nargs):
    lowered = jax.jit(fn).lower(*([spec] * nargs))
    txt = lowered.compile().as_text()
    return len(re.findall(r'all-to-all', txt))

ordered = count_a2a(dfft.make_sharded_fft(mesh), 1)
import functools
pm = dfft.make_sharded_polymul(mesh)
pm_n = count_a2a(pm, 2)
print(f"ordered={ordered} polymul={pm_n}")
# ordered fwd uses 3 transposes; polymul (2 fwd + 1 inv, all Z-order) uses 6
assert pm_n < 3 * ordered, (ordered, pm_n)
""", n_devices=8)
    assert "ordered=" in out


def test_fft_distributed_fp32_accuracy_large_n_8dev():
    """n = 2^20 over 8 shards stays within fp32 tolerance of the f64
    numpy oracle — the end-to-end half of the fp32-twiddle regression pin
    (the table-level half, which fails on the pre-fix float32 twiddle
    arithmetic, is tests/test_dist_real.py::
    test_fp32_twiddle_regression_exact_integer_exponents)."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.fft import distributed as dfft

mesh = jax.make_mesh((1, 8), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
rng = np.random.default_rng(0)
n = 1 << 20
x = rng.standard_normal((1, n)) + 1j * rng.standard_normal((1, n))
xj = jax.device_put(jnp.asarray(x, jnp.complex64), sh)
y = np.asarray(jax.jit(dfft.make_sharded_fft(mesh))(xj))
want = np.fft.fft(x)
err = np.max(np.abs(y - want)) / np.max(np.abs(want))
assert err < 2e-6, f"fwd rel err {err}"
back = np.asarray(jax.jit(dfft.make_sharded_fft(mesh, inverse=True))(
    jax.device_put(jnp.asarray(y), sh)))
err = np.max(np.abs(back - x)) / np.max(np.abs(x))
assert err < 2e-6, f"roundtrip rel err {err}"
print("OK")
""", n_devices=8)
    assert "OK" in out
