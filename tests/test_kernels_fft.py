"""Pallas FFT / polymul kernels vs. pure-jnp oracles (interpret mode on CPU).

Per-kernel shape x dtype sweeps + hypothesis property tests on the system's
mathematical invariants (linearity, Parseval, convolution theorem, Eq. (10)).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import fft as kfft
from repro.kernels import ops as kops
from repro.kernels import polymul as kpoly
from repro.kernels import ref


def _rand_complex(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape))


def _planes(x):
    return (jnp.asarray(x.real, jnp.float32), jnp.asarray(x.imag, jnp.float32))


def _join(yr, yi):
    return np.asarray(yr) + 1j * np.asarray(yi)


# ---------------------------------------------------------------------------
# Shape / dtype / radix sweep vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 32, 128, 1024])
@pytest.mark.parametrize("radix", [2, 4])
@pytest.mark.parametrize("batch", [1, 3])
def test_fft_kernel_matches_numpy(rng, n, radix, batch):
    x = _rand_complex(rng, (batch, n))
    yr, yi = kfft.fft_planes(*_planes(x), radix=radix)
    want = np.fft.fft(x)
    np.testing.assert_allclose(_join(yr, yi), want,
                               rtol=1e-4, atol=1e-4 * np.sqrt(n))


@pytest.mark.parametrize("n", [16, 256])
@pytest.mark.parametrize("radix", [2, 4])
def test_ifft_kernel_roundtrip(rng, n, radix):
    x = _rand_complex(rng, (4, n))
    yr, yi = kfft.fft_planes(*_planes(x), radix=radix)
    zr, zi = kfft.fft_planes(yr, yi, inverse=True, radix=radix)
    np.testing.assert_allclose(_join(zr, zi), x, rtol=1e-4, atol=1e-5 * n)


@pytest.mark.parametrize("n", [64])
def test_fft_kernel_bf16(rng, n):
    x = _rand_complex(rng, (2, n))
    xr = jnp.asarray(x.real, jnp.bfloat16)
    xi = jnp.asarray(x.imag, jnp.bfloat16)
    yr, yi = kfft.fft_planes(xr, xi)
    want = np.fft.fft(x)
    got = np.asarray(yr, np.float32) + 1j * np.asarray(yi, np.float32)
    # bf16 storage, fp32 compute: ~2-3 decimal digits
    np.testing.assert_allclose(got, want, rtol=0.15, atol=0.15 * np.sqrt(n))


def test_fft_kernel_nondivisible_batch(rng):
    """Batch not a multiple of the block: wrapper pads and strips."""
    x = _rand_complex(rng, (5, 64))
    yr, yi = kfft.fft_planes(*_planes(x), block_b=4)
    np.testing.assert_allclose(_join(yr, yi), np.fft.fft(x), rtol=1e-4,
                               atol=1e-3)


@pytest.mark.parametrize("oracle", [ref.dft, ref.fft_recursive,
                                    ref.fft_stockham])
def test_oracles_agree(rng, oracle):
    """The three independent references agree with numpy."""
    x = _rand_complex(rng, (2, 64))
    got = np.asarray(oracle(jnp.asarray(x, jnp.complex64)))
    np.testing.assert_allclose(got, np.fft.fft(x), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Fused polymul kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 64, 512])
@pytest.mark.parametrize("radix", [2, 4])
def test_polymul_complex_kernel(rng, n, radix):
    a = _rand_complex(rng, (3, n))
    b = _rand_complex(rng, (3, n))
    cr, ci = kpoly.polymul_complex_planes(*_planes(a), *_planes(b),
                                          radix=radix)
    want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b))
    np.testing.assert_allclose(_join(cr, ci), want, rtol=1e-3,
                               atol=1e-4 * n)


@pytest.mark.parametrize("n", [8, 64, 512])
def test_polymul_real_kernel(rng, n):
    a = rng.standard_normal((3, n))
    b = rng.standard_normal((3, n))
    c = kpoly.polymul_real_planes(jnp.asarray(a, jnp.float32),
                                  jnp.asarray(b, jnp.float32))
    want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b)).real
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-3, atol=1e-4 * n)


def test_polymul_linear_matches_direct_convolution(rng):
    """ops.polymul(mode='linear') == coefficient convolution (paper Eq. 9)."""
    n = 32
    a = rng.standard_normal((2, n))
    b = rng.standard_normal((2, n))
    c = kops.polymul(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
                     mode="linear", backend="pallas")
    want = np.zeros((2, 2 * n))
    for i in range(2):
        want[i, :2 * n - 1] = np.convolve(a[i], b[i])
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-3, atol=1e-3)


def test_realpack_matches_ref(rng):
    n = 64
    x = rng.standard_normal((2, n))
    y = rng.standard_normal((2, n))
    xk, yk = kops.realpack_fft(jnp.asarray(x, jnp.float32),
                               jnp.asarray(y, jnp.float32), backend="xla")
    np.testing.assert_allclose(np.asarray(xk), np.fft.fft(x), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(yk), np.fft.fft(y), rtol=1e-3,
                               atol=1e-3)


def test_fft_causal_conv(rng):
    T, K = 100, 17  # deliberately not powers of two
    x = rng.standard_normal((3, T)).astype(np.float32)
    k = rng.standard_normal((3, K)).astype(np.float32)
    y = kops.fft_causal_conv(jnp.asarray(x), jnp.asarray(k), backend="xla")
    want = np.stack([np.convolve(x[i], k[i])[:T] for i in range(3)])
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------

_n_strategy = st.sampled_from([8, 16, 64, 128])
_seed_strategy = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=20, deadline=None)
@given(n=_n_strategy, seed=_seed_strategy, alpha=st.floats(-3, 3),
       beta=st.floats(-3, 3))
def test_fft_linearity(n, seed, alpha, beta):
    r = np.random.default_rng(seed)
    x = _rand_complex(r, (1, n))
    y = _rand_complex(r, (1, n))
    fx = np.asarray(ref.fft_stockham(jnp.asarray(x, jnp.complex64)))
    fy = np.asarray(ref.fft_stockham(jnp.asarray(y, jnp.complex64)))
    fxy = np.asarray(ref.fft_stockham(jnp.asarray(alpha * x + beta * y,
                                                  jnp.complex64)))
    np.testing.assert_allclose(fxy, alpha * fx + beta * fy, rtol=1e-3,
                               atol=1e-3 * n)


@settings(max_examples=20, deadline=None)
@given(n=_n_strategy, seed=_seed_strategy)
def test_parseval(n, seed):
    r = np.random.default_rng(seed)
    x = _rand_complex(r, (1, n))
    fx = np.asarray(ref.fft_stockham(jnp.asarray(x, jnp.complex64)))
    np.testing.assert_allclose(np.sum(np.abs(fx) ** 2) / n,
                               np.sum(np.abs(x) ** 2), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=_n_strategy, seed=_seed_strategy)
def test_convolution_theorem_vs_direct(n, seed):
    r = np.random.default_rng(seed)
    a = r.standard_normal((1, n))
    b = r.standard_normal((1, n))
    c = np.asarray(kops.polymul(jnp.asarray(a, jnp.float32),
                                jnp.asarray(b, jnp.float32),
                                mode="circular", backend="xla"))
    want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b)).real
    np.testing.assert_allclose(c, want, rtol=1e-3, atol=1e-3 * n)


@settings(max_examples=20, deadline=None)
@given(n=_n_strategy, seed=_seed_strategy)
def test_realpack_identity(n, seed):
    """Eq. (10): packing two real FFTs into one complex FFT is exact."""
    r = np.random.default_rng(seed)
    x = r.standard_normal((1, n))
    y = r.standard_normal((1, n))
    xk, yk = ref.realpack_fft_ref(jnp.asarray(x, jnp.float32),
                                  jnp.asarray(y, jnp.float32))
    np.testing.assert_allclose(np.asarray(xk), np.fft.fft(x), rtol=1e-3,
                               atol=1e-3 * n)
    np.testing.assert_allclose(np.asarray(yk), np.fft.fft(y), rtol=1e-3,
                               atol=1e-3 * n)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 32]), seed=_seed_strategy)
def test_pallas_kernel_equals_oracle_property(n, seed):
    """Kernel == oracle on random data (the per-kernel allclose contract)."""
    r = np.random.default_rng(seed)
    x = _rand_complex(r, (2, n))
    yr, yi = kfft.fft_planes(*_planes(x))
    want = np.asarray(ref.dft(jnp.asarray(x, jnp.complex64)))
    np.testing.assert_allclose(_join(yr, yi), want, rtol=1e-3, atol=1e-3 * n)


# ---------------------------------------------------------------------------
# Planner contract: loud rejection + the exact (NTT) route
# ---------------------------------------------------------------------------

def test_planner_rejects_non_power_of_two():
    """plan() must raise, not silently mis-plan (asserts vanish under -O)."""
    from repro.core import fft as fcore
    for bad in (48, 0, -8, 1536):
        with pytest.raises(ValueError):
            fcore.plan(bad, batch=8)
    with pytest.raises(ValueError):
        fcore.plan(1024, batch=-1)


def test_planner_exact_route():
    from repro.core import fft as fcore
    p = fcore.plan(4096, batch=64, exact=True)
    assert p.exact and p.tier == "local" and p.radix == 2
    assert "NTT" in p.describe()
    # the float route is unchanged by the new field
    f = fcore.plan(4096, batch=64)
    assert not f.exact and f.radix == 4


def test_ops_ifft_roundtrip_both_backends(rng):
    """Inverse-transform round-trip through the public ops layer."""
    x = _rand_complex(rng, (3, 128)).astype(np.complex64)
    for backend in ("xla", "pallas"):
        y = kops.fft(jnp.asarray(x), backend=backend)
        z = np.asarray(kops.ifft(y, backend=backend))
        np.testing.assert_allclose(z, x, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# 2-D extension (signal processing application of the paper's primitive)
# ---------------------------------------------------------------------------

def test_fft2_matches_numpy(rng):
    x = _rand_complex(rng, (2, 16, 32))
    got = np.asarray(kops.fft2(jnp.asarray(x, jnp.complex64), backend="xla"))
    np.testing.assert_allclose(got, np.fft.fft2(x), rtol=1e-3, atol=1e-3)
    back = np.asarray(kops.fft2(jnp.asarray(got), inverse=True,
                                backend="xla"))
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-4)


def test_fft_conv2d_matches_direct(rng):
    H, W, kh, kw = 12, 20, 3, 5
    img = rng.standard_normal((2, H, W)).astype(np.float32)
    kern = rng.standard_normal((kh, kw)).astype(np.float32)
    got = np.asarray(kops.fft_conv2d(jnp.asarray(img), jnp.asarray(kern),
                                     backend="xla"))
    # direct 'same' convolution reference
    want = np.zeros_like(img)
    pi = np.pad(img, ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2)))
    for r in range(kh):
        for c in range(kw):
            want += kern[r, c] * pi[:, kh - 1 - r:kh - 1 - r + H,
                                    kw - 1 - c:kw - 1 - c + W][..., ::1]
    # convolution flips the kernel relative to correlation
    want2 = np.zeros_like(img)
    for r in range(kh):
        for c in range(kw):
            want2 += kern[r, c] * pi[:, r:r + H, c:c + W]
    close1 = np.allclose(got, want, rtol=1e-3, atol=1e-3)
    close2 = np.allclose(got, want2, rtol=1e-3, atol=1e-3)
    assert close1 or close2
