"""Deterministic mini-fallback for the slice of the hypothesis API this
suite uses, for toolchains where the real library isn't installed.

``conftest.py`` registers this module as ``hypothesis`` only when the real
one is missing (CI installs the real thing; the pinned container may not).
Property tests then still *run* — each ``@given`` test is executed
``max_examples`` times with samples drawn from a per-test seeded PRNG — they
just lose hypothesis's shrinking and example database. Supported surface:
``given(**kwargs)``, ``settings(max_examples=, deadline=)``, and the
``integers`` / ``floats`` / ``sampled_from`` strategies.
"""
from __future__ import annotations

import inspect
import random
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value=0, max_value=2**31 - 1):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.sampled_from = sampled_from

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    for k, s in strats.items():
        if not isinstance(s, _Strategy):
            raise TypeError(f"unsupported strategy for {k!r}: {s!r}")

    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            # per-test deterministic stream, stable across runs/processes
            rng = random.Random(zlib.adler32(fn.__qualname__.encode()))
            for i in range(n):
                draw = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **kwargs, **draw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{i + 1}): {draw}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # hide the drawn params from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return deco
