"""ABFT tier: fault model, sim-level injection, integrity checks, and the
check-cost contract (docs/fault_tolerance.md).

* :class:`FaultModel` is seeded + replayable: same (seed, array) -> same
  faults; quarantine remaps to finite spares;
* sim-level faults (dead array, stuck cells, transient flips) land in the
  charge log as zero-cycle ``fault:*`` ledger entries and are DETECTED by
  the matching integrity check — while fault-free runs pass it;
* the modular checks are exact (every single-coefficient corruption is
  caught); the float checks are toleranced residuals that localize the
  corrupted batch row;
* cost contract: ``abft.charge_check`` on a live sim == the closed form
  ``abft.check_cycles`` == ``cost.abft_check_cycles`` (counter parity),
  the checked overhead stays under the BENCH gate, and
  ``workload_cost(..., verified=True)`` / ``pim_ok=False`` price exactly
  these numbers into the planner.
"""
import numpy as np
import pytest

from repro.core import cost as cost_lib
from repro.core.fft.planner import plan
from repro.core.ntt import NTTParams, RNSParams
from repro.core.pim import (FOURIERPIM_8, FP32, INT32, FaultModel,
                            SparesExhausted, fft_pim, ntt_pim)
from repro.core.pim.crossbar import CrossbarSim
from repro.ft import abft

CFG = FOURIERPIM_8


def _negacyclic_ref(a, b, q):
    """O(n^2) negacyclic product mod q in exact python ints (the oracle
    the eval-at-psi check is validated against)."""
    n = len(a)
    conv = np.convolve(np.array([int(v) for v in a], object),
                       np.array([int(v) for v in b], object))
    out = [(int(conv[k]) - (int(conv[k + n]) if k + n < len(conv) else 0))
           % q for k in range(n)]
    return out


# ---------------------------------------------------------------------------
# FaultModel: determinism, quarantine, spares
# ---------------------------------------------------------------------------

def test_fault_model_deterministic():
    kw = dict(seed=7, stuck_per_array=2, bitflip_per_gate=1e-6,
              n_arrays=4, spares=2)
    f1 = FaultModel(**kw).for_array(1)
    f2 = FaultModel(**kw).for_array(1)
    assert f1 == f2 and f1.permanent
    assert len(f1.stuck_pos) == 2
    # a different seed draws different stuck cells
    assert FaultModel(**{**kw, "seed": 8}).for_array(1) != f1
    # clean model resolves None everywhere (the zero-overhead fast path)
    assert FaultModel(seed=7, n_arrays=4).for_array(1) is None


def test_fault_model_quarantine_and_spares():
    fm = FaultModel(seed=0, dead_arrays=(0, 1, 2), n_arrays=4, spares=2)
    assert fm.for_array(0).dead
    spare = fm.quarantine(0)
    assert spare >= fm.n_arrays
    assert fm.is_quarantined(0)
    assert fm.for_array(0) is None          # spares are clean
    assert fm.quarantine(0) == spare        # idempotent, no spare burned
    fm.quarantine(1)
    with pytest.raises(SparesExhausted):
        fm.quarantine(2)
    # the spare draws its own (replayable) transient stream
    a = fm.rng_for(0, salt=5).random(3)
    b = fm.rng_for(0, salt=5).random(3)
    np.testing.assert_array_equal(a, b)


def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(bitflip_per_gate=1.5)
    with pytest.raises(ValueError):
        FaultModel(dead_arrays=(9,), n_arrays=4)
    with pytest.raises(ValueError):
        FaultModel(stuck_per_array=-1)


# ---------------------------------------------------------------------------
# Sim-level injection: ledger entries + detection by the checks
# ---------------------------------------------------------------------------

def test_dead_array_mod_detected_and_costs_nothing(rng):
    n = 1024
    params = NTTParams.make(n)
    a = rng.integers(0, params.q, n).astype(np.uint32)
    b = rng.integers(0, params.q, n).astype(np.uint32)
    clean = ntt_pim.pim_ntt_polymul(a, b, params, CFG, INT32)
    fm = FaultModel(seed=0, dead_arrays=(0,), n_arrays=2, spares=1)
    faulty = ntt_pim.pim_ntt_polymul(a, b, params, CFG, INT32,
                                     faults=fm, array_id=0)
    assert abft.check_polymul_mod(a, b, clean.output, params).ok
    v = abft.check_polymul_mod(a, b, faulty.output, params)
    assert not v and v.failed_rows == (0,) and v.check == "eval-at-psi"
    # ledger: the array names itself, at zero cycles — fault injection
    # never perturbs the cost model
    tags = [t for t, _ in faulty.log if t.startswith("fault:")]
    assert tags and all(t == "fault:dead:a0" for t in tags)
    assert all(c == 0 for t, c in faulty.log if t.startswith("fault:"))
    assert faulty.counters.cycles == clean.counters.cycles


def test_transient_flip_float_detected_by_parseval(rng):
    n = 1024
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    clean = fft_pim.pim_fft(x, CFG, FP32)
    assert abft.check_fft(x, clean.output).ok
    fm = FaultModel(seed=3, bitflip_per_gate=1e-4, n_arrays=1, spares=0)
    faulty = fft_pim.pim_fft(x, CFG, FP32, faults=fm, array_id=0)
    flips = [t for t, _ in faulty.log if t == "fault:flip:a0"]
    assert flips, "pinned seed must fire at least one transient"
    assert not abft.check_fft(x, faulty.output)
    assert faulty.counters.cycles == clean.counters.cycles
    # same model, same seed -> identical corrupted output (replayable)
    again = fft_pim.pim_fft(
        x, CFG, FP32,
        faults=FaultModel(seed=3, bitflip_per_gate=1e-4, n_arrays=1,
                          spares=0), array_id=0)
    np.testing.assert_array_equal(faulty.output, again.output)


def test_stuck_cells_mod_detected(rng):
    n = 2048
    params = NTTParams.make(n)
    a = rng.integers(0, params.q, n).astype(np.uint32)
    b = rng.integers(0, params.q, n).astype(np.uint32)
    fm = FaultModel(seed=11, stuck_per_array=3, n_arrays=1, spares=0)
    faulty = ntt_pim.pim_ntt_polymul(a, b, params, CFG, INT32,
                                     faults=fm, array_id=0)
    assert any(t == "fault:stuck:a0" for t, _ in faulty.log)
    assert not abft.check_polymul_mod(a, b, faulty.output, params)


# ---------------------------------------------------------------------------
# Integrity checks: clean pass, corruption localized
# ---------------------------------------------------------------------------

def test_float_checks_pass_clean_and_localize_row(rng):
    n = 128
    x = (rng.standard_normal((3, n))
         + 1j * rng.standard_normal((3, n))).astype(np.complex64)
    out = np.fft.fft(x).astype(np.complex64)
    assert abft.check_fft(x, out).ok
    bad = out.copy()
    bad[1, 5] *= 3.0
    v = abft.check_fft(x, bad)
    assert not v and v.failed_rows == (1,)

    xr = rng.standard_normal((3, n)).astype(np.float32)
    outr = np.fft.rfft(xr).astype(np.complex64)
    assert abft.check_rfft(xr, outr).ok
    badr = outr.copy()
    badr[2, 7] += 50.0
    v = abft.check_rfft(xr, badr)
    assert not v and v.failed_rows == (2,) and v.check == "parseval-half"

    a = rng.standard_normal((3, n)) + 1j * rng.standard_normal((3, n))
    b = rng.standard_normal((3, n)) + 1j * rng.standard_normal((3, n))
    r = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b))
    assert abft.check_polymul(a, b, r).ok
    rb = r.copy()
    rb[0, 0] += 100.0
    v = abft.check_polymul(a, b, rb)
    assert not v and v.failed_rows == (0,)

    ar, br = a.real, b.real
    rr = np.fft.irfft(np.fft.rfft(ar) * np.fft.rfft(br), n)
    assert abft.check_polymul_real(ar, br, rr).ok
    assert not abft.check_polymul_real(ar, br, rr + 1.0)


def test_polymul_mod_check_catches_every_coefficient(rng):
    """Exactness: ANY single-coefficient corruption moves r(psi) by
    delta * psi^j != 0 mod q — checked for every position at once."""
    n = 64
    params = NTTParams.make(n)
    a = rng.integers(0, params.q, n).astype(np.uint32)
    b = rng.integers(0, params.q, n).astype(np.uint32)
    r = np.array(_negacyclic_ref(a, b, params.q), np.uint32)
    assert abft.check_polymul_mod(a, b, r, params).ok
    batch = np.tile(r, (n, 1))
    batch[np.arange(n), np.arange(n)] = \
        (batch[np.arange(n), np.arange(n)] + 1) % params.q
    v = abft.check_polymul_mod(np.tile(a, (n, 1)), np.tile(b, (n, 1)),
                               batch, params)
    assert v.failed_rows == tuple(range(n))


def test_polymul_rns_check_and_factor_recovery(rng):
    n = 128
    rns = RNSParams.make(n, modulus_bits=60)
    limbs = abft.check_limbs_for(rns)
    prod = 1
    for limb in limbs:
        prod *= limb.q
    assert prod == rns.modulus
    Q = rns.modulus
    a = np.array([int(v) for v in rng.integers(0, 1 << 62, n)],
                 object) % Q
    b = np.array([int(v) for v in rng.integers(0, 1 << 62, n)],
                 object) % Q
    r = np.array(_negacyclic_ref(a, b, Q), object)
    assert abft.check_polymul_rns(a, b, r, rns).ok
    bad = r.copy()
    bad[17] = (bad[17] + 1) % Q
    v = abft.check_polymul_rns(a, b, bad, rns)
    assert not v and v.failed_rows == (0,)


def test_rns_unsupported_modulus_rejected():
    # A Mersenne prime shares no factor with the 30-bit NTT limb primes.
    rns = RNSParams.make(64, modulus=(1 << 61) - 1)
    with pytest.raises(abft.ABFTUnsupportedModulus):
        abft.check_limbs_for(rns)


# ---------------------------------------------------------------------------
# Check cost: counter parity, overhead gate, planner pricing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", sorted(abft.CHECKS))
@pytest.mark.parametrize("n", [1024, 4096])
def test_check_cost_counter_parity(workload, n):
    spec = INT32 if workload == "polymul-mod" else FP32
    sim = CrossbarSim(CFG, spec)
    abft.charge_check(sim, workload, n)
    closed = abft.check_cycles(workload, n, CFG, spec)
    assert sim.ctr.cycles == closed
    assert cost_lib.abft_check_cycles(workload, n) == closed


@pytest.mark.parametrize("workload", sorted(abft.CHECKS))
@pytest.mark.parametrize("n", [1024, 4096])
def test_check_overhead_under_gate(workload, n):
    """The check must stay CHEAP relative to the transform it verifies —
    the same <= 0.25 bound the BENCH abft_overhead_ratio gate enforces."""
    base = cost_lib.pim_local_unit_cycles(workload, n, batch=2)
    check = cost_lib.abft_check_cycles(workload, n)
    assert 0 < check <= 0.25 * base, \
        f"{workload}/n={n}: check {check} vs base {base}"


def test_verified_pricing_adds_exactly_the_check():
    n, batch = 1024, 8
    for workload in cost_lib.WORKLOADS:
        base = cost_lib.workload_cost(workload, n, batch)
        ver = cost_lib.workload_cost(workload, n, batch, verified=True)
        # verified pricing may reorder the sorted candidate list: match
        # candidates by identity, not rank
        by_key = {(c["tier"], c["real"]): c for c in ver["candidates"]}
        assert len(by_key) == len(base["candidates"])
        for cb in base["candidates"]:
            cv = by_key[(cb["tier"], cb["real"])]
            pb, pv = cb["backends"]["pim"], cv["backends"]["pim"]
            if "infeasible" in pb:
                continue
            wl = cost_lib._pim_workload(workload, cb["real"])
            assert pv["pim_cycles"] - pb["pim_cycles"] == \
                cost_lib.abft_check_cycles(wl, n)
            assert pv["total_s"] > pb["total_s"]
            xv = cv["backends"]["xla"]
            assert xv["t_compute_s"] >= cb["backends"]["xla"]["t_compute_s"]


def test_pim_ok_false_quarantines_every_candidate():
    c = cost_lib.workload_cost("fft", 1024, 8, pim_ok=False)
    assert c["candidates"]
    for cand in c["candidates"]:
        assert cand["backend_best"] == "xla"
        assert "quarantined" in cand["backends"]["pim"]["infeasible"]


def test_planner_verified_and_pim_ok_passthrough():
    p = plan(n=1024, batch=8, workload="fft", verified=True, pim_ok=False)
    best = p.cost["best"]
    assert best["backend_best"] == "xla"
    assert "quarantined" in best["backends"]["pim"]["infeasible"]
    pv = plan(n=1024, batch=8, workload="polymul-mod", verified=True)
    pb = plan(n=1024, batch=8, workload="polymul-mod")
    pim_v = pv.cost["best"]["backends"]["pim"]
    pim_b = pb.cost["best"]["backends"]["pim"]
    if "pim_cycles" in pim_v and "pim_cycles" in pim_b:
        assert pim_v["pim_cycles"] > pim_b["pim_cycles"]
