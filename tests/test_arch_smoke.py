"""Per-architecture smoke tests on reduced configs (CPU, 1 device).

Every assigned arch: (1) forward + loss + grad step produce finite values
with the right shapes; (2) decode-with-cache is consistent with the
full-sequence forward (prefill/decode parity) — a strong correctness check
of KV-cache/ring/recurrent-state handling.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_MODULES, ASSIGNED, get_config
from repro.models import lm

ALL_ARCHS = ASSIGNED + ["fourierpim-lm"]


def _batch_for(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    batch = {}
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.frontend == "embeddings":
        batch["embeds"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                            jnp.float32) * 0.02
        batch["tokens"] = None
    else:
        batch["tokens"] = tokens
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                               (B, S, 3))
        batch["positions"] = pos
    batch["labels"] = labels
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_grad_finite(arch):
    cfg = get_config(arch).scaled_down()
    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    batch = _batch_for(cfg, jax.random.key(1))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch)))(params)
    assert np.isfinite(float(loss)), arch
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # logits shape
    logits, aux, _ = jax.jit(
        lambda p: lm.forward(cfg, p, batch.get("tokens"),
                             positions=batch.get("positions"),
                             embeds=batch.get("embeds")))(params)
    B = 2
    S = (batch["tokens"] if batch.get("tokens") is not None
         else batch["embeds"]).shape[1]
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_parity(arch):
    """decode_step(token_S | prefill(tokens[:S])) == forward(tokens[:S+1]).

    Validates cache layout (incl. SWA rings), recurrent state carry, and
    position handling for every mixer family.
    """
    cfg = get_config(arch).scaled_down()
    if cfg.is_moe:
        # capacity drops are data-dependent on group composition; a no-drop
        # capacity factor (E/k) makes train/decode routing identical so the
        # parity check is exact.
        import dataclasses
        cfg = dataclasses.replace(
            cfg, capacity_factor=cfg.num_experts / cfg.experts_per_token)
    B, S = 2, 64  # S == smoke window so ring slots line up
    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    if cfg.frontend == "embeddings":
        embeds = jax.random.normal(jax.random.key(1), (B, S + 1, cfg.d_model),
                                   jnp.float32) * 0.02
        tokens = None
    else:
        tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                    cfg.vocab_size)
        embeds = None
    positions = None
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(
            jnp.arange(S + 1, dtype=jnp.int32)[None, :, None], (B, S + 1, 3))

    # ground truth: full forward on S+1 tokens
    logits_full, _, _ = lm.forward(
        cfg, params, tokens,
        positions=positions,
        embeds=embeds)
    want = np.asarray(logits_full[:, -1], np.float32)

    # prefill on S, then decode token S
    pf_tokens = tokens[:, :S] if tokens is not None else None
    pf_pos = positions[:, :S] if positions is not None else None
    pf_emb = embeds[:, :S] if embeds is not None else None
    last_logits, state = lm.prefill(cfg, params, pf_tokens,
                                    positions=pf_pos, embeds=pf_emb,
                                    cache_capacity=S + 1)
    # prefill's last logits must equal forward at position S-1
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(logits_full[:, S - 1], np.float32), rtol=2e-3, atol=2e-3)

    dec_pos = positions[:, S:S + 1] if positions is not None else None
    dec_emb = embeds[:, S:S + 1] if embeds is not None else None
    tok = tokens[:, S] if tokens is not None else None
    got, _ = lm.decode_step(cfg, params, state, tok, jnp.int32(S),
                            positions=dec_pos, embed=dec_emb)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_analytic():
    for arch in ["qwen3-1.7b", "granite-moe-3b-a800m", "rwkv6-7b"]:
        cfg = get_config(arch).scaled_down()
        params = lm.init_params(cfg, jax.random.key(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        # analytic model ignores small vectors (biases, norms, mu, etc.)
        assert abs(actual - analytic) / analytic < 0.25, (arch, actual,
                                                          analytic)


def test_moe_active_params_less_than_total():
    cfg = get_config("mixtral-8x22b")
    assert cfg.active_param_count() < cfg.param_count()
    # mixtral: ~141B total, ~39B active (public figures) — sanity band
    assert 1.0e11 < cfg.param_count() < 1.6e11
    assert 3.0e10 < cfg.active_param_count() < 4.6e10


def test_llama405b_param_count():
    cfg = get_config("llama3-405b")
    assert 3.8e11 < cfg.param_count() < 4.3e11, cfg.param_count()
