"""Shared test utilities.

NOTE: XLA_FLAGS / forced device counts are deliberately NOT set here — smoke
tests and benchmarks must see the real single CPU device. Tests that need a
multi-device mesh spawn a subprocess via ``run_in_subprocess_devices``.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

try:
    import hypothesis  # noqa: F401
except ImportError:  # pinned toolchain: run property tests on the fallback
    import _hypothesis_fallback as _hf
    sys.modules["hypothesis"] = _hf  # type: ignore[assignment]
    sys.modules["hypothesis.strategies"] = _hf.strategies


def run_in_subprocess_devices(snippet: str, n_devices: int = 8,
                              timeout: int = 600) -> str:
    """Run ``snippet`` in a fresh python with n forced host devices.

    The snippet should print results / raise on failure. Returns stdout.
    """
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        """) + textwrap.dedent(snippet)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={res.returncode})\n--- stdout ---\n"
            f"{res.stdout}\n--- stderr ---\n{res.stderr[-4000:]}")
    return res.stdout


def pytest_collection_modifyitems(config, items):
    """Partition tier-1: anything not explicitly marked ``dist`` is ``unit``,
    so ``-m unit`` and ``-m dist`` select disjoint, exhaustive halves."""
    for item in items:
        if item.get_closest_marker("dist") is None:
            item.add_marker(pytest.mark.unit)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
