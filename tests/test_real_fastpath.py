"""Real-Hermitian fast path: two-for-one packed rfft/irfft kernels, the
paired-inverse real polymul, the planner's real tier, and the serve route.

Contract layers pinned here:
  * kernel parity: ``rfft_planes`` vs ``np.fft.rfft`` at fp32 tolerance,
    ``irfft(rfft(x)) == x`` round-trips, odd/even batch padding edges;
  * the EXACT Hermitian symmetry of ``hermitian_split`` (bitwise ``==``) —
    the property the paired inverse relies on;
  * ``polymul_real`` vs the schoolbook circular product up to n = 4096;
  * planner: ``plan(n, b, real=True)`` returns the doubled batch block and
    the real tier; exact+real is rejected;
  * serve: ``--op polymul-real`` actually selects the real route (plan and
    kernel), instead of silently aliasing the complex lambda (regression).
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import fft as fft_core
from repro.kernels import fft as kfft
from repro.kernels import ops as kops
from repro.kernels import polymul as kpoly


def _unpack_to_numpy(yr, yi):
    """Packed-Nyquist planes -> np.fft.rfft layout (n/2+1 complex bins)."""
    yr = np.asarray(yr)
    yi = np.asarray(yi)
    zero = np.zeros_like(yr[..., :1])
    re = np.concatenate([yr, yi[..., :1]], axis=-1)
    im = np.concatenate([zero, yi[..., 1:], zero], axis=-1)
    return re + 1j * im


def _circular_schoolbook(a, b):
    """O(n^2)-equivalent circular product oracle (linear convolve + fold)."""
    n = a.shape[-1]
    out = np.empty_like(a)
    for i in range(a.shape[0]):
        full = np.convolve(a[i], b[i])
        out[i] = full[:n]
        out[i, :n - 1] += full[n:]
    return out


# ---------------------------------------------------------------------------
# Kernel tier
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 64, 1024])
@pytest.mark.parametrize("radix", [2, 4])
@pytest.mark.parametrize("batch", [1, 2, 5])
def test_rfft_kernel_matches_numpy(rng, n, radix, batch):
    x = rng.standard_normal((batch, n)).astype(np.float32)
    yr, yi = kfft.rfft_planes(jnp.asarray(x), radix=radix, block_b=4)
    assert yr.shape == yi.shape == (batch, n // 2)   # half-width planes
    np.testing.assert_allclose(_unpack_to_numpy(yr, yi), np.fft.rfft(x),
                               rtol=1e-4, atol=1e-4 * np.sqrt(n))


@pytest.mark.parametrize("n", [16, 256])
@pytest.mark.parametrize("radix", [2, 4])
@pytest.mark.parametrize("batch", [1, 3, 4])
def test_irfft_rfft_roundtrip_kernel(rng, n, radix, batch):
    """irfft(rfft(x)) == x, including odd batches through the even-block
    padding path."""
    x = rng.standard_normal((batch, n)).astype(np.float32)
    yr, yi = kfft.rfft_planes(jnp.asarray(x), radix=radix, block_b=4)
    back = kfft.irfft_planes(yr, yi, radix=radix, block_b=4)
    np.testing.assert_allclose(np.asarray(back), x, rtol=1e-4, atol=1e-4 * n)


def test_hermitian_split_exact_symmetry(rng):
    """The split spectra are EXACTLY Hermitian (bitwise), not just close:
    each mirrored component is the same float expression. The paired
    inverse in the polymul kernel is only valid because of this."""
    n = 64
    zr = jnp.asarray(rng.standard_normal((3, n)), jnp.float32)
    zi = jnp.asarray(rng.standard_normal((3, n)), jnp.float32)
    ar, ai, br, bi = (np.asarray(v) for v in kfft.hermitian_split(zr, zi))
    for sr, si in ((ar, ai), (br, bi)):
        mirror_r = np.roll(sr[:, ::-1], 1, axis=1)   # S_{n-k}.re
        mirror_i = np.roll(si[:, ::-1], 1, axis=1)
        assert (sr == mirror_r).all()
        assert (si == -mirror_i).all()


def test_real_mode_batch_block_doubles():
    for n in (1024, 4096, 16384):
        assert (kfft.plan_batch_block(n, real=True)
                == 2 * kfft.plan_batch_block(n))


@pytest.mark.parametrize("n", [64, 512, 4096])
def test_polymul_real_kernel_vs_schoolbook(rng, n):
    a = rng.standard_normal((2, n)).astype(np.float32)
    b = rng.standard_normal((2, n)).astype(np.float32)
    c = kpoly.polymul_real_planes(jnp.asarray(a), jnp.asarray(b), block_b=2)
    want = _circular_schoolbook(a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-3, atol=1e-4 * n)


@pytest.mark.parametrize("batch", [1, 2, 3, 5, 8])
def test_polymul_real_batch_padding_edges(rng, batch):
    """Odd batches pair the tail row with zero padding; results must be
    identical to the per-row product."""
    n = 128
    a = rng.standard_normal((batch, n)).astype(np.float32)
    b = rng.standard_normal((batch, n)).astype(np.float32)
    c = kpoly.polymul_real_planes(jnp.asarray(a), jnp.asarray(b), block_b=4)
    want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b)).real
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-3, atol=1e-4 * n)


# ---------------------------------------------------------------------------
# Ops tier (public rfft/irfft/polymul_real, both backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_rfft_matches_numpy(rng, backend):
    x = rng.standard_normal((2, 3, 128)).astype(np.float32)
    got = np.asarray(kops.rfft(jnp.asarray(x), backend=backend))
    np.testing.assert_allclose(got, np.fft.rfft(x), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("packed", [False, True])
def test_ops_irfft_roundtrip(rng, backend, packed):
    x = rng.standard_normal((5, 64)).astype(np.float32)
    h = kops.rfft(jnp.asarray(x), backend=backend, packed=packed)
    back = np.asarray(kops.irfft(h, backend=backend, packed=packed))
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


def test_ops_rfft_rejects_complex(rng):
    with pytest.raises(TypeError):
        kops.rfft(jnp.ones((2, 8), jnp.complex64))
    with pytest.raises(TypeError):
        kops.polymul_real(jnp.ones((2, 8), jnp.complex64),
                          jnp.ones((2, 8), jnp.complex64))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_polymul_real_linear(rng, backend):
    n = 32
    a = rng.standard_normal((2, n)).astype(np.float32)
    b = rng.standard_normal((2, n)).astype(np.float32)
    c = np.asarray(kops.polymul_real(jnp.asarray(a), jnp.asarray(b),
                                     mode="linear", backend=backend))
    want = np.zeros((2, 2 * n))
    for i in range(2):
        want[i, :2 * n - 1] = np.convolve(a[i], b[i])
    np.testing.assert_allclose(c, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

_n_strategy = st.sampled_from([8, 16, 64, 256])
_seed_strategy = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=15, deadline=None)
@given(n=_n_strategy, seed=_seed_strategy)
def test_property_irfft_rfft_identity(n, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal((2, n)).astype(np.float32)
    yr, yi = kfft.rfft_planes(jnp.asarray(x), block_b=2)
    back = np.asarray(kfft.irfft_planes(yr, yi, block_b=2))
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4 * n)


@settings(max_examples=15, deadline=None)
@given(n=_n_strategy, seed=_seed_strategy)
def test_property_rfft_parity(n, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal((3, n)).astype(np.float32)
    yr, yi = kfft.rfft_planes(jnp.asarray(x), block_b=4)
    np.testing.assert_allclose(_unpack_to_numpy(yr, yi), np.fft.rfft(x),
                               rtol=1e-3, atol=1e-3 * np.sqrt(n))


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 32, 128]), seed=_seed_strategy)
def test_property_polymul_real_vs_schoolbook(n, seed):
    r = np.random.default_rng(seed)
    a = r.standard_normal((2, n)).astype(np.float32)
    b = r.standard_normal((2, n)).astype(np.float32)
    c = np.asarray(kpoly.polymul_real_planes(jnp.asarray(a), jnp.asarray(b),
                                             block_b=2))
    want = _circular_schoolbook(a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(c, want, rtol=1e-3, atol=1e-3 * n)


# ---------------------------------------------------------------------------
# Planner real tier
# ---------------------------------------------------------------------------

def test_planner_real_tier_doubled_block():
    for n in (1024, 4096):
        pr = fft_core.plan(n, batch=64, real=True)
        pc = fft_core.plan(n, batch=64)
        assert pr.real and not pc.real
        assert pr.tier == "local"
        assert pr.block_b == 2 * pc.block_b
        assert "real-packed" in pr.describe()


def test_planner_real_tier_local_ceiling_matches_complex():
    """The real tier's local-n ceiling equals the complex tier's: the
    minimum schedulable block is a PAIR of real rows (= one full complex
    row), so at the ceiling the mandatory 2-row block sits exactly at the
    VMEM budget — doubling the ceiling would demand a 2x-budget block on
    real hardware. (The batch BLOCK doubles; the ceiling does not.)"""
    from repro.core.fft import planner
    from repro.kernels.fft import (VMEM_BUDGET_BYTES, _LIVE_FACTOR,
                                   plan_batch_block)
    n_edge = planner._MAX_LOCAL_N_REAL
    assert n_edge == planner._MAX_LOCAL_N
    p = fft_core.plan(n_edge, 1, real=True, model_shards=4)
    assert p.tier == "local"
    # the mandatory even block at the ceiling fits the budget exactly
    blk = plan_batch_block(n_edge, real=True)
    assert blk >= 2
    assert blk * n_edge * 4 * _LIVE_FACTOR <= VMEM_BUDGET_BYTES
    assert fft_core.plan(2 * n_edge, 1, real=True,
                         model_shards=4).tier == "distributed"
    assert fft_core.plan(2 * planner._MAX_LOCAL_N, 1,
                         model_shards=4).tier == "distributed"


def test_planner_rejects_exact_real_combo():
    with pytest.raises(ValueError):
        fft_core.plan(1024, batch=8, exact=True, real=True)


# ---------------------------------------------------------------------------
# Serve route regression: polymul-real must NOT alias the complex lambda
# ---------------------------------------------------------------------------

def test_serve_polymul_real_route_selected(rng):
    from repro.launch.serve import FFTService
    svc = FFTService(256, 4, "polymul-real")
    # Route + plan: the real tier is actually selected.
    assert svc.route == "polymul-real-packed"
    assert svc.plan is not None and svc.plan.real
    assert svc.plan.block_b == 2 * fft_core.plan(256, 4).block_b
    # The complex endpoint stays complex.
    cplx = FFTService(256, 4, "polymul")
    assert not cplx.plan.real and cplx.route == "polymul"
    # And the real route computes the right thing.
    a = rng.standard_normal((4, 256)).astype(np.float32)
    b = rng.standard_normal((4, 256)).astype(np.float32)
    got = np.asarray(svc._fn(jnp.asarray(a), jnp.asarray(b)))
    want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b)).real
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)
    assert not np.iscomplexobj(got)


def test_serve_rfft_route(rng):
    from repro.launch.serve import FFTService
    svc = FFTService(128, 4, "rfft")
    assert svc.plan.real and svc.route == "rfft-real"
    x = rng.standard_normal((4, 128)).astype(np.float32)
    got = np.asarray(svc._fn(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.rfft(x), rtol=1e-3, atol=1e-3)
