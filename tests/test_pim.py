"""Faithful-reproduction layer tests: simulator correctness vs numpy.fft,
closed-form == simulator counters, partition scaling, polymul optimizations,
and a bit-exact NOR-netlist adder pinning the cost model's structure."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pim import (A100, FOURIERPIM_8, FOURIERPIM_40, FP16, FP32,
                            RTX3070, complex_word_bits, fft_latency_cycles,
                            fft_throughput_per_s, gpu_model, pim_fft,
                            pim_polymul, pim_polymul_real, pim_rfft,
                            polymul_latency_cycles,
                            polymul_real_batch_latency_cycles,
                            polymul_real_pair_latency_cycles,
                            polymul_throughput_per_s, rfft_latency_cycles,
                            rfft_throughput_per_s, with_partitions)
from repro.core.pim import aritpim, fft_pim


@pytest.mark.parametrize("n", [1024, 2048, 4096, 8192])
def test_pim_fft_matches_numpy(rng, n):
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    res = pim_fft(x, FOURIERPIM_8, FP32)
    np.testing.assert_allclose(res.output, np.fft.fft(x), rtol=1e-10,
                               atol=1e-9)


@pytest.mark.parametrize("n", [1024, 2048, 8192])
def test_pim_ifft_roundtrip(rng, n):
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    f = pim_fft(x, FOURIERPIM_8, FP32)
    b = pim_fft(f.output, FOURIERPIM_8, FP32, inverse=True)
    np.testing.assert_allclose(b.output, x, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("spec", [FP32, FP16])
@pytest.mark.parametrize("n", [1024, 2048, 4096, 16384])
@pytest.mark.parametrize("p", [1, 2, 4])
def test_closed_form_latency_matches_simulator(rng, n, spec, p):
    cfg = with_partitions(FOURIERPIM_8, p)
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    res = pim_fft(x, cfg, spec)
    assert res.counters.cycles == fft_latency_cycles(n, cfg, spec)


@pytest.mark.parametrize("real", [False, True])
def test_polymul_closed_form_matches_simulator(rng, real):
    n = 4096
    if real:
        a, b = rng.standard_normal(n), rng.standard_normal(n)
        res = pim_polymul_real(a, b, FOURIERPIM_8, FP32)
    else:
        a = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        b = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        res = pim_polymul(a, b, FOURIERPIM_8, FP32)
    assert res.counters.cycles == polymul_latency_cycles(
        n, FOURIERPIM_8, FP32, real=real)


def test_pim_polymul_values(rng):
    n = 2048
    a = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    b = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    res = pim_polymul(a, b, FOURIERPIM_8, FP32)
    want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b))
    np.testing.assert_allclose(res.output, want, rtol=1e-9, atol=1e-9)
    ar, br = rng.standard_normal(n), rng.standard_normal(n)
    resr = pim_polymul_real(ar, br, FOURIERPIM_8, FP32)
    wantr = np.fft.ifft(np.fft.fft(ar) * np.fft.fft(br)).real
    np.testing.assert_allclose(resr.output, wantr, rtol=1e-9, atol=1e-9)


def test_partitions_reduce_latency_monotonically():
    n = 16384  # beta = 8
    lats = [fft_latency_cycles(n, with_partitions(FOURIERPIM_8, p), FP16)
            for p in (1, 2, 4)]
    assert lats[0] > lats[1] > lats[2]
    # speedup cannot exceed p
    assert lats[0] / lats[2] <= 4.0 + 1e-9


def test_partition_area_restriction_footnote7():
    """Full-precision n=8K admits 2 partitions but scratch at p=4 spills;
    n=16K full occupies the whole data width (restricted dimensions)."""
    w = complex_word_bits(FP32)
    cfg4 = with_partitions(FOURIERPIM_8, 4)
    assert cfg4.crossbars_per_fft(8192, w) > 1.0
    cfg2 = with_partitions(FOURIERPIM_8, 2)
    assert cfg2.crossbars_per_fft(8192, w) <= 1.0
    assert FOURIERPIM_8.valid_config(16384, w)
    assert not FOURIERPIM_8.valid_config(32768, w)  # future work: multi-xbar


# ---------------------------------------------------------------------------
# Real-Hermitian path: pim_rfft + the paired-inverse real polymul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [FP32, FP16])
@pytest.mark.parametrize("n", [1024, 2048, 4096])
def test_pim_rfft_values_and_counter_parity(rng, n, spec):
    """Two real sequences via one packed complex FFT: half-spectra match
    numpy, simulator counters == the closed form."""
    x, y = rng.standard_normal(n), rng.standard_normal(n)
    res = pim_rfft(x, y, FOURIERPIM_8, spec)
    np.testing.assert_allclose(res.spectra[0], np.fft.rfft(x), rtol=1e-9,
                               atol=1e-8)
    np.testing.assert_allclose(res.spectra[1], np.fft.rfft(y), rtol=1e-9,
                               atol=1e-8)
    assert res.counters.cycles == rfft_latency_cycles(n, FOURIERPIM_8, spec)


def test_pim_rfft_throughput_near_2x_fft():
    """Each schedule slot carries two real sequences: throughput is ~2x the
    complex FFT's (slightly under — the unpack pass is not free)."""
    for n in (2048, 4096):
        ratio = (rfft_throughput_per_s(n, FOURIERPIM_8, FP32)
                 / fft_throughput_per_s(n, FOURIERPIM_8, FP32))
        assert 1.9 < ratio < 2.0, ratio


@pytest.mark.parametrize("batch", [2, 4, 5])
def test_polymul_real_paired_counter_parity_and_values(rng, batch):
    """(B, n) batches share one inverse per product pair: counters == the
    batch closed form, values still match numpy per row (the Re/Im split of
    the packed inverse is exact for Hermitian product spectra)."""
    n = 2048
    a = rng.standard_normal((batch, n))
    b = rng.standard_normal((batch, n))
    res = pim_polymul_real(a, b, FOURIERPIM_8, FP32)
    assert res.counters.cycles == polymul_real_batch_latency_cycles(
        n, batch, FOURIERPIM_8, FP32)
    want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b)).real
    np.testing.assert_allclose(res.output, want, rtol=1e-9, atol=1e-8)


def test_polymul_real_pair_parity_direct(rng):
    """The (2, n) pair IS the closed-form unit: sim counters == pair form,
    and the pair is strictly cheaper than two unpaired products."""
    n = 4096
    a = rng.standard_normal((2, n))
    b = rng.standard_normal((2, n))
    res = pim_polymul_real(a, b, FOURIERPIM_8, FP32)
    pair = polymul_real_pair_latency_cycles(n, FOURIERPIM_8, FP32)
    assert res.counters.cycles == pair
    assert pair < 2 * polymul_latency_cycles(n, FOURIERPIM_8, FP32,
                                             real=True)


@pytest.mark.parametrize("spec", [FP32, FP16])
@pytest.mark.parametrize("n", [1024, 4096])
def test_real_complex_cycle_ratio_gate(n, spec):
    """THE acceptance gate (the same constant benchmarks/run.py --smoke /
    BENCH_fourier.json enforces): per-product simulated cycles of the
    paired real polymul <= 0.65x the complex fused polymul."""
    from benchmarks.run import REAL_COMPLEX_CYCLE_GATE
    pair = polymul_real_pair_latency_cycles(n, FOURIERPIM_8, spec)
    cplx = polymul_latency_cycles(n, FOURIERPIM_8, spec)
    ratio = pair / (2 * cplx)
    assert ratio <= REAL_COMPLEX_CYCLE_GATE, (n, spec, ratio)


def test_real_polymul_throughput_beats_complex():
    """Amortized pair latency + halved operand area: the real path's
    products/s must beat the complex path's by well over the paper's
    per-transform ratio."""
    for n in (2048, 8192):
        r = polymul_throughput_per_s(n, FOURIERPIM_8, FP32, real=True)
        c = polymul_throughput_per_s(n, FOURIERPIM_8, FP32)
        assert r > 1.5 * c, (n, r / c)


def test_real_polymul_cheaper_than_complex():
    """Eq. (10) packing: one forward transform instead of two."""
    n = 8192
    c = polymul_latency_cycles(n, FOURIERPIM_8, FP32, real=False)
    r = polymul_latency_cycles(n, FOURIERPIM_8, FP32, real=True)
    assert r < c
    # it must save close to one forward FFT
    fwd = fft_latency_cycles(n, FOURIERPIM_8, FP32, charge_perm=False)
    assert c - r > 0.8 * fwd


def test_polymul_skips_input_permutations():
    """Permutation cancellation (§5): polymul < 3 x (FFT incl. perm)."""
    n = 4096
    with_perm = fft_latency_cycles(n, FOURIERPIM_8, FP32, charge_perm=True)
    no_perm = fft_latency_cycles(n, FOURIERPIM_8, FP32, charge_perm=False)
    assert no_perm < with_perm
    pm = polymul_latency_cycles(n, FOURIERPIM_8, FP32)
    # exact structure: 2 fwd + 1 inv permutation-free transforms + the
    # pointwise cmul serialized over the beta units.
    inv_np = fft_latency_cycles(n, FOURIERPIM_8, FP32, charge_perm=False,
                                inverse=True)
    serial = n // (2 * FOURIERPIM_8.crossbar_rows)
    assert pm == 2 * no_perm + inv_np + serial * aritpim.complex_mul_cycles(FP32)


def test_throughput_trends():
    """Paper Fig. 5: no-partition throughput falls ~linearly in n (serial
    beta units); with partitions >= beta it falls ~logarithmically."""
    full = [fft_throughput_per_s(n, FOURIERPIM_8, FP16)
            for n in (2048, 4096, 8192)]
    assert full[0] / full[2] > 3.0      # ~linear: 4x dims -> >3x drop
    cfg = with_partitions(FOURIERPIM_8, 4)
    part = [fft_throughput_per_s(n, cfg, FP16) for n in (2048, 4096, 8192)]
    assert part[0] / part[2] < full[0] / full[2]  # partitions flatten it


def test_reproduction_bands():
    """Headline claims (§6): throughput and energy ratios land in the
    paper's reported bands (5-15x thr, 4-13x energy, per-config claims
    validated in EXPERIMENTS.md)."""
    from benchmarks import fft_pim_bench
    ratios = fft_pim_bench.run()
    # full precision, partitions: "up to 5x vs RTX 3070, up to 7x vs A100"
    best_thr8 = max(r["thr8_vs_3070"] for (p, n), r in ratios.items()
                    if p == "full" and n <= 8192)
    best_thr40 = max(r["thr40_vs_A100"] for (p, n), r in ratios.items()
                     if p == "full" and n <= 8192)
    assert 4.0 <= best_thr8 <= 6.5, best_thr8
    assert 5.5 <= best_thr40 <= 8.5, best_thr40
    # half precision: "6x vs 3070, 9x vs A100"
    bh8 = max(r["thr8_vs_3070"] for (p, n), r in ratios.items()
              if p == "half")
    bh40 = max(r["thr40_vs_A100"] for (p, n), r in ratios.items()
               if p == "half")
    assert 5.0 <= bh8 <= 8.5, bh8
    assert 7.5 <= bh40 <= 12.0, bh40
    # energy: 4-13x bands (allow the 16K smem-regime outlier vs 3070)
    e_a100 = [r["energy_vs_A100"] for (p, n), r in ratios.items()]
    assert all(2.5 <= e <= 13.0 for e in e_a100), e_a100


def test_gpu_model_memory_bound_regimes():
    """Fig. 1 / footnote 8: single smem pass for small n, 2 passes at 16K
    full precision on the 3070 (the 'different linear trend'), A100's larger
    smem keeps 16K single-pass."""
    assert RTX3070.fft_passes(8192, 8) == 1
    assert RTX3070.fft_passes(16384, 8) == 2
    assert A100.fft_passes(16384, 8) == 1
    # GPU half precision gains exactly 2x (memory bound), paper §6
    full = gpu_model.fft_throughput_per_s(8192, RTX3070, 8)
    half = gpu_model.fft_throughput_per_s(8192, RTX3070, 4)
    assert abs(half / full - 2.0) < 1e-9


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([1024, 2048, 4096]), seed=st.integers(0, 2**31 - 1))
def test_pim_fft_property(n, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal(n) + 1j * r.standard_normal(n)
    res = pim_fft(x, FOURIERPIM_8, FP32)
    np.testing.assert_allclose(res.output, np.fft.fft(x), rtol=1e-9,
                               atol=1e-8)


# ---------------------------------------------------------------------------
# Bit-exact stateful-logic microcheck: a NOR-only ripple adder (MAGIC [20])
# validates the structural assumption behind fixed_add_cycles ~ 9N (the
# 9-gate NOR full adder is the known optimum; this 12-gate netlist is the
# straightforward construction and bounds it).
# ---------------------------------------------------------------------------

def _nor(x, y):
    return ~(x | y) & 1


def _full_adder_nor(a, b, cin):
    g1 = _nor(a, b)
    g2 = _nor(a, g1)          # ~a & b
    g3 = _nor(b, g1)          # a & ~b
    g4 = _nor(g2, g3)         # XNOR(a, b)
    g5 = _nor(g4, cin)        # XOR(a,b) & ~cin
    cout = _nor(g1, g5)       # = majority(a, b, cin)
    g6 = _nor(g4, g4)         # ~XNOR = XOR(a, b)
    g7 = _nor(cin, cin)       # ~cin
    g8 = _nor(g6, g7)         # ~(XOR | ~cin) = XNOR & cin
    g9 = _nor(g5, g8)         # ~(sum):  sum = g5 | g8
    summ = _nor(g9, g9)
    return summ, cout, 10     # gate count of this construction


def test_nor_full_adder_exhaustive():
    for a in (0, 1):
        for b in (0, 1):
            for cin in (0, 1):
                s, cout, gates = _full_adder_nor(a, b, cin)
                assert s == (a ^ b ^ cin), (a, b, cin)
                assert cout == ((a & b) | (cin & (a ^ b))), (a, b, cin)
    # cost model charges 9 gates/bit: the literature's optimal MAGIC FA;
    # our naive netlist (12) bounds it within ~33%.
    assert 9 <= gates <= 13


def test_nor_ripple_adder_matches_integer_add(rng):
    for _ in range(20):
        n = 16
        x, y = int(rng.integers(0, 2**n)), int(rng.integers(0, 2**n))
        cin = 0
        s_bits = []
        for i in range(n):
            s, cin, _ = _full_adder_nor((x >> i) & 1, (y >> i) & 1, cin)
            s_bits.append(s)
        got = sum(b << i for i, b in enumerate(s_bits)) + (cin << n)
        assert got == x + y


# ---------------------------------------------------------------------------
# Distributed real-Hermitian path (four-step across crossbar arrays)
# ---------------------------------------------------------------------------

def test_pim_rfft_distributed_matches_numpy_and_closed_forms(rng):
    """Value-exact vs np.fft.rfft, every shard's cycle counter == the
    closed form, and the byte fields == their closed forms — the PIM side
    of the distributed-rfft cost-model contract (the TPU-ledger side lives
    in tests/test_dist_real.py)."""
    from repro.core.pim import (fft_distributed_a2a_bytes,
                                fft_distributed_latency_cycles,
                                pim_rfft_distributed,
                                rfft_distributed_a2a_bytes,
                                rfft_distributed_latency_cycles,
                                rfft_distributed_permute_bytes)
    for D in (2, 8):
        n = D * FOURIERPIM_8.crossbar_rows
        x = rng.standard_normal(n)
        y = rng.standard_normal(n)
        res = pim_rfft_distributed(x, y, D, FOURIERPIM_8, FP32)
        want = np.stack([np.fft.rfft(x), np.fft.rfft(y)])
        assert np.max(np.abs(res.spectra - want)) < 1e-8 * np.max(np.abs(want))
        closed = rfft_distributed_latency_cycles(n, D, FOURIERPIM_8, FP32)
        assert {c.cycles for c in res.shard_counters} == {closed}
        assert res.a2a_bytes == rfft_distributed_a2a_bytes(n, FP32)
        assert res.permute_bytes == rfft_distributed_permute_bytes(n, FP32)
        # the split charge is the only delta on top of the complex closed form
        assert closed > fft_distributed_latency_cycles(n, D, FOURIERPIM_8,
                                                       FP32)


def test_pim_rfft_distributed_byte_ratio_gate(rng):
    """Total interconnect bytes (transposes + conjugate-bin permute) of the
    packed real four-step stay <= 0.6x the complex distributed path for the
    same two real sequences — the tentpole's traffic target, in the PIM
    model's whole-array byte unit."""
    from repro.core.pim import (fft_distributed_a2a_bytes,
                                rfft_distributed_a2a_bytes,
                                rfft_distributed_permute_bytes)
    for n in (2048, 8192, 1 << 20):
        real = (rfft_distributed_a2a_bytes(n, FP32)
                + rfft_distributed_permute_bytes(n, FP32))
        cplx = 2 * fft_distributed_a2a_bytes(n, FP32)   # one per sequence
        assert real / cplx <= 0.6, (n, real / cplx)
        # unordered complex transform is cheaper (Z-order output, 2 moves)
        assert fft_distributed_a2a_bytes(n, FP32, ordered=False) \
            < fft_distributed_a2a_bytes(n, FP32)


def test_pim_rfft_distributed_rejects_bad_shard_counts(rng):
    from repro.core.pim import pim_rfft_distributed
    n = 2 * FOURIERPIM_8.crossbar_rows
    x = rng.standard_normal(n)
    for bad in (1, 3):
        with pytest.raises(ValueError):
            pim_rfft_distributed(x, x, bad, FOURIERPIM_8, FP32)
