"""Substrate tests: optimizer (incl. int8 state), data pipeline determinism,
checkpoint atomicity/roundtrip/elastic-reshard, watchdog, gradient
compression, end-to-end training loss decrease."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.ft import checkpoint as ckpt
from repro.ft.watchdog import StepWatchdog, WatchdogConfig
from repro.optim import adamw


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.asarray([2.0, -3.0, 1.5]), "b": jnp.asarray(4.0)}
    target = {"w": jnp.asarray([0.5, 0.5, 0.5]), "b": jnp.asarray(0.0)}

    def loss(p):
        return (jnp.sum((p["w"] - target["w"]) ** 2)
                + (p["b"] - target["b"]) ** 2)
    return params, loss


@pytest.mark.parametrize("state_dtype", ["float32", "int8"])
def test_adamw_converges(state_dtype):
    params, loss = _quad_problem()
    cfg = adamw.OptConfig(lr=5e-2, weight_decay=0.0, warmup_steps=0,
                          total_steps=400, state_dtype=state_dtype)
    state = adamw.init_state(params, cfg)
    step = jax.jit(lambda p, s: adamw.apply(
        p, jax.grad(loss)(p), s, cfg))
    for _ in range(400):
        params, state = step(params, state)
    assert float(loss(params)) < 1e-2, float(loss(params))


def test_int8_state_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 300)),
                    jnp.float32)
    q = adamw._quantize(x)
    y = adamw._dequantize(q, 300)
    assert y.shape == x.shape
    # blockwise int8: ~1% of per-block max
    err = np.max(np.abs(np.asarray(y - x)))
    assert err <= np.max(np.abs(np.asarray(x))) / 127.0 * 1.01


def test_lr_schedule_shape():
    cfg = adamw.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(adamw.lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-3)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    d1 = SyntheticLM(1000, 32, 8, seed=3)
    d2 = SyntheticLM(1000, 32, 8, seed=3)
    b5a = d1.batch_at(5)
    b5b = d2.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticLM(1000, 32, 8, seed=3, n_hosts=2, host_id=0)
    h1 = SyntheticLM(1000, 32, 8, seed=3, n_hosts=2, host_id=1)
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_pipeline_learnable_structure():
    """Next token is predictable from the current one most of the time."""
    d = SyntheticLM(997, 64, 4, seed=0)
    b = d.batch_at(0)
    diffs = (b["labels"] - b["tokens"]) % 997
    # each row has a single dominant delta
    for row in diffs:
        vals, counts = np.unique(row, return_counts=True)
        assert counts.max() / row.size > 0.8


def test_prefetcher_orders_steps():
    d = SyntheticLM(100, 8, 2, seed=1)
    pf = Prefetcher(d, start_step=7)
    try:
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (7, 8)
        np.testing.assert_array_equal(b0["tokens"], d.batch_at(7)["tokens"])
    finally:
        pf.stop()


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.asarray(1.5)},
            "opt": {"m": jnp.ones((3, 4)), "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 10, t)
    step, restored = ckpt.restore_latest(str(tmp_path), t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_atomicity(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]
    # simulate a crashed partial write
    os.makedirs(tmp_path / "step_99.tmp")
    (tmp_path / "step_99.tmp" / "garbage").write_text("x")
    ckpt.save(str(tmp_path), 6, t, keep=2)
    assert not (tmp_path / "step_99.tmp").exists()
    assert ckpt.latest_step(str(tmp_path)) == 6


@pytest.mark.dist
def test_checkpoint_elastic_reshard_subprocess(tmp_path):
    """Save under an 8-device mesh sharding, restore under 4 devices."""
    from conftest import run_in_subprocess_devices
    out = run_in_subprocess_devices(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ft import checkpoint as ckpt

mesh8 = jax.make_mesh((8,), ("data",))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
ckpt.save(r"{tmp_path}", 1, {{"x": xs}})

mesh4 = jax.make_mesh((4, 2), ("data", "model"))
sh = {{"x": NamedSharding(mesh4, P("model", "data"))}}
step, restored = ckpt.restore_latest(r"{tmp_path}", {{"x": x}}, sh)
assert step == 1
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
assert restored["x"].sharding.spec == P("model", "data")
print("OK")
""", n_devices=8)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_stragglers_and_evicts():
    evicted = []
    wd = StepWatchdog(WatchdogConfig(warmup_steps=2, threshold=2.0,
                                     evict_after=2),
                      on_evict=evicted.append)
    for s in range(5):
        assert not wd.observe(s, 1.0)
    assert wd.observe(5, 5.0)       # straggler
    assert wd.observe(6, 5.0)       # second consecutive -> evict
    assert evicted == [6]
    assert not wd.observe(7, 1.0)   # recovers
    # EWMA unpoisoned by straggler steps
    assert abs(wd.ewma - 1.0) < 0.1


# ---------------------------------------------------------------------------
# Gradient compression (error feedback)
# ---------------------------------------------------------------------------

@pytest.mark.dist
def test_compressed_psum_error_feedback_subprocess():
    from conftest import run_in_subprocess_devices
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist.collectives import compressed_psum_leaf
from repro.dist.compat import shard_map

mesh = jax.make_mesh((4,), ("pod",))
g = jnp.asarray(np.random.default_rng(0).standard_normal((4, 512)),
                jnp.float32)

def f(g_local, err):
    red, new_err = compressed_psum_leaf(g_local[0], err[0], "pod")
    return red[None], new_err[None]

fn = shard_map(f, mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
               out_specs=(P("pod", None), P("pod", None)), check_vma=False)
err0 = jnp.zeros_like(g)
red, err = jax.jit(fn)(g, err0)
true_mean = np.mean(np.asarray(g), axis=0)
got = np.asarray(red)[0]
rel = np.max(np.abs(got - true_mean)) / (np.max(np.abs(true_mean)) + 1e-9)
assert rel < 0.05, rel
# error feedback: residual equals what quantization dropped
assert np.max(np.abs(np.asarray(err))) < np.max(np.abs(np.asarray(g))) / 64
print("OK")
""", n_devices=4)
    assert "OK" in out


@pytest.mark.dist
def test_train_step_compressed_psum_pod_mesh_subprocess():
    """The ROADMAP wiring: ``make_train_step(pod_axis=...)`` runs the full
    LM step inside shard_map over a 4-pod mesh, reducing gradients through
    ``dist.collectives.compressed_psum``. Loss decreases, the error-feedback
    residual is carried (nonzero after a step), and metrics come back
    pod-averaged."""
    from conftest import run_in_subprocess_devices
    out = run_in_subprocess_devices("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist import collectives
from repro.dist.compat import shard_map
from repro.models import lm
from repro.optim import adamw
from repro.train import step as step_lib

mesh = jax.make_mesh((4,), ("pod",))
cfg = dataclasses.replace(get_config("qwen3-1.7b").scaled_down(),
                          max_seq_len=32)
opt_cfg = adamw.OptConfig(lr=3e-3, warmup_steps=0, total_steps=20)
params = lm.init_params(cfg, jax.random.key(0))
opt_state = adamw.init_state(params, opt_cfg)
errs = collectives.zeros_like_errs(params)
step = step_lib.make_train_step(cfg, opt_cfg, pod_axis="pod")
fn = jax.jit(shard_map(step, mesh=mesh,
                       in_specs=(P(), P(), P(), P("pod")),
                       out_specs=(P(), P(), P(), P()), check_vma=False))
data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
batch0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
# the compression wire format actually goes over the pod axis: one
# compressed-psum record per gradient leaf (ledger records at trace time,
# so probe BEFORE the jit cache is warm).
with collectives.ledger() as led:
    fn.lower(params, opt_state, errs,
             {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in batch0.items()})
assert led.counts["compressed-psum"] == len(jax.tree.leaves(params))
losses = []
for s in range(8):
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
    params, opt_state, errs, metrics = fn(params, opt_state, errs, batch)
    losses.append(float(metrics["loss"]))
assert min(losses[3:]) < losses[0] - 0.3, losses
err_max = max(float(jnp.max(jnp.abs(e))) for e in jax.tree.leaves(errs))
assert err_max > 0, "error-feedback residual must be carried"
print("OK", round(losses[0], 3), "->", round(min(losses), 3))
""", n_devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# End-to-end: training loss decreases & resume continuity
# ---------------------------------------------------------------------------

def test_train_loop_loss_decreases(tmp_path):
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "qwen3-1.7b", "--smoke", "--steps", "40",
        "--batch", "8", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "20"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_train_resume_continues(tmp_path):
    from repro.launch import train as train_mod
    train_mod.main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "20",
                    "--batch", "4", "--seq", "32",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"])
    losses2 = train_mod.main(
        ["--arch", "qwen3-1.7b", "--smoke", "--steps", "30",
         "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path)])
    # resumed run only covers steps 20..30
    assert len(losses2) == 10


@pytest.mark.dist
def test_train_compress_grads_flag_subprocess():
    """The launch surface for the pod-mesh compressed step (ROADMAP
    leftover): ``--mesh PxDxM --compress-grads`` trains end-to-end on a
    2-pod virtual mesh through the int8 error-feedback psum, and the flag
    without a pod axis is rejected loudly."""
    from conftest import run_in_subprocess_devices
    out = run_in_subprocess_devices("""
import numpy as np
from repro.launch import train as train_mod
losses = train_mod.main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "12",
                         "--batch", "8", "--seq", "32",
                         "--mesh", "2x2x1", "--compress-grads"])
assert len(losses) == 12, losses
assert all(l == l for l in losses), f"NaN loss: {losses}"
assert losses[-1] < losses[0], losses
# Data-axis reduction pin: the same global batch over (pod=2, data=1) and
# (pod=2, data=2) must follow the same trajectory — the per-pod gradient
# is the intra-pod data MEAN, so splitting a pod's batch across two data
# shards changes the layout, not the math. Before the data_axis reduction
# was wired, each data shard applied only its own half-batch gradient and
# the trajectories diverged.
losses_d1 = train_mod.main(["--arch", "qwen3-1.7b", "--smoke", "--steps",
                            "6", "--batch", "8", "--seq", "32",
                            "--mesh", "2x1x1", "--compress-grads"])
losses_d2 = train_mod.main(["--arch", "qwen3-1.7b", "--smoke", "--steps",
                            "6", "--batch", "8", "--seq", "32",
                            "--mesh", "2x2x1", "--compress-grads"])
diff = float(np.max(np.abs(np.array(losses_d1) - np.array(losses_d2))))
assert diff < 1e-3, (losses_d1, losses_d2)
try:
    train_mod.main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "1",
                    "--batch", "4", "--seq", "32",
                    "--mesh", "2x2", "--compress-grads"])
except SystemExit:
    pass
else:
    raise AssertionError("--compress-grads without a pod axis should error")
print("OK", round(losses[0], 3), "->", round(losses[-1], 3),
      "dp-diff", diff)
""", n_devices=4)
    assert "OK" in out
