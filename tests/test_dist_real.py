"""Distributed real-Hermitian tier: differential tests + ledger parity.

Unit half (single real device, D=1 mesh): twiddle-precision regression,
values vs numpy, ledger == closed form, planner routing and shape guards.
Dist half (subprocess, 8 virtual devices): the same contracts at D=8,
n in {2^12, 2^20}, plus the serve endpoint.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_in_subprocess_devices
from repro.core import fft as fft_core
from repro.core.fft import distributed as dfft
from repro.dist import collectives


def _packed_ref(x: np.ndarray) -> np.ndarray:
    """np.fft.rfft in the kernels' packed-Nyquist layout (B, n/2)."""
    n = x.shape[-1]
    full = np.fft.rfft(x.astype(np.float64))
    packed = full[..., :n // 2].copy()
    packed[..., 0] = full[..., 0].real + 1j * full[..., n // 2].real
    return packed


def _circular_ref(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Circular product via the f64 FFT oracle."""
    return np.fft.ifft(np.fft.fft(a.astype(np.float64))
                       * np.fft.fft(b.astype(np.float64))).real


# ---------------------------------------------------------------------------
# fp32-twiddle regression (the PR-5 bugfix pin)
# ---------------------------------------------------------------------------

def test_fp32_twiddle_regression_exact_integer_exponents():
    """The step-3 twiddle block must match float64 ground truth to
    ~fp32-rounding accuracy at large n.

    The pre-fix code built the angles as ``2*pi*(k1*j2)/n`` with float32
    ``k1*j2`` products and a separately rounded device-phase factor inside
    the trace — several f32 roundings per twiddle, ~4e-7 worst-case error
    (the first assert reproduces that formula and pins the failure). The
    fixed path reduces exponents mod n in int64 and evaluates angles in
    float64 host-side, rounding ONCE to complex64 (~4e-8): the 1.5e-7
    bound below fails on the pre-fix computation and passes post-fix.
    """
    n, D = 1 << 20, 8
    n1, width = D, (n // D) // D
    worst_prefix, worst_fixed = 0.0, 0.0
    for idx in range(D):
        k1i = np.arange(n1, dtype=np.int64)[:, None]
        j2i = (idx * width + np.arange(width, dtype=np.int64))[None, :]
        truth = np.exp(-2j * np.pi * ((k1i * j2i) % n) / n)

        # The pre-fix formula: f32 products, f32 angles, two rounded factors.
        k1 = jnp.arange(n1, dtype=jnp.float32)[:, None]
        j2 = jnp.arange(width, dtype=jnp.float32)[None, :]
        ang = -2.0 * jnp.pi * (k1 * j2) / n
        tw = jnp.cos(ang) + 1j * jnp.sin(ang)
        ang2 = -2.0 * jnp.pi * k1[:, 0] * (np.float32(idx) * width) / n
        phase = (jnp.cos(ang2) + 1j * jnp.sin(ang2))[:, None]
        worst_prefix = max(worst_prefix,
                           float(np.max(np.abs(np.asarray(tw * phase)
                                               - truth))))

        fixed = np.asarray(dfft._twiddle(n, n1, width, jnp.int32(idx),
                                         inverse=False))
        worst_fixed = max(worst_fixed,
                          float(np.max(np.abs(fixed - truth))))
    assert worst_prefix > 2.5e-7, \
        f"pre-fix formula unexpectedly accurate ({worst_prefix:.2e}) — " \
        f"did the bug this test documents get re-fixed upstream?"
    assert worst_fixed < 1.5e-7, \
        f"twiddle block drifted from f64 ground truth: {worst_fixed:.2e}"


def test_twiddle_forward_inverse_conjugate():
    n, D = 4096, 8
    fwd = np.asarray(dfft._twiddle(n, D, (n // D) // D, jnp.int32(3), False))
    inv = np.asarray(dfft._twiddle(n, D, (n // D) // D, jnp.int32(3), True))
    np.testing.assert_allclose(inv, np.conj(fwd), atol=1e-7)


# ---------------------------------------------------------------------------
# Shape guards (the silent-truncation bugfix pin)
# ---------------------------------------------------------------------------

def test_four_step_shape_guard():
    dfft.check_four_step_shape(512, 8)            # D^2 = 64 | 512
    dfft.check_four_step_shape(1024, 8, real=True)  # 2 D^2 = 128 | 1024
    for n, d, real in ((32, 8, False), (96, 8, False), (8, 4, False),
                       (64, 8, True), (2048, 3, False)):
        with pytest.raises(ValueError, match="four-step"):
            dfft.check_four_step_shape(n, d, real=real)


def test_planner_rejects_untileable_distributed_shapes():
    big = 1 << 19   # above the local VMEM ceiling -> distributed tier
    plan = fft_core.plan(big, 4, model_shards=8)
    assert plan.tier == "distributed" and not plan.real
    plan = fft_core.plan(big, 4, model_shards=8, real=True)
    assert plan.tier == "distributed" and plan.real
    with pytest.raises(ValueError, match="cannot plan"):
        fft_core.plan(big, 4, model_shards=3)
    with pytest.raises(ValueError, match="cannot plan"):
        fft_core.plan(big, 4, model_shards=3, real=True)
    # below the ceiling the planner keeps the local tier regardless of D
    assert fft_core.plan(4096, 4, model_shards=3).tier == "local"


def test_distributed_real_pads_odd_batch(rng):
    # Odd global batches no longer raise: the wrapper pads one zero
    # partner row before shard_map (Eq.-10 pairing is linear, the padded
    # row's result is discarded) and slices it off on return.
    mesh = jax.make_mesh((1,), ("model",))
    x = rng.standard_normal((3, 256)).astype(np.float32)
    p = np.asarray(jax.jit(dfft.make_sharded_rfft(mesh, batch_axes=()))(
        jnp.asarray(x)))
    assert p.shape == (3, 128)
    ref = _packed_ref(x)
    assert np.max(np.abs(p - ref)) / np.max(np.abs(ref)) < 1e-5


# ---------------------------------------------------------------------------
# Values + ledger on the single-device mesh (unit tier)
# ---------------------------------------------------------------------------

def test_rfft_irfft_polymul_distributed_single_device(rng):
    mesh = jax.make_mesh((1,), ("model",))
    B, n = 4, 512
    x = rng.standard_normal((B, n)).astype(np.float32)
    p = np.asarray(jax.jit(dfft.make_sharded_rfft(mesh, batch_axes=()))(
        jnp.asarray(x)))
    assert p.shape == (B, n // 2) and p.dtype == np.complex64
    ref = _packed_ref(x)
    assert np.max(np.abs(p - ref)) / np.max(np.abs(ref)) < 1e-5
    # the packed layout converts with the kernels' own converter
    half = np.asarray(fft_core.packed_to_halfspec(jnp.real(jnp.asarray(p)),
                                                  jnp.imag(jnp.asarray(p))))
    np.testing.assert_allclose(half, np.fft.rfft(x.astype(np.float64)),
                               atol=2e-3)
    back = np.asarray(jax.jit(dfft.make_sharded_irfft(mesh, batch_axes=()))(
        jnp.asarray(p)))
    assert back.dtype == np.float32
    assert np.max(np.abs(back - x)) < 1e-5

    a = rng.standard_normal((B, n)).astype(np.float32)
    b = rng.standard_normal((B, n)).astype(np.float32)
    c = np.asarray(jax.jit(dfft.make_sharded_polymul_real(
        mesh, batch_axes=()))(jnp.asarray(a), jnp.asarray(b)))
    want = _circular_ref(a, b)
    assert np.max(np.abs(c - want)) / np.max(np.abs(want)) < 1e-5


def test_dist_real_ledger_parity_single_device():
    mesh = jax.make_mesh((1,), ("model",))
    B, n = 6, 1024
    rspec = jax.ShapeDtypeStruct((B, n), jnp.float32)
    pspec = jax.ShapeDtypeStruct((B, n // 2), jnp.complex64)
    cases = (
        ("rfft", dfft.make_sharded_rfft(mesh, batch_axes=()), (rspec,)),
        ("irfft", dfft.make_sharded_irfft(mesh, batch_axes=()), (pspec,)),
        ("polymul_real", dfft.make_sharded_polymul_real(mesh, batch_axes=()),
         (rspec, rspec)),
    )
    for op, fn, args in cases:
        with collectives.ledger() as led:
            jax.jit(fn).lower(*args)
        want = dfft.four_step_collective_stats(n, B, 1, op=op)
        assert led.counts["all-to-all"] == want["a2a_count"], (op, led.as_dict())
        assert led.bytes_by_kind["all-to-all"] == want["a2a_bytes"], \
            (op, led.as_dict())
        assert led.counts["ppermute"] == want["ppermute_count"], \
            (op, led.as_dict())
        assert led.bytes_by_kind["ppermute"] == want["ppermute_bytes"], \
            (op, led.as_dict())


def test_collective_stats_real_vs_complex_ratio():
    for n, B, D in ((4096, 4, 1), (4096, 8, 8), (1 << 20, 2, 8)):
        rfft = dfft.four_step_collective_stats(n, B, D, op="rfft")
        fft = dfft.four_step_collective_stats(n, B, D, op="fft")
        pm_r = dfft.four_step_collective_stats(n, B, D, op="polymul_real")
        pm_c = dfft.four_step_collective_stats(n, B, D, op="polymul")
        assert rfft["total_bytes"] / fft["total_bytes"] <= 0.6
        assert pm_r["total_bytes"] / pm_c["total_bytes"] <= 0.6
    with pytest.raises(ValueError, match="even"):
        dfft.four_step_collective_stats(4096, 3, 8, op="rfft")
    with pytest.raises(ValueError, match="unknown op"):
        dfft.four_step_collective_stats(4096, 2, 8, op="nope")


# ---------------------------------------------------------------------------
# 8-virtual-device tier (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.dist
def test_dist_real_differential_8dev():
    """rfft vs np.fft.rfft, irfft roundtrip, and polymul_real vs the
    schoolbook circular product at n = 2^12 on a (data, model) mesh."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.fft import distributed as dfft

mesh = jax.make_mesh((1, 8), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
rng = np.random.default_rng(0)
B, n = 4, 4096
x = rng.standard_normal((B, n)).astype(np.float32)
xj = jax.device_put(jnp.asarray(x), sh)

p = np.asarray(jax.jit(dfft.make_sharded_rfft(mesh))(xj))
full = np.fft.rfft(x.astype(np.float64))
packed = full[:, :n//2].copy()
packed[:, 0] = full[:, 0].real + 1j * full[:, n//2].real
err = np.max(np.abs(p - packed)) / np.max(np.abs(packed))
assert err < 1e-5, f"rfft err {err}"

back = np.asarray(jax.jit(dfft.make_sharded_irfft(mesh))(
    jax.device_put(jnp.asarray(p), sh)))
err = np.max(np.abs(back - x))
assert err < 1e-4, f"irfft roundtrip err {err}"

a = rng.standard_normal((B, n)).astype(np.float32)
b = rng.standard_normal((B, n)).astype(np.float32)
c = np.asarray(jax.jit(dfft.make_sharded_polymul_real(mesh))(
    jax.device_put(jnp.asarray(a), sh), jax.device_put(jnp.asarray(b), sh)))
# schoolbook circular product (linear convolve in f64, folded mod x^n - 1)
want = np.empty((B, n))
for i in range(B):
    lin = np.convolve(a[i].astype(np.float64), b[i].astype(np.float64))
    want[i] = lin[:n] + np.concatenate([lin[n:], [0.0]])
err = np.max(np.abs(c - want)) / np.max(np.abs(want))
assert err < 1e-5, f"polymul err {err}"

# divisibility guard fires loudly at call time
try:
    dfft.make_sharded_fft(mesh)(jnp.zeros((1, 32), jnp.complex64))
except ValueError as e:
    assert "four-step" in str(e)
else:
    raise AssertionError("n=32 over D=8 should be rejected")
print("OK")
""", n_devices=8)
    assert "OK" in out


@pytest.mark.dist
def test_dist_real_large_n_8dev():
    """The serving shape the distributed tier exists for: n = 2^20 over 8
    shards stays within fp32 tolerance of the f64 numpy oracle (this is
    the end-to-end side of the fp32-twiddle regression pin)."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.fft import distributed as dfft

mesh = jax.make_mesh((1, 8), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
rng = np.random.default_rng(0)
B, n = 2, 1 << 20
x = rng.standard_normal((B, n)).astype(np.float32)
p = np.asarray(jax.jit(dfft.make_sharded_rfft(mesh))(
    jax.device_put(jnp.asarray(x), sh)))
full = np.fft.rfft(x.astype(np.float64))
packed = full[:, :n//2].copy()
packed[:, 0] = full[:, 0].real + 1j * full[:, n//2].real
err = np.max(np.abs(p - packed)) / np.max(np.abs(packed))
assert err < 2e-6, f"rfft n=2^20 err {err}"

a = rng.standard_normal((B, n)).astype(np.float32)
b = rng.standard_normal((B, n)).astype(np.float32)
c = np.asarray(jax.jit(dfft.make_sharded_polymul_real(mesh))(
    jax.device_put(jnp.asarray(a), sh), jax.device_put(jnp.asarray(b), sh)))
want = np.fft.ifft(np.fft.fft(a.astype(np.float64))
                   * np.fft.fft(b.astype(np.float64))).real
err = np.max(np.abs(c - want)) / np.max(np.abs(want))
assert err < 2e-6, f"polymul n=2^20 err {err}"
print("OK")
""", n_devices=8)
    assert "OK" in out


@pytest.mark.dist
def test_dist_real_ledger_parity_8dev():
    """Byte-ledger == closed form at D=8, and the real/complex total-byte
    ratio holds the <= 0.6 gate (the tentpole's traffic contract)."""
    out = run_in_subprocess_devices("""
import jax, jax.numpy as jnp
from repro.core.fft import distributed as dfft
from repro.dist import collectives

mesh = jax.make_mesh((1, 8), ("data", "model"))
B, n, D = 4, 4096, 8
rspec = jax.ShapeDtypeStruct((B, n), jnp.float32)
pspec = jax.ShapeDtypeStruct((B, n // 2), jnp.complex64)
for op, fn, args in (
        ("rfft", dfft.make_sharded_rfft(mesh), (rspec,)),
        ("irfft", dfft.make_sharded_irfft(mesh), (pspec,)),
        ("polymul_real", dfft.make_sharded_polymul_real(mesh),
         (rspec, rspec))):
    with collectives.ledger() as led:
        jax.jit(fn).lower(*args)
    want = dfft.four_step_collective_stats(n, B, D, op=op)
    assert led.counts["all-to-all"] == want["a2a_count"], (op, led.as_dict())
    assert led.bytes_by_kind["all-to-all"] == want["a2a_bytes"], (op, led.as_dict())
    assert led.counts["ppermute"] == want["ppermute_count"], (op, led.as_dict())
    assert led.bytes_by_kind["ppermute"] == want["ppermute_bytes"], (op, led.as_dict())

real = dfft.four_step_collective_stats(n, B, D, op="polymul_real")
cplx = dfft.four_step_collective_stats(n, B, D, op="polymul")
ratio = real["total_bytes"] / cplx["total_bytes"]
assert ratio <= 0.6, ratio
print("OK ratio", round(ratio, 4))
""", n_devices=8)
    assert "OK" in out


@pytest.mark.dist
def test_serve_polymul_real_distributed_8dev():
    """``--op polymul-real --model-shards 8`` dispatches the distributed
    real tier (route + plan recorded), matches the LOCAL fused kernel
    numerically, and the end-to-end driver completes."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.launch import serve
from repro.core import fft as fft_core

svc = serve.FFTService(1024, 4, "polymul-real", model_shards=8)
assert svc.route == "polymul-real-distributed", svc.route
assert svc.plan.tier == "distributed" and svc.plan.real
assert svc.plan.seq_shards == 8
rng = np.random.default_rng(0)
a = rng.standard_normal((4, 1024)).astype(np.float32)
b = rng.standard_normal((4, 1024)).astype(np.float32)
got = np.asarray(svc._fn(jnp.asarray(a), jnp.asarray(b)))
local = np.asarray(fft_core.polymul_real(jnp.asarray(a), jnp.asarray(b),
                                         mode="circular"))
err = np.max(np.abs(got - local))
assert err < 1e-3, f"distributed serve vs local kernel: {err}"

# shape guards fire loudly at service construction ...
try:
    serve.FFTService(96, 4, "polymul-real", model_shards=8)
except ValueError:
    pass
else:
    raise AssertionError("should reject n=96 (D^2 does not divide n)")
# ... but odd batches are legal now: the tier pads the tail row with a
# zeros partner internally and slices it off (the old even-batch guard
# is gone; ROADMAP leftover)
svc3 = serve.FFTService(1024, 3, "polymul-real", model_shards=8)
a3 = rng.standard_normal((3, 1024)).astype(np.float32)
b3 = rng.standard_normal((3, 1024)).astype(np.float32)
g3 = np.asarray(svc3._fn(jnp.asarray(a3), jnp.asarray(b3)))
w3 = np.fft.ifft(np.fft.fft(a3) * np.fft.fft(b3)).real
assert g3.shape == (3, 1024), g3.shape
assert np.max(np.abs(g3 - w3)) < 1e-3, "odd-batch distributed polymul-real"

stats = serve.main(["--service", "fft", "--n", "1024", "--batch", "4",
                    "--requests", "8", "--op", "polymul-real",
                    "--model-shards", "8"])
assert stats["served"] == 8, stats
print("OK")
""", n_devices=8)
    assert "OK" in out
