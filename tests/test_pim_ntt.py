"""PIM NTT cost-model tier: closed-form latency == simulator counters for
all three layouts (the parity contract tests/test_pim.py enforces for the
float FFT), throughput monotonicity in beta, the negacyclic polymul
structure, and the counter-ORDERING regression that pinned the fft_2rbeta
perm-charge placement fix."""
import numpy as np
import pytest

from repro.core.ntt import ref
from repro.core.pim import (FOURIERPIM_8, FP32, INT16, INT32,
                            batched_ntt_stats, fft_2r, fft_2rbeta,
                            ntt_latency_cycles, ntt_polymul_latency_cycles,
                            ntt_throughput_per_s, pim_ntt, pim_ntt_polymul,
                            r_fft, with_partitions)
from repro.core.pim import aritpim, ntt_pim


def _layout_cases(spec):
    """(n, layout_fn) per configuration; 16-bit moduli only exist below
    2^16, which caps the valid n for INT16 value-level runs."""
    cases = [(1024, ntt_pim.r_ntt), (2048, ntt_pim.ntt_2r),
             (4096, ntt_pim.ntt_2rbeta), (16384, ntt_pim.ntt_2rbeta)]
    if spec.word_bits < 32:
        cases = [c for c in cases if c[0] <= 2048]
    return cases


def _make_params(n, spec):
    bits = 30 if spec.word_bits >= 32 else 14
    return ref.NTTParams.make(n, bits=bits)


# ---------------------------------------------------------------------------
# Values exact, closed form == counters, all layouts x partitions x words
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [INT32, INT16])
@pytest.mark.parametrize("p", [1, 2, 4])
def test_closed_form_latency_matches_simulator(rng, spec, p):
    cfg = with_partitions(FOURIERPIM_8, p)
    for n, layout in _layout_cases(spec):
        params = _make_params(n, spec)
        x = rng.integers(0, params.q, size=n)
        res = layout(x, params, cfg, spec)
        assert (res.output == ref.ntt(x, params)).all(), (n, layout.__name__)
        assert res.counters.cycles == ntt_latency_cycles(n, cfg, spec), \
            (n, layout.__name__, p)
        inv = layout(res.output, params, cfg, spec, inverse=True)
        assert (inv.output == x.astype(np.uint64)).all()
        assert inv.counters.cycles == ntt_latency_cycles(n, cfg, spec,
                                                         inverse=True)


def test_pim_ntt_rejects_float_input():
    """Same loud-failure contract as the reference: truncating floats into
    an 'exact' transform would be a silent lie."""
    params = _make_params(1024, INT32)
    with pytest.raises(TypeError):
        pim_ntt(np.ones(1024, np.float64), params, FOURIERPIM_8, INT32)


@pytest.mark.parametrize("n", [1024, 2048, 8192])
def test_pim_ntt_dispatch_roundtrip(rng, n):
    params = _make_params(n, INT32)
    x = rng.integers(0, params.q, size=n)
    f = pim_ntt(x, params, FOURIERPIM_8, INT32)
    b = pim_ntt(f.output, params, FOURIERPIM_8, INT32, inverse=True)
    assert (b.output == x.astype(np.uint64)).all()


@pytest.mark.parametrize("negacyclic", [True, False])
def test_polymul_closed_form_matches_simulator(rng, negacyclic):
    n = 4096
    params = _make_params(n, INT32)
    a = rng.integers(0, params.q, size=n)
    b = rng.integers(0, params.q, size=n)
    res = pim_ntt_polymul(a, b, params, FOURIERPIM_8, INT32,
                          negacyclic=negacyclic)
    fn = ref.negacyclic_polymul if negacyclic else ref.cyclic_polymul
    assert (res.output == fn(a, b, params)).all()
    assert res.counters.cycles == ntt_polymul_latency_cycles(
        n, FOURIERPIM_8, INT32, negacyclic=negacyclic)


def test_negacyclic_premium_is_three_modmuls():
    """Twist/untwist structure: negacyclic = cyclic + 3 serialized modmuls
    (psi twist x2 + psi^-1 untwist; the 1/n rides the inverse transform)."""
    n = 8192
    beta_serial = n // (2 * FOURIERPIM_8.crossbar_rows)
    cyc = ntt_polymul_latency_cycles(n, FOURIERPIM_8, INT32,
                                     negacyclic=False)
    nega = ntt_polymul_latency_cycles(n, FOURIERPIM_8, INT32)
    assert nega - cyc == 3 * aritpim.mod_mul_cycles(INT32) * beta_serial


def test_polymul_skips_input_permutations():
    """§5 analogue: polymul transforms charge no bit-reversal (DIT/DIF
    cancellation), so 3 transforms + 4 modmuls is the whole budget."""
    n = 4096
    no_perm = ntt_latency_cycles(n, FOURIERPIM_8, INT32, charge_perm=False)
    with_perm = ntt_latency_cycles(n, FOURIERPIM_8, INT32, charge_perm=True)
    assert no_perm < with_perm
    inv_np = ntt_latency_cycles(n, FOURIERPIM_8, INT32, charge_perm=False,
                                inverse=True)
    serial = n // (2 * FOURIERPIM_8.crossbar_rows)
    pm = ntt_polymul_latency_cycles(n, FOURIERPIM_8, INT32)
    assert pm == (2 * no_perm + inv_np
                  + 4 * aritpim.mod_mul_cycles(INT32) * serial)


# ---------------------------------------------------------------------------
# Throughput trends
# ---------------------------------------------------------------------------

def test_throughput_monotone_decreasing_in_beta():
    """Serial beta units: throughput strictly falls as n (hence beta)
    grows, and the drop is superlinear without partitions."""
    ths = [ntt_throughput_per_s(n, FOURIERPIM_8, INT32)
           for n in (2048, 4096, 8192, 16384)]
    assert all(a > b for a, b in zip(ths, ths[1:])), ths
    assert ths[0] / ths[-1] > 3.0


def test_partitions_flatten_beta_serialization():
    n = 16384  # beta = 8
    lats = [ntt_latency_cycles(n, with_partitions(FOURIERPIM_8, p), INT32)
            for p in (1, 2, 4)]
    assert lats[0] > lats[1] > lats[2]
    assert lats[0] / lats[2] <= 4.0 + 1e-9   # speedup bounded by p


def test_int_words_halve_area_vs_float():
    """A 32-bit residue word is half the 64-bit complex float word: the
    NTT occupies half the crossbar area at equal n (extra batch capacity
    once the float layout spills) and reaches 2x the sequence length
    before hitting the crossbar-width wall."""
    n = 16384
    word_f = aritpim.complex_word_bits(FP32)
    area_int = FOURIERPIM_8.crossbars_per_fft(n, INT32.word_bits)
    area_float = FOURIERPIM_8.crossbars_per_fft(n, word_f)
    assert area_int == pytest.approx(area_float / 2)
    assert FOURIERPIM_8.batch_capacity(n, INT32.word_bits) \
        >= FOURIERPIM_8.batch_capacity(n, word_f)
    assert FOURIERPIM_8.valid_config(32768, INT32.word_bits)
    assert not FOURIERPIM_8.valid_config(32768, word_f)


def test_batched_ntt_stats_full_wave_matches_closed_form():
    st = batched_ntt_stats(2048, None, FOURIERPIM_8, INT32)
    assert st["waves"] == 1 and st["utilization"] == 1.0
    want = ntt_throughput_per_s(2048, FOURIERPIM_8, INT32)
    assert st["throughput_per_s"] == pytest.approx(want, rel=1e-6)
    ragged = batched_ntt_stats(2048, st["arrays_per_device"] + 1,
                               FOURIERPIM_8, INT32)
    assert ragged["waves"] == 2 and ragged["utilization"] < 1.0


# ---------------------------------------------------------------------------
# Counter-ordering regression (the fft_2rbeta perm-placement fix)
# ---------------------------------------------------------------------------

def _first_index(log, tag):
    for i, (t, _) in enumerate(log):
        if t == tag:
            return i
    raise AssertionError(f"no {tag!r} charge in log: {log[:6]}...")


@pytest.mark.parametrize("case", ["r", "2r", "2rbeta"])
def test_fft_perm_charged_before_first_butterfly(rng, case):
    """All three float-FFT layouts must charge the input bit-reversal
    BEFORE any butterfly; fft_2rbeta used to charge it after the group
    loop (totals identical, ordering wrong)."""
    fn, n = {"r": (r_fft, 1024), "2r": (fft_2r, 2048),
             "2rbeta": (fft_2rbeta, 4096)}[case]
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    res = fn(x, FOURIERPIM_8, FP32)
    assert _first_index(res.log, "perm") < _first_index(res.log, "butterfly")
    assert res.log[-1][0] != "perm", "perm must not trail the group loop"


@pytest.mark.parametrize("case", ["r", "2r", "2rbeta"])
def test_ntt_perm_charged_before_first_butterfly(rng, case):
    fn, n = {"r": (ntt_pim.r_ntt, 1024), "2r": (ntt_pim.ntt_2r, 2048),
             "2rbeta": (ntt_pim.ntt_2rbeta, 4096)}[case]
    params = _make_params(n, INT32)
    x = rng.integers(0, params.q, size=n)
    res = fn(x, params, FOURIERPIM_8, INT32)
    assert _first_index(res.log, "perm") < _first_index(res.log, "butterfly")
    assert res.log[-1][0] != "perm"


def test_perm_placement_preserves_totals(rng):
    """The ordering fix must not change totals: 2rbeta closed form still
    equals the simulator (guards against fixing ordering by dropping or
    double-charging the permutation)."""
    n = 8192
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    from repro.core.pim import fft_latency_cycles
    res = fft_2rbeta(x, FOURIERPIM_8, FP32)
    assert res.counters.cycles == fft_latency_cycles(n, FOURIERPIM_8, FP32)
    perm_cycles = sum(c for t, c in res.log if t == "perm")
    no_perm = fft_2rbeta(x, FOURIERPIM_8, FP32, charge_perm=False)
    assert res.counters.cycles - no_perm.counters.cycles == perm_cycles


# ---------------------------------------------------------------------------
# RNS: per-limb accounting, limbs as waves
# ---------------------------------------------------------------------------

def _small_rns(n=1024, bits=100):
    from repro.core.ntt.rns import RNSParams
    return RNSParams.make(n, modulus_bits=bits)


def test_rns_polymul_counters_are_limb_sums(rng):
    """pim_rns_polymul's counters == sum of per-limb fused-polymul sims ==
    the closed form k * ntt_polymul_latency_cycles; values == the numpy
    reference (big-int oracle parity lives in tests/test_rns_ntt.py)."""
    from repro.core.ntt.rns import random_poly, rns_polymul_reference
    from repro.core.pim import pim_rns_polymul, rns_polymul_latency_cycles
    n = 1024
    r = _small_rns(n)
    a = random_poly(rng, n, r.modulus)
    b = random_poly(rng, n, r.modulus)
    res = pim_rns_polymul(a, b, r, FOURIERPIM_8, INT32)
    per_limb = ntt_polymul_latency_cycles(n, FOURIERPIM_8, INT32)
    assert res.counters.cycles == r.k * per_limb
    assert res.counters.cycles == rns_polymul_latency_cycles(
        n, r.k, FOURIERPIM_8, INT32)
    assert (res.result == rns_polymul_reference(a, b, r)).all()


def test_rns_wave_schedule_through_dist_batching():
    """Limbs ride the same wave scheduler as transform batches: more limbs
    than arrays -> extra waves, latency scales with waves not limb count."""
    from repro.core.pim import rns_polymul_wave_stats
    import dataclasses as dc
    n = 16384
    r = _small_rns(1024)          # k only; stats take (n, k) directly
    # shrink the memory so only 2 arrays exist: k limbs -> ceil(k/2) waves
    cfg = dc.replace(FOURIERPIM_8, memory_bytes=n * 32 // 8 * 4)
    assert cfg.batch_capacity(n, INT32.word_bits) == 2
    st = rns_polymul_wave_stats(n, r.k, cfg, INT32)
    assert st["limbs"] == r.k
    assert st["waves"] == -(-r.k // st["arrays_per_device"])
    one = rns_polymul_wave_stats(n, 1, cfg, INT32)
    assert st["latency_s"] == pytest.approx(
        one["wave_latency_s"] * st["waves"])
    assert st["total_cycles"] == r.k * one["total_cycles"]


# ---------------------------------------------------------------------------
# Distributed four-step NTT: values exact, closed form == per-shard counters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_distributed_ntt_values_and_counter_parity(rng, n_shards):
    from repro.core.pim import (ntt_distributed_a2a_bytes,
                                ntt_distributed_latency_cycles,
                                pim_ntt_distributed)
    n = n_shards * FOURIERPIM_8.crossbar_rows
    params = ref.NTTParams.make(n)
    x = rng.integers(0, params.q, size=n)
    res = pim_ntt_distributed(x, params, n_shards, FOURIERPIM_8, INT32)
    # Bit-exact against the single-array reference transform.
    assert (res.output == ref.ntt(x, params)).all()
    # Shards are symmetric: every shard's counter equals the closed form.
    want = ntt_distributed_latency_cycles(n, n_shards, FOURIERPIM_8, INT32)
    for ctr in res.shard_counters:
        assert ctr.cycles == want
    assert res.latency_cycles == want
    assert res.a2a_bytes == ntt_distributed_a2a_bytes(n, n_shards, INT32)


def test_distributed_ntt_charge_log_ordering(rng):
    """Tagged charge-log contract per shard: the step-3 twiddle modmul sits
    between the phase-A butterflies and phase-B's bit-reversal perm, and
    phase B (unlike phase A, whose reorder rides the transpose) charges a
    perm before its first butterfly."""
    from repro.core.pim import pim_ntt_distributed
    n = 4 * FOURIERPIM_8.crossbar_rows
    params = ref.NTTParams.make(n)
    x = rng.integers(0, params.q, size=n)
    res = pim_ntt_distributed(x, params, 4, FOURIERPIM_8, INT32)
    for log in res.logs:
        mm = _first_index(log, "modmul")
        perm = _first_index(log, "perm")
        assert _first_index(log, "butterfly") < mm < perm
        assert any(t == "butterfly" for t, _ in log[perm:])


def test_distributed_ntt_scaling_with_shards():
    """Structural identity of the closed form: per-shard latency grows by
    exactly ONE phase-A stage per doubling of the shard count — phase B
    (the local length-r transform) and the step-3 twiddle modmul are
    D-independent, so the whole D-dependence is log2(D) column stages."""
    from repro.core.pim import ntt_distributed_latency_cycles
    r = FOURIERPIM_8.crossbar_rows
    lat2 = ntt_distributed_latency_cycles(2 * r, 2, FOURIERPIM_8, INT32)
    lat4 = ntt_distributed_latency_cycles(4 * r, 4, FOURIERPIM_8, INT32)
    lat8 = ntt_distributed_latency_cycles(8 * r, 8, FOURIERPIM_8, INT32)
    assert lat4 - lat2 == lat8 - lat4       # constant per-doubling increment
    stage_a = lat4 - lat2
    base = (ntt_latency_cycles(r, FOURIERPIM_8, INT32)
            + aritpim.mod_mul_cycles(INT32))
    assert lat2 == base + stage_a


def test_distributed_ntt_rejects_bad_shapes():
    from repro.core.pim import pim_ntt_distributed
    params = ref.NTTParams.make(2048)
    x = np.zeros(2048, np.int64)
    with pytest.raises(ValueError):
        pim_ntt_distributed(x, params, 3, FOURIERPIM_8, INT32)  # non-pow2 D
    with pytest.raises(AssertionError):
        # n/D != crossbar rows
        pim_ntt_distributed(x, params, 4, FOURIERPIM_8, INT32)


# ---------------------------------------------------------------------------
# Integer cost-model structure
# ---------------------------------------------------------------------------

def test_modular_op_cost_structure():
    """Pins the documented derivations: Barrett modmul = 3 muls + 2 adds
    + 4, butterfly = modmul + 2 modadds, and the op_cycles dispatch."""
    w = INT32.word_bits
    assert aritpim.mod_add_cycles(INT32) == 2 * (9 * w + 1) + 2
    assert aritpim.mod_mul_cycles(INT32) == (3 * (12 * w * w + 3 * w)
                                             + 2 * (9 * w + 1) + 4)
    assert aritpim.ntt_butterfly_cycles(INT32) == (
        aritpim.mod_mul_cycles(INT32) + 2 * aritpim.mod_add_cycles(INT32))
    assert aritpim.op_cycles("butterfly", INT32) \
        == aritpim.ntt_butterfly_cycles(INT32)
    assert aritpim.op_cycles("copy", INT32) == 2 * w
    assert aritpim.storage_word_bits(INT32) == 32
    assert aritpim.storage_word_bits(FP32) == 64
    # no IEEE overhead: the integer butterfly at 16-bit words is far below
    # the fp16 complex butterfly
    assert aritpim.ntt_butterfly_cycles(INT16) \
        < aritpim.butterfly_cycles(aritpim.FP16)
