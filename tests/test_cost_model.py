"""Cost-model tier: the auto-tiering planner's numbers and choices
(docs/planner.md).

* closed-form PIM cycle counts == live ``CrossbarSim`` counters, for
  every workload on both tiers (the cost model's "measured twin"
  contract — the same equalities the smoke bench re-asserts per run);
* closed-form collective-byte formulas == live ``dist.collectives``
  ledger traces of the REAL sharded builders (AbstractMesh: a lower()
  trace needs no devices, so the single-CPU suite measures the D=8 tier);
* prune / infeasibility reasons NAME their constraint — the serve layer
  surfaces these messages verbatim, so they are pinned here;
* ``plan(n, batch, workload=...)`` (auto mode) never returns a plan the
  guards ``bind()`` applies would reject — property-tested across the
  (workload, n, batch, D) space;
* ``FFTPlan.cost`` rides along without perturbing plan equality/hash
  (the engine keys buckets on plans).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import cost as cost_lib
from repro.core.cost import (LINK_BW, WORKLOADS, dist_prune_reason,
                             local_prune_reason, pim_dist_infeasible,
                             pim_local_infeasible, workload_cost, xla_cost)
from repro.core.fft import planner
from repro.core.fft.planner import plan
from repro.core.ntt import NTTParams
from repro.core.pim import (FOURIERPIM_8, FP32, INT32, aritpim, fft_pim,
                            ntt_pim, polymul_pim)

CFG = FOURIERPIM_8


# ---------------------------------------------------------------------------
# Closed forms == simulator counters (local tier)
# ---------------------------------------------------------------------------

def _sim_local_cycles(workload: str, n: int, batch: int,
                      rng: np.random.Generator) -> int:
    if workload == "fft":
        z = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        return fft_pim.pim_fft(z, CFG, FP32).counters.cycles
    if workload == "rfft":
        return fft_pim.pim_rfft(rng.standard_normal(n),
                                rng.standard_normal(n),
                                CFG, FP32).counters.cycles
    if workload == "polymul":
        a = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        b = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        return polymul_pim.pim_polymul(a, b, CFG, FP32).counters.cycles
    if workload == "polymul-real":
        return polymul_pim.pim_polymul_real(
            rng.standard_normal((batch, n)), rng.standard_normal((batch, n)),
            CFG, FP32).counters.cycles
    params = NTTParams.make(n)
    a = rng.integers(0, params.q, n).astype(np.uint32)
    b = rng.integers(0, params.q, n).astype(np.uint32)
    return ntt_pim.pim_ntt_polymul(a, b, params, CFG, INT32).counters.cycles


@pytest.mark.parametrize("workload", WORKLOADS)
def test_local_unit_cycles_match_simulator(workload, rng):
    n, batch = 2048, 4
    want = cost_lib.pim_local_unit_cycles(workload, n, batch=batch)
    assert _sim_local_cycles(workload, n, batch, rng) == want


def test_complex_fallback_candidates_price_the_complex_schedule():
    """A real=False candidate of a real workload runs the complex kernels
    on XLA — its PIM twin must price the complex schedule too, not the
    packed one it isn't running."""
    assert cost_lib._pim_workload("rfft", False) == "fft"
    assert cost_lib._pim_workload("polymul-real", False) == "polymul"
    assert cost_lib._pim_workload("rfft", True) == "rfft"
    n = 2048
    c = cost_lib.pim_cost("rfft", n, 4, tier="local", real=False)
    assert c.pim_cycles == fft_pim.fft_latency_cycles(n, CFG, FP32)


# ---------------------------------------------------------------------------
# Closed forms == simulator counters + byte records (distributed tier)
# ---------------------------------------------------------------------------

def test_dist_unit_cycles_match_distributed_simulators(rng):
    n, D = 8192, 8            # the n1 = D cap: n == D * crossbar_rows
    r = fft_pim.pim_rfft_distributed(rng.standard_normal(n),
                                     rng.standard_normal(n), D, CFG, FP32)
    rfft_meas = max(c.cycles for c in r.shard_counters)
    unpack = fft_pim.realpack_unpack_cycles(CFG, FP32)
    assert rfft_meas == cost_lib.pim_dist_unit_cycles("rfft", n, D)
    assert rfft_meas - unpack == cost_lib.pim_dist_unit_cycles("fft", n, D)
    assert r.a2a_bytes + r.permute_bytes == \
        cost_lib.pim_dist_unit_bytes("rfft", n, D)

    params = NTTParams.make(n)
    x = rng.integers(0, params.q, n).astype(np.uint32)
    nt = ntt_pim.pim_ntt_distributed(x, params, D, CFG, INT32)
    # polymul-mod composes 3 transforms + the pointwise/twist modmuls
    assert 3 * nt.latency_cycles + 4 * aritpim.mod_mul_cycles(INT32) == \
        cost_lib.pim_dist_unit_cycles("polymul-mod", n, D)
    assert 3 * nt.a2a_bytes == \
        cost_lib.pim_dist_unit_bytes("polymul-mod", n, D)
    # the float polymuls compose the measured transform the same way
    assert 3 * (rfft_meas - unpack) + aritpim.complex_mul_cycles(FP32) == \
        cost_lib.pim_dist_unit_cycles("polymul", n, D)


@pytest.mark.parametrize("workload,real", [
    ("fft", False), ("rfft", True), ("rfft", False),
    ("polymul", False), ("polymul-real", True), ("polymul-real", False),
    ("polymul-mod", False)])
def test_xla_collective_bytes_match_live_ledger(workload, real):
    """The byte model the planner charges for the distributed XLA tier ==
    the live ledger of the actual sharded builder, traced at the real
    shard count on an AbstractMesh."""
    from repro.core.fft import distributed as dfft
    from repro.core.ntt import distributed as dntt
    from repro.dist import collectives
    n, batch, D = 1024, 4, 4
    mesh = jax.sharding.AbstractMesh((("model", D),))
    if workload == "polymul-mod":
        build = dntt.make_sharded_ntt_polymul(
            mesh, NTTParams.make(n), axis_name="model", batch_axes=())
        spec = jax.ShapeDtypeStruct((batch, n), jnp.uint32)
        args = (spec, spec)
    elif workload == "rfft" and real:
        build = dfft.make_sharded_rfft(mesh, batch_axes=())
        args = (jax.ShapeDtypeStruct((batch, n), jnp.float32),)
    elif workload == "polymul-real" and real:
        build = dfft.make_sharded_polymul_real(mesh, batch_axes=())
        spec = jax.ShapeDtypeStruct((batch, n), jnp.float32)
        args = (spec, spec)
    elif workload in ("polymul", "polymul-real"):
        build = dfft.make_sharded_polymul(mesh, batch_axes=())
        spec = jax.ShapeDtypeStruct((batch, n), jnp.complex64)
        args = (spec, spec)
    else:
        build = dfft.make_sharded_fft(mesh, batch_axes=())
        args = (jax.ShapeDtypeStruct((batch, n), jnp.complex64),)
    with collectives.ledger() as led:
        jax.jit(build).lower(*args)
    got = led.bytes_by_kind["all-to-all"] + led.bytes_by_kind["ppermute"]
    assert got == cost_lib._xla_collective_bytes(workload, n, batch, D,
                                                 real=real)


def test_xla_collective_bytes_pad_odd_real_batches():
    """The engine pads odd real batches to the next even size; the byte
    model charges the padded batch, not the impossible odd one."""
    even = cost_lib._xla_collective_bytes("rfft", 1024, 4, 4, real=True)
    assert cost_lib._xla_collective_bytes("rfft", 1024, 3, 4,
                                          real=True) == even


def test_xla_cost_is_roofline_max_plus_collectives():
    local = xla_cost("fft", 4096, 8, tier="local")
    assert local.t_collective_s == 0 and local.collective_bytes == 0
    assert local.total_s == max(local.t_compute_s, local.t_memory_s)
    dist = xla_cost("fft", 4096, 8, tier="distributed", n_devices=8)
    assert dist.t_compute_s == pytest.approx(local.t_compute_s / 8)
    assert dist.t_memory_s == pytest.approx(local.t_memory_s / 8)
    assert dist.t_collective_s == dist.collective_bytes / LINK_BW
    assert dist.total_s == pytest.approx(
        max(dist.t_compute_s, dist.t_memory_s) + dist.t_collective_s)


# ---------------------------------------------------------------------------
# Prune / infeasibility reasons name their constraint
# ---------------------------------------------------------------------------

def test_prune_reasons_name_their_constraint():
    assert local_prune_reason("fft", 1024) is None
    assert "_MAX_LOCAL_N" in local_prune_reason("fft", 2 ** 20)
    assert "_MAX_LOCAL_N_EXACT" in local_prune_reason("polymul-mod",
                                                      2 ** 20)
    assert dist_prune_reason("fft", 4096, 8, real=False) is None
    assert "model_shards > 1" in dist_prune_reason("fft", 1024, 1,
                                                   real=False)
    assert "D^2 | n" in dist_prune_reason("fft", 2 ** 20, 3, real=False)
    # the ordered real tier's stricter tiling has its own name
    assert "2*D^2 | n" in dist_prune_reason("rfft", 2 ** 20, 1024,
                                            real=True)


def test_pim_infeasibility_names_its_constraint():
    assert pim_local_infeasible("fft", 2048) is None
    bad = pim_local_infeasible("fft", 65536)
    assert "valid_config" in bad and "crossbar_cols" in bad
    assert pim_dist_infeasible(8192, 8) is None
    bad = pim_dist_infeasible(8192, 4)
    assert "n1 = D four-step cap" in bad
    assert "model_shards > 1" in pim_dist_infeasible(8192, 1)


def test_auto_plan_error_names_every_pruned_candidate():
    """A workload with no executable candidate fails listing each pruned
    (tier, packing) with the constraint that pruned it — the serve layer
    returns this message verbatim."""
    with pytest.raises(ValueError) as ei:
        plan(2 ** 20, 4, workload="fft", model_shards=3)
    msg = str(ei.value)
    assert "every candidate was pruned" in msg
    assert "_MAX_LOCAL_N" in msg and "D^2 | n" in msg
    with pytest.raises(ValueError) as ei:
        plan(2 ** 20, 4, workload="rfft", real=True, model_shards=1024)
    assert "2*D^2 | n" in str(ei.value)


# ---------------------------------------------------------------------------
# The chooser and the auto planner surface
# ---------------------------------------------------------------------------

def test_workload_cost_breakdown_structure():
    b = workload_cost("polymul-real", 4096, 8, n_devices=8)
    assert b["best"] is not None
    totals = [c["total_s"] for c in b["candidates"]]
    assert totals == sorted(totals)         # cheapest-first, stable ties
    assert b["best"] == b["candidates"][0]
    assert b["constants"]["link_bw"] == LINK_BW
    assert {c["real"] for c in b["candidates"]
            if c["tier"] == "local"} == {True, False}


def test_pim_infeasibility_is_a_backend_verdict_not_a_prune():
    """A shape the crossbar cannot hold still EXECUTES on XLA — PIM
    infeasibility must not remove the candidate, only its PIM score."""
    b = workload_cost("fft", 65536, 8, n_devices=8)
    local = [c for c in b["candidates"] if c["tier"] == "local"]
    assert local, b["pruned"]
    assert "valid_config" in local[0]["backends"]["pim"]["infeasible"]
    assert local[0]["backend_best"] == "xla"


def test_auto_plan_knob_interactions():
    with pytest.raises(ValueError, match="unknown workload"):
        plan(1024, 4, workload="dct")
    with pytest.raises(ValueError, match="exact.*polymul-mod"):
        plan(1024, 4, workload="fft", exact=True)
    with pytest.raises(ValueError, match="real-packed route"):
        plan(1024, 4, workload="polymul", real=True)
    # explicit knobs narrow the candidate space instead of being ignored
    p = plan(4096, 8, workload="fft", model_shards=8,
             force_distributed=True)
    assert p.tier == "distributed" and p.seq_shards == 8
    assert all(c["tier"] == "distributed" for c in p.cost["candidates"])
    p = plan(4096, 8, workload="rfft", real=True)
    assert p.real is True
    p = plan(1024, 4, workload="polymul-mod")
    assert p.exact is True and p.radix == 2


def test_auto_plan_cost_breakdown_rides_without_breaking_equality():
    """FFTPlan.cost is excluded from eq/hash: an auto plan and the
    equivalent explicit plan are the same bucket key to the engine."""
    auto = plan(1024, 4, workload="fft")
    explicit = plan(1024, 4)
    assert auto.cost is not None and explicit.cost is None
    assert auto == explicit and hash(auto) == hash(explicit)
    best = auto.cost["best"]
    assert (best["tier"], best["real"]) == (auto.tier, auto.real)


@settings(max_examples=60, deadline=None)
@given(workload=st.sampled_from(WORKLOADS),
       k=st.integers(6, 19),
       batch=st.integers(1, 16),
       D=st.sampled_from([1, 2, 4, 8, 16]))
def test_auto_plan_is_always_executable(workload, k, batch, D):
    """Property: auto either raises naming the pruning constraints, or
    returns a plan that passes the same guards bind() applies — never a
    plan the kernels reject."""
    n = 2 ** k
    try:
        p = plan(n, batch, workload=workload, model_shards=D)
    except ValueError as e:
        msg = str(e)
        assert "every candidate was pruned" in msg
        assert ("_MAX_LOCAL_N" in msg or "D^2 | n" in msg
                or "model_shards > 1" in msg)
        return
    assert p.exact == (workload == "polymul-mod")
    if p.tier == "local":
        cap = (planner._MAX_LOCAL_N_EXACT if p.exact
               else planner._MAX_LOCAL_N)
        assert n <= cap
        assert p.seq_shards == 1 and p.block_b >= 1
    else:
        from repro.core.fft.distributed import check_four_step_shape
        # the ordered rfft is the only dist route with the 2*D^2 tiling
        check_four_step_shape(n, p.seq_shards,
                              real=p.real and workload == "rfft")
        assert p.seq_shards == D
    best = p.cost["best"]
    assert (best["tier"], best["real"]) == (p.tier, p.real)


def test_engine_auto_mode_binds_serves_and_reports_predictions(rng):
    """End to end: every registry op binds in auto mode, serves, verifies
    against its numpy oracle, and reports predicted-vs-observed cost in
    stats() (the serve-layer surface of the tentpole)."""
    from repro.launch.engine import ServeEngine
    ops = ("fft", "rfft", "polymul", "polymul-real", "polymul-mod")
    engine = ServeEngine(max_batch=4, auto=True)
    for op in ops:
        engine.register(op, 256)
        assert engine.bound(op, 256).plan.cost is not None
    engine.warmup()
    kept = {}
    for op in ops:
        payload = engine.bound(op, 256).random_payload(rng)
        kept[op] = (engine.submit(op, 256, payload), payload)
    stats = engine.run(len(ops))
    assert stats["served"] == len(ops)
    for op, (rid, payload) in kept.items():
        engine.bound(op, 256).verify(payload, engine.results[rid])
    for name, b in stats["buckets"].items():
        assert b["predicted_s_per_req"] is not None, name
        assert b["predicted_tier"] == "local", name
        assert b["predicted_backend"] in ("pim", "xla"), name
        assert b["observed_s_per_req"] > 0, name
