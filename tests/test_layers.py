"""Layer-level unit tests: MoE routing invariants, Fourier mixing oracle,
RWKV/SSM chunked-state consistency, RoPE properties."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.models.config import ModelConfig
from repro.models.layers import moe as moe_lib
from repro.models.layers import recurrent as rec_lib
from repro.models.layers.common import apply_rope, fourier_mixing


def _moe_cfg(**kw):
    base = dict(name="t", family="moe", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                num_experts=4, experts_per_token=2, moe_group_size=16,
                dtype="float32", param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_moe_gates_normalized_and_capacity_bounds(rng):
    cfg = _moe_cfg()
    params = moe_lib.init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, 32)), jnp.float32)
    y, aux = moe_lib.moe_ffn(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # aux loss near 1.0 for near-uniform routing (E * sum fe*pe ~= 1)
    assert 0.5 < float(aux) < 4.0


def test_moe_no_drop_equals_dense_mixture(rng):
    """With capacity E/k (no drops), grouped dispatch must equal the naive
    dense mixture sum_k gate_k * FFN_{e_k}(x)."""
    cfg = _moe_cfg(capacity_factor=2.0)  # E/k = 2 -> no drops
    params = moe_lib.init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 16, 32)), jnp.float32)
    y, _ = moe_lib.moe_ffn(params, x, cfg)

    # naive reference
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        fe = h @ params["w_down"][e]
        w_e = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1)
        want = want + w_e[..., None] * fe
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_fourier_mixing_matches_direct_convolution(rng):
    d, K, S = 8, 4, 32
    params = {
        "taps": jnp.asarray(rng.standard_normal((K, d)), jnp.float32),
        "gate": jnp.zeros((d, d), jnp.float32),  # sigmoid(0) = 0.5 gate
    }
    x = jnp.asarray(rng.standard_normal((1, S, d)), jnp.float32)
    y = fourier_mixing(params, x)
    # direct causal depthwise conv
    want = np.zeros((1, S, d))
    xn = np.asarray(x)
    tn = np.asarray(params["taps"])
    for t in range(S):
        for s in range(min(K, t + 1)):
            want[0, t] += tn[s] * xn[0, t - s]
    want *= 0.5
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-3)


def test_rwkv_chunked_state_consistency(rng):
    """Processing [0:S] in one call == two chunked calls with carried
    state (the property decode and multi-chunk prefill rely on)."""
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=128,
                      num_heads=2, num_kv_heads=2, d_ff=256,
                      vocab_size=128, mixer="rwkv6", dtype="float32",
                      param_dtype="float32")
    params = rec_lib.init_rwkv_params(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, 128)) * 0.3, jnp.float32)
    y_full, _ = rec_lib.rwkv_time_mix(params, x)
    y1, st = rec_lib.rwkv_time_mix(params, x[:, :8])
    y2, _ = rec_lib.rwkv_time_mix(params, x[:, 8:], state=st)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


def test_ssm_chunked_state_consistency(rng):
    cfg = ModelConfig(name="t", family="hybrid", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                      mixer="hymba", ssm_state=8, dtype="float32",
                      param_dtype="float32")
    params = rec_lib.init_ssm_params(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 12, 32)) * 0.3, jnp.float32)
    y_full, _ = rec_lib.ssm_mix(params, x)
    y1, h = rec_lib.ssm_mix(params, x[:, :6])
    y2, _ = rec_lib.ssm_mix(params, x[:, 6:], state=h)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


def test_rope_preserves_norm_and_relative_phase(rng):
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    y = apply_rope(x, pos)
    # rotation preserves per-pair norms
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    def dot_at(p, k):
        rq = apply_rope(q, jnp.asarray([[p]], jnp.int32))
        rv = apply_rope(v, jnp.asarray([[p + k]], jnp.int32))
        return float(jnp.sum(rq * rv))
    assert abs(dot_at(3, 5) - dot_at(10, 5)) < 1e-4
    assert abs(dot_at(3, 5) - dot_at(3, 2)) > 1e-6  # actually varies with k
