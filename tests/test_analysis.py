"""Invariant-linter tier (repro.analysis, docs/static_analysis.md).

Three layers:
  * fixture pairs — for EVERY registered rule, one snippet it MUST flag
    and one near-miss it MUST pass; the meta-test makes the pairing a
    closed loop (a rule without fixtures cannot be registered, a fixture
    without a rule is dead weight) and requires each rule's docstring to
    name the PR/bug it encodes;
  * engine mechanics — suppression placement (same line / comment-only
    line above, nothing further), mandatory reasons, unused-noqa,
    non-suppressible meta rules, reporters, CLI exit codes (0/1/2);
  * the repo gate itself — ``analyze_paths(src tests benchmarks)`` must
    be clean with every suppression carrying a reason: the same
    assertion CI's static-analysis job enforces, pinned here so a plain
    ``pytest`` run catches a violation before push.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro import analysis

ROOT = Path(__file__).resolve().parent.parent

# ---------------------------------------------------------------------------
# Fixture pairs: rule id -> {flag: (source, path), ok: (source, path)}
# Paths are virtual — placement rules match on suffix, so fixtures can
# claim to live anywhere in the tree.
# ---------------------------------------------------------------------------

_P = "src/repro/somepkg/snippet.py"
_P_FT = "src/repro/ft/snippet.py"

FIXTURES: dict[str, dict[str, tuple[str, str]]] = {
    "tracer-leak": {
        "flag": ("""\
import functools
import jax.numpy as jnp


@functools.lru_cache(maxsize=8)
def tables(n):
    return jnp.arange(n)
""", _P),
        "ok": ("""\
import functools
import numpy as np


@functools.lru_cache(maxsize=8)
def tables(n):
    return np.arange(n)
""", _P),
    },
    "fp32-phase": {
        "flag": ("""\
import numpy as np


def twiddles(n):
    return np.exp(2j * np.pi * np.arange(n).astype(np.float32) / n)
""", _P),
        # f64 trig, rounded ONCE after — the PR-5 fix shape
        "ok": ("""\
import numpy as np


def twiddles(n):
    return np.exp(2j * np.pi * np.arange(n) / n).astype(np.complex64)
""", _P),
    },
    "mutable-default": {
        "flag": ("""\
def make_watchdog(cfg=WatchdogConfig()):
    return StepWatchdog(cfg)
""", _P),
        # None sentinel — the PR-7 fix shape; frozen non-Config dataclass
        # defaults stay legal (launch/ops.py OpContext)
        "ok": ("""\
def make_watchdog(cfg=None):
    return StepWatchdog(WatchdogConfig() if cfg is None else cfg)


def bind(n, ctx=OpContext()):
    return ctx
""", _P),
    },
    "raw-collective": {
        "flag": ("""\
import jax


def reduce_grads(x):
    return jax.lax.psum(x, "data")
""", _P),
        "ok": ("""\
from repro.dist import collectives


def reduce_grads(x):
    return collectives.psum(x, "data")
""", _P),
    },
    "dispatch-ladder": {
        # renamed variable: the old "elif op ==" string grep missed this
        "flag": ("""\
def dispatch(o, x):
    if o == "fft":
        return run_fft(x)
    elif o == "polymul":
        return run_polymul(x)
    raise ValueError(o)
""", _P),
        # single op comparison + registry hand-off: not a ladder
        "ok": ("""\
def dispatch(o, x):
    if o == "fft":
        return run_fft(x)
    return registry.bind(o).fn(x)
""", _P),
    },
    "signal-lock": {
        "flag": ("""\
import signal


def install(engine):
    def _on_term(signum, frame):
        engine.request_stop()
    signal.signal(signal.SIGTERM, _on_term)
""", _P),
        # thread hand-off, locky call inside a NESTED def that runs on
        # the spawned thread — the PR-7 fix shape
        "ok": ("""\
import signal
import threading


def install(engine):
    def _on_term(signum, frame):
        def _stop():
            engine.request_stop()
        threading.Thread(target=_stop, daemon=True).start()
    signal.signal(signal.SIGTERM, _on_term)
""", _P),
    },
    "durable-write": {
        "flag": ("""\
import json


def write_state(path, state):
    with open(path, "w") as f:
        json.dump(state, f)
""", _P_FT),
        # reads are fine; and the same raw write OUTSIDE ft/ is out of
        # scope for this rule (checked via the flag snippet's path)
        "ok": ("""\
def read_state(path):
    with open(path) as f:
        return f.read()
""", _P_FT),
    },
    "bare-plan-literal": {
        "flag": ("""\
def forced_plan():
    return FFTPlan(tier="distributed", radix=2, block_b=1)
""", _P),
        "ok": ("""\
from repro.core.fft.planner import plan


def forced_plan(n):
    return plan(n, 8, force_distributed=True)
""", _P),
    },
    "noqa-reason": {
        "flag": ("""\
import jax


def reduce_grads(x):
    return jax.lax.psum(x, "data")  # repro: noqa[raw-collective]
""", _P),
        "ok": ("""\
import jax


def reduce_grads(x):
    return jax.lax.psum(x, "data")  # repro: noqa[raw-collective]: fixture exercising the raw call
""", _P),
    },
    "unused-noqa": {
        "flag": ("""\
def clean():
    return 1  # repro: noqa[raw-collective]: nothing here needs excusing
""", _P),
        "ok": ("""\
import jax


def reduce_grads(x):
    return jax.lax.psum(x, "data")  # repro: noqa[raw-collective]: fixture exercising the raw call
""", _P),
    },
}


@pytest.mark.parametrize("rule_id", analysis.RULE_IDS)
def test_rule_fixture_pair(rule_id):
    """Each rule flags its must-flag snippet (message naming the
    historical PR) and stays silent on its near-miss."""
    flag_src, flag_path = FIXTURES[rule_id]["flag"]
    res = analysis.analyze_source(flag_src, flag_path)
    hits = [f for f in res.findings if f.rule == rule_id]
    assert hits, (f"rule {rule_id} missed its must-flag fixture; got "
                  f"{[f.format() for f in res.findings]}")
    assert re.search(r"PR \d|noqa", hits[0].message), \
        f"finding message must name the historical bug: {hits[0].message}"
    ok_src, ok_path = FIXTURES[rule_id]["ok"]
    res_ok = analysis.analyze_source(ok_src, ok_path)
    assert res_ok.findings == [], \
        (f"rule {rule_id}'s near-miss fixture must pass every rule; got "
         f"{[f.format() for f in res_ok.findings]}")


def test_meta_every_rule_has_fixtures_and_docstring():
    """The closed loop the ISSUE demands: >= 8 rules, unique ids, every
    registered rule carries BOTH fixtures and a docstring naming the
    PR/bug it encodes; no orphan fixtures."""
    ids = list(analysis.RULE_IDS)
    assert len(ids) == len(set(ids)), "duplicate rule ids"
    assert len(ids) >= 8, f"need >= 8 active rules, have {len(ids)}"
    for rule in analysis.RULES:
        assert rule.id in FIXTURES, f"rule {rule.id} has no fixture pair"
        assert {"flag", "ok"} <= set(FIXTURES[rule.id]), \
            f"rule {rule.id} needs both a must-flag and a must-pass fixture"
        doc = type(rule).__doc__ or ""
        assert re.search(r"PR \d", doc), \
            f"rule {rule.id} docstring must name the PR/bug it encodes"
        assert rule.summary, f"rule {rule.id} has no summary line"
    assert set(FIXTURES) == set(ids), \
        f"orphan fixtures: {set(FIXTURES) - set(ids)}"


def test_op_name_set_matches_registry():
    """The ladder rule's literal op-name set cannot drift from the
    launch/ops.py registry (the analyzer stays importable without jax, so
    it carries the set as data; this pin keeps the two in sync)."""
    from repro.launch import ops as op_registry
    assert set(analysis.OP_NAMES) == set(op_registry.op_names())


# ---------------------------------------------------------------------------
# Suppression mechanics
# ---------------------------------------------------------------------------

_RAW = 'import jax\n\n\ndef f(x):\n    return jax.lax.psum(x, "d")'


def test_noqa_same_line_suppresses_and_keeps_reason():
    src = _RAW + "  # repro: noqa[raw-collective]: byte accounting pinned elsewhere\n"
    res = analysis.analyze_source(src, _P)
    assert res.findings == []
    assert len(res.suppressed) == 1
    assert res.suppressed[0]["reason"] == "byte accounting pinned elsewhere"
    assert res.suppressed[0]["rule"] == "raw-collective"


def test_noqa_standalone_line_above_suppresses():
    src = ('import jax\n\n\ndef f(x):\n'
           '    # repro: noqa[raw-collective]: pinned elsewhere\n'
           '    return jax.lax.psum(x, "d")\n')
    res = analysis.analyze_source(src, _P)
    assert res.findings == []


def test_noqa_two_lines_above_does_not_reach():
    src = ('import jax\n\n\ndef f(x):\n'
           '    # repro: noqa[raw-collective]: too far away\n'
           '    y = x\n'
           '    return jax.lax.psum(y, "d")\n')
    res = analysis.analyze_source(src, _P)
    rules = sorted(f.rule for f in res.findings)
    # the finding survives AND the stranded noqa is reported
    assert rules == ["raw-collective", "unused-noqa"]


def test_noqa_wrong_rule_id_does_not_suppress():
    src = _RAW + "  # repro: noqa[tracer-leak]: mismatched excuse\n"
    res = analysis.analyze_source(src, _P)
    rules = sorted(f.rule for f in res.findings)
    assert rules == ["raw-collective", "unused-noqa"]


def test_noqa_unknown_rule_id_reported():
    src = "x = 1  # repro: noqa[not-a-rule]: whatever\n"
    res = analysis.analyze_source(src, _P)
    assert [f.rule for f in res.findings] == ["noqa-reason"]
    assert "unknown rule id" in res.findings[0].message


def test_meta_rules_cannot_be_suppressed():
    src = "x = 1  # repro: noqa[unused-noqa]: trying to silence the police\n"
    res = analysis.analyze_source(src, _P)
    assert [f.rule for f in res.findings] == ["noqa-reason"]
    assert "cannot itself be suppressed" in res.findings[0].message


def test_parse_error_is_a_finding():
    res = analysis.analyze_source("def broken(:\n", _P)
    assert [f.rule for f in res.findings] == ["parse-error"]


def test_json_report_shape():
    res = analysis.analyze_source(_RAW + "\n", _P)
    rep = analysis.to_json(res)
    assert rep["schema"] == "repro.analysis/v1"
    assert rep["rule_count"] == len(analysis.RULES)
    assert rep["ok"] is False
    assert {r["id"] for r in rep["rules"]} == set(analysis.RULE_IDS)
    f = rep["findings"][0]
    assert {"path", "line", "col", "rule", "message"} <= set(f)
    json.dumps(rep)    # serializable


# ---------------------------------------------------------------------------
# CLI exit codes + repo gate
# ---------------------------------------------------------------------------

def _run_cli(*argv, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "repro.analysis", *argv],
                          capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_exit_codes_and_json(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(FIXTURES["raw-collective"]["flag"][0])
    good = tmp_path / "good.py"
    good.write_text(FIXTURES["raw-collective"]["ok"][0])

    res = _run_cli(str(good))
    assert res.returncode == 0, res.stdout + res.stderr
    res = _run_cli(str(bad))
    assert res.returncode == 1
    assert "[raw-collective]" in res.stdout

    res = _run_cli(str(bad), "--format", "json")
    assert res.returncode == 1
    rep = json.loads(res.stdout)
    assert rep["ok"] is False and len(rep["findings"]) == 1

    assert _run_cli().returncode == 2                      # no paths
    assert _run_cli(str(tmp_path / "nope")).returncode == 2  # missing path
    assert _run_cli("--list-rules").returncode == 0


def test_repo_tree_is_clean_and_suppressions_carry_reasons():
    """The CI gate, as a test: zero findings over src/tests/benchmarks and
    every suppression in the tree states why the historical bug does not
    apply at its site."""
    res = analysis.analyze_paths([str(ROOT / "src"), str(ROOT / "tests"),
                                  str(ROOT / "benchmarks")])
    assert res.ok, "invariant linter findings:\n" + \
        "\n".join(f.format() for f in res.findings)
    assert res.n_files > 50
    for s in res.suppressed:
        assert s["reason"].strip(), f"reasonless suppression at {s}"


def test_seeded_bug_fails_gate_naming_rule_and_origin(tmp_path):
    """Acceptance pin: re-shipping a historical bug (here PR 3's jnp
    lru_cache, in a file laid out like kernels/) turns the gate red with a
    message naming the rule and the original bug."""
    pkg = tmp_path / "src" / "repro" / "kernels"
    pkg.mkdir(parents=True)
    (pkg / "seeded.py").write_text(FIXTURES["tracer-leak"]["flag"][0])
    res = _run_cli(str(tmp_path / "src"))
    assert res.returncode == 1
    assert "[tracer-leak]" in res.stdout and "PR 3" in res.stdout
