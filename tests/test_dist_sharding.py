"""repro.dist tests: constrain round-trips under a dev mesh, no-op without
a mesh, unknown-axis rejection, rules-table registration, crossbar-batch
scheduling, and the collective byte ledger."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import run_in_subprocess_devices
from repro.dist import batching, collectives, sharding


# ---------------------------------------------------------------------------
# constrain: validation + no-mesh behavior (in-process, single device)
# ---------------------------------------------------------------------------

def test_constrain_noop_without_mesh():
    x = jnp.arange(12.0).reshape(3, 4)
    y = sharding.constrain(x, "batch", "model")
    assert y is x  # identity, not a copy: nothing to constrain against
    # and under jit it traces fine
    z = jax.jit(lambda v: sharding.constrain(v, "batch", None))(x)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x))


def test_constrain_rejects_unknown_logical_axis():
    x = jnp.zeros((2, 2))
    with pytest.raises(ValueError, match="unknown logical axis"):
        sharding.constrain(x, "bogus", None)
    with pytest.raises(ValueError, match="unknown logical axis"):
        sharding.logical_to_spec(("bogus", None), (2, 2), None)


def test_constrain_rejects_rank_mismatch():
    with pytest.raises(ValueError, match="rank"):
        sharding.constrain(jnp.zeros((2, 2)), "batch")


def test_rules_table_register_and_reset():
    try:
        sharding.register_rule("rows", "data")
        assert sharding.current_rules()["rows"] == ("data",)
        # now valid (still a no-op without a mesh)
        x = jnp.zeros((4,))
        assert sharding.constrain(x, "rows") is x
    finally:
        sharding.reset_rules()
    assert "rows" not in sharding.current_rules()
    with pytest.raises(ValueError):
        sharding.constrain(jnp.zeros((4,)), "rows")


def test_axis_rules_context_restores():
    before = sharding.current_rules()
    with sharding.axis_rules({"sp": ("data",)}):
        assert sharding.current_rules()["sp"] == ("data",)
    assert sharding.current_rules() == before
    with sharding.axis_rules({"only": ("model",)}, extend=False):
        assert set(sharding.current_rules()) == {"only"}
    assert sharding.current_rules() == before


# ---------------------------------------------------------------------------
# constrain under a real dev mesh (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.dist
def test_constrain_roundtrips_specs_under_dev_mesh():
    out = run_in_subprocess_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import sharding
from repro.launch.mesh import make_dev_mesh

mesh = make_dev_mesh(2, 4)

def check(shape, logical, want_spec):
    x = jnp.zeros(shape)
    with sharding.use_mesh(mesh):
        spec = sharding.logical_to_spec(logical, shape, mesh)
        assert spec == want_spec, (logical, spec, want_spec)
        y = jax.jit(lambda v: sharding.constrain(v, *logical))(x)
    want = NamedSharding(mesh, want_spec)
    assert y.sharding.is_equivalent_to(want, len(shape)), (
        logical, y.sharding, want)

# batch -> (pod, data): pod absent on the dev mesh, data kept
check((8, 32, 64), ("batch", None, "model"), P("data", None, "model"))
# sp rides the model axis
check((8, 32), ("batch", "sp"), P("data", "model"))
# non-dividing dim: model (4) does not divide 30 -> dropped
check((8, 30), ("batch", "model"), P("data", None))
# all rules resolve to absent axes -> spec degrades to fully-None and
# constrain skips the constraint entirely (identity)
with sharding.use_mesh(mesh):
    assert sharding.logical_to_spec(("pod", None), (8, 8), mesh) == P(None, None)
    x = jnp.zeros((8, 8))
    assert sharding.constrain(x, "pod", None) is x
# registered override takes effect inside the context
with sharding.axis_rules({"sp": ("data",)}):
    check((32, 8), (None, "sp"), P(None, "data"))
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_constrain_noop_on_trivial_mesh_inside_context():
    # a 1-device mesh is a no-op too (nothing to partition)
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.zeros((4,))
    with sharding.use_mesh(mesh):
        assert sharding.constrain(x, "batch") is x


# ---------------------------------------------------------------------------
# Crossbar-batch scheduler
# ---------------------------------------------------------------------------

def test_schedule_waves_math():
    ws = batching.schedule_waves(10, 4)
    assert (ws.waves, ws.tail) == (3, 2)
    assert ws.wave_sizes == (4, 4, 2)
    assert ws.utilization == pytest.approx(10 / 12)
    assert ws.latency(2.0) == 6.0
    assert ws.throughput(2.0) == pytest.approx(10 / 6.0)
    full = batching.schedule_waves(8, 4)
    assert full.utilization == 1.0 and full.waves == 2
    assert batching.schedule_waves(0, 4).waves == 0


def test_plan_crossbar_batch_without_mesh():
    plan = batching.plan_crossbar_batch(100, num_arrays=32)
    assert plan.waves == 4
    assert plan.utilization == pytest.approx(100 / (4 * 32))
    rep = plan.report()
    assert rep["n_devices"] == 1 and rep["tail"] == 4


def test_plan_crossbar_batch_on_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    plan = batching.plan_crossbar_batch(7, num_arrays=2, mesh=mesh)
    # pod axis absent -> only data used; 7 over 2 arrays = 4 waves, tail 1
    assert plan.mesh_plan.axes == ("data",)
    assert plan.waves == 4 and plan.wave.tail == 1
    assert plan.throughput(1.0) == pytest.approx(7 / 4)


def test_pim_batched_stats_consistent_with_closed_form():
    from repro.core.pim import FOURIERPIM_8, FP32, fft_throughput_per_s
    from repro.core.pim.fft_pim import batched_fft_stats
    from repro.core.pim.device_model import FULL_COMPLEX_BITS
    n = 2048
    arrays = int(FOURIERPIM_8.batch_capacity(n, FULL_COMPLEX_BITS)
                 * FOURIERPIM_8.concurrency)
    stats = batched_fft_stats(n, arrays, FOURIERPIM_8, FP32)
    # one full wave == the paper's steady-state throughput
    assert stats["waves"] == 1 and stats["utilization"] == 1.0
    assert stats["throughput_per_s"] == pytest.approx(
        fft_throughput_per_s(n, FOURIERPIM_8, FP32), rel=0.01)
    # a half-filled second wave halves utilization, not throughput math
    stats2 = batched_fft_stats(n, arrays + arrays // 2, FOURIERPIM_8, FP32)
    assert stats2["waves"] == 2
    assert stats2["utilization"] == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# Collective byte ledger
# ---------------------------------------------------------------------------

def test_ledger_records_wrapper_bytes():
    from repro.dist import compat
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.zeros((4, 8), jnp.float32)

    def f(v):
        v = collectives.psum(v, "data")
        v = collectives.all_to_all(v, "data", split_axis=1, concat_axis=0,
                                   tiled=True)
        return v

    fn = compat.shard_map(f, mesh=mesh,
                          in_specs=(jax.sharding.PartitionSpec("data"),),
                          out_specs=jax.sharding.PartitionSpec("data"),
                          check_vma=False)
    with collectives.ledger() as led:
        jax.jit(fn).lower(x)  # bytes are recorded at trace time
    assert led.bytes_by_kind["psum"] == 4 * 8 * 4
    assert led.bytes_by_kind["all-to-all"] == 4 * 8 * 4
    assert led.counts["psum"] == 1 and led.counts["all-to-all"] == 1
    assert led.total_bytes() == 2 * 4 * 8 * 4
    # outside the context nothing records
    jax.jit(fn).lower(x)
    assert led.total_bytes() == 2 * 4 * 8 * 4


@pytest.mark.dist
def test_distributed_fft_traffic_lands_in_ledger():
    out = run_in_subprocess_devices("""
import jax, jax.numpy as jnp
from repro.core.fft import distributed as dfft
from repro.dist import collectives
from repro.launch.mesh import make_dev_mesh

mesh = make_dev_mesh(2, 4)
x = jnp.zeros((4, 256), jnp.complex64)
with collectives.ledger() as led:
    jax.jit(dfft.make_sharded_fft(mesh)).lower(x)
# ordered forward transform = 3 all-to-all transposes of the local block,
# each moving the per-device (batch 4/2, seq 256/4) complex64 tile
assert led.counts["all-to-all"] == 3, led.counts
assert led.bytes_by_kind["all-to-all"] == 3 * 2 * 64 * 8, led.as_dict()
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_compressed_psum_leaf_single_axis_shapes():
    from repro.dist import compat
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(1).standard_normal((64,)),
                    jnp.float32)

    def f(gl, el):
        red, err = collectives.compressed_psum_leaf(gl, el, "pod")
        return red, err

    fn = compat.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                          out_specs=(P(), P()), check_vma=False)
    red, err = jax.jit(fn)(g, jnp.zeros_like(g))
    assert red.shape == g.shape and err.shape == g.shape
    # axis of size 1: mean == dequantized self, residual is the quant error
    np.testing.assert_allclose(np.asarray(red + err), np.asarray(g),
                               atol=1e-6)
    assert np.max(np.abs(np.asarray(err))) <= np.max(np.abs(np.asarray(g))) / 64


def test_batch_plan_helper_on_distributed_fft():
    from repro.core.fft import distributed as dfft
    mesh = jax.make_mesh((1,), ("data",))
    plan = dfft.batch_plan(mesh, 5)
    assert plan.mesh_plan.per_device == 5
    assert plan.report()["mesh_axes"] == ["data"]


def test_batch_plan_mesh_without_pod_axis():
    """batch_plan promises pod-level batching, but single-pod meshes have
    no "pod" axis: shard_batch must skip absent axes (not KeyError, not
    silently plan for 1 device) and the pad/utilization accounting must
    match the closed form. A stub mesh suffices — dist.batching is pure
    scheduling arithmetic over mesh.shape."""
    import math
    import types

    from repro.core.fft.distributed import batch_plan

    mesh = types.SimpleNamespace(shape={"data": 4, "model": 2})
    plan = batch_plan(mesh, 10, transforms_per_device=3)
    mp = plan.mesh_plan
    assert mp.axes == ("data",)              # pod absent -> skipped
    assert mp.n_devices == 4                 # model never carries batch
    assert mp.per_device == math.ceil(10 / 4) == 3
    assert mp.pad == 3 * 4 - 10 == 2
    assert mp.utilization == pytest.approx(10 / 12)
    # per-device waves: 3 transforms over 3 arrays = 1 full wave
    assert plan.waves == 1 and plan.wave.tail == 0
    assert plan.utilization == pytest.approx(10 / (4 * 1 * 3))
    assert plan.throughput(2.0) == pytest.approx(10 / 2.0)

    # pod axis present: both axes multiply into the device count
    pod_mesh = types.SimpleNamespace(shape={"pod": 2, "data": 4, "model": 2})
    pp = batch_plan(pod_mesh, 16, transforms_per_device=1)
    assert pp.mesh_plan.axes == ("pod", "data")
    assert pp.mesh_plan.n_devices == 8
    assert pp.mesh_plan.per_device == 2 and pp.mesh_plan.pad == 0
    assert pp.utilization == 1.0

    # and on a REAL mesh (the single-CPU case CI runs on)
    real_mesh = jax.make_mesh((1,), ("data",))
    rp = batch_plan(real_mesh, 5, transforms_per_device=2)
    assert rp.mesh_plan.axes == ("data",) and rp.mesh_plan.n_devices == 1
    assert rp.waves == 3 and rp.wave.tail == 1
    assert rp.utilization == pytest.approx(5 / 6)
