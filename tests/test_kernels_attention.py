"""Pallas flash-attention kernel vs naive oracle (interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.attention import attention_ref, flash_attention
from repro.models.layers.attention import blockwise_attention


def _qkv(rng, H, S, hd, dtype=jnp.float32):
    return tuple(jnp.asarray(rng.standard_normal((H, S, hd)), dtype)
                 for _ in range(3))


@pytest.mark.parametrize("S,bq,bk", [(64, 16, 16), (100, 32, 16),
                                     (128, 128, 64)])
@pytest.mark.parametrize("window", [1 << 30, 24])
def test_flash_kernel_matches_oracle(rng, S, bq, bk, window):
    q, k, v = _qkv(rng, 3, S, 32)
    got = flash_attention(q, k, v, window=window, bq=bq, bk=bk)
    want = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_bf16(rng):
    q, k, v = _qkv(rng, 2, 64, 32, jnp.bfloat16)
    got = flash_attention(q, k, v, bq=32, bk=32)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05, atol=0.05)


def test_flash_kernel_noncausal(rng):
    q, k, v = _qkv(rng, 2, 48, 16)
    got = flash_attention(q, k, v, causal=False, bq=16, bk=16)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_matches_model_blockwise(rng):
    """Kernel == the pure-JAX blockwise formulation the models use."""
    H, S, hd = 4, 64, 16
    q, k, v = _qkv(rng, H, S, hd)
    got = flash_attention(q, k, v, bq=16, bk=16)
    # blockwise_attention expects (B, S, H, hd) with GQA layout
    qb = jnp.swapaxes(q, 0, 1)[None]
    kb = jnp.swapaxes(k, 0, 1)[None]
    vb = jnp.swapaxes(v, 0, 1)[None]
    want = blockwise_attention(qb, kb, vb, window=1 << 30, kv_block=16)
    want = jnp.swapaxes(want[0], 0, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       S=st.sampled_from([16, 40, 64]),
       window=st.sampled_from([8, 1 << 30]))
def test_flash_kernel_property(seed, S, window):
    r = np.random.default_rng(seed)
    q, k, v = _qkv(r, 2, S, 16)
    got = flash_attention(q, k, v, window=window, bq=16, bk=16)
    want = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
