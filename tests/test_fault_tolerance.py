"""Fault-tolerance tier: resume-safe training, engine warm restart,
perf-trajectory ratchet (docs/fault_tolerance.md).

Unit half:
  * ``ft.checkpoint.save`` re-saving an existing step lands the FRESH
    arrays + extra (regression: os.rename onto an existing dir used to
    silently discard the new write);
  * restore into a tree the payload does not cover raises a KeyError that
    names the --compress-grads resume hazard;
  * ``StepWatchdog()`` instances do not share a config object (regression:
    mutable default), and the EWMA/event state round-trips state_dict;
  * a ``--compress-grads`` training checkpoint carries the error-feedback
    residual with its leading pod axis plus the watchdog/data-cursor
    ``extra``, and a resume with mismatched stream flags is REFUSED;
  * engine drain (submit raises EngineStopped, run() finishes the
    backlog), snapshot-with-pending refusal, warm-restart counter
    carry-over, watchdog-driven eviction and the elastic_restart path;
  * the trajectory ratchet: self-compare passes, slack-exceeding drift
    and dropped metrics are violations, history extends bounded.

Dist half (subprocess, forced host devices):
  * ``_restore_state`` places params/opt mesh-replicated and grad_err
    P("pod") across ALL devices (no silent device-0 landing);
  * THE kill-and-resume test: a --compress-grads run SIGKILLed mid-run
    and resumed from its checkpoint follows a loss trajectory
    bitwise-identical to an uninterrupted run.
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import REPO, SRC, run_in_subprocess_devices
from repro.ft import checkpoint as ckpt_lib
from repro.ft.watchdog import StepWatchdog, WatchdogConfig
from repro.launch.engine import LATENCY_WINDOW, EngineStopped, ServeEngine

sys.path.insert(0, REPO)
from benchmarks import trajectory  # noqa: E402


# ---------------------------------------------------------------------------
# ft.checkpoint: atomic re-save + payload/tree mismatch
# ---------------------------------------------------------------------------

def test_checkpoint_resave_overwrites(tmp_path):
    """Re-saving an existing step must land the fresh arrays and extra.

    Regression: ``os.rename(tmp, final)`` fails on an existing directory
    (errno ENOTEMPTY swallowed on some platforms / silently kept the OLD
    payload), so a periodic save followed by the final save at the same
    step resumed from stale state."""
    d = str(tmp_path)
    ckpt_lib.save(d, 5, {"w": jnp.zeros((3,))}, extra={"gen": 1})
    ckpt_lib.save(d, 5, {"w": jnp.full((3,), 7.0)}, extra={"gen": 2})
    _, restored = ckpt_lib.restore_latest(d, {"w": jnp.zeros((3,))})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full((3,), 7.0))
    assert ckpt_lib.read_extra(d, 5) == {"gen": 2}
    # no .tmp / .old.tmp remnants and exactly one listed step
    assert [n for n in os.listdir(d) if n.endswith(".tmp")] == []
    assert ckpt_lib.all_steps(d) == [5]


def test_restore_missing_key_names_the_hazard(tmp_path):
    """Restoring a tree the payload does not cover (the --compress-grads
    resume from a residual-less checkpoint) is a clear KeyError, not a
    silent zero-fill."""
    d = str(tmp_path)
    ckpt_lib.save(d, 1, {"params": jnp.ones((2,))})
    with pytest.raises(KeyError, match="grad_err"):
        ckpt_lib.restore(d, 1, {"params": jnp.ones((2,)),
                                "grad_err": jnp.zeros((2,))})


# ---------------------------------------------------------------------------
# StepWatchdog: config aliasing + checkpointable state
# ---------------------------------------------------------------------------

def test_watchdog_configs_not_shared():
    """Regression: ``cfg: WatchdogConfig = WatchdogConfig()`` evaluated the
    default ONCE, so tuning one watchdog's threshold retuned every other
    instance in the process."""
    a, b = StepWatchdog(), StepWatchdog()
    assert a.cfg is not b.cfg
    a.cfg.threshold = 99.0
    assert b.cfg.threshold == WatchdogConfig().threshold
    # an explicit cfg is used as-is
    cfg = WatchdogConfig(threshold=1.5)
    assert StepWatchdog(cfg).cfg is cfg


def test_watchdog_state_roundtrip_preserves_baseline():
    """A restored watchdog keeps its EWMA baseline and event log: the very
    next slow step is flagged without re-warming."""
    src = StepWatchdog(WatchdogConfig(warmup_steps=2, threshold=2.0))
    for step, dt in enumerate([0.1, 0.1, 0.1, 0.9]):
        src.observe(step, dt)
    assert len(src.events) == 1 and src.consecutive_flags == 1
    state = src.state_dict()
    assert json.loads(json.dumps(state)) == state  # manifest-serializable

    dst = StepWatchdog(WatchdogConfig(warmup_steps=2, threshold=2.0))
    dst.load_state_dict(state)
    assert dst.ewma == src.ewma and dst.seen == src.seen
    assert dst.events == src.events
    # past warmup from the restored baseline: a slow step flags immediately
    assert dst.observe(4, 0.9) is True
    # a fresh watchdog with the same history-free cfg would still be warming
    fresh = StepWatchdog(WatchdogConfig(warmup_steps=2, threshold=2.0))
    assert fresh.observe(4, 0.9) is False


# ---------------------------------------------------------------------------
# Training checkpoint payload: grad_err + manifest extra + stream guard
# ---------------------------------------------------------------------------

def _train(argv):
    from repro.launch import train as train_mod
    return train_mod.main(argv)


TRAIN_FLAGS = ["--arch", "qwen3-1.7b", "--smoke", "--batch", "4",
               "--seq", "16", "--seed", "3", "--mesh", "1x1x1",
               "--compress-grads"]


def test_train_checkpoint_carries_grad_err_and_extra(tmp_path):
    """The saved tree includes the error-feedback residual with its
    explicit leading pod axis, and the manifest ``extra`` carries the
    watchdog state + data-pipeline cursor."""
    ck = str(tmp_path / "ck")
    _train(TRAIN_FLAGS + ["--steps", "2", "--ckpt-dir", ck,
                          "--ckpt-every", "2"])
    step = ckpt_lib.latest_step(ck)
    assert step == 2
    man = ckpt_lib.read_manifest(ck, step)
    err_entries = [e for e in man["arrays"]
                   if e["key"].startswith("grad_err/")]
    assert err_entries, "checkpoint payload lost the grad_err residual"
    for e in err_entries:
        assert e["shape"][0] == 1, \
            f"{e['key']}: leading pod axis missing ({e['shape']})"
    param_keys = {e["key"].split("/", 1)[1] for e in man["arrays"]
                  if e["key"].startswith("params/")}
    err_keys = {e["key"].split("/", 1)[1] for e in err_entries}
    assert err_keys == param_keys  # one residual per gradient leaf

    extra = man["extra"]
    assert extra["compress_grads"] is True
    assert extra["data"] == {"next_step": 2, "seed": 3,
                             "global_batch": 4, "seq": 16}
    wd = extra["watchdog"]
    assert wd["seen"] == 2 and wd["ewma"] is not None


def test_train_resume_refuses_stream_mismatch(tmp_path):
    """Resuming with a different --seed would replay a DIFFERENT synthetic
    stream while pretending to continue — the cursor guard refuses."""
    ck = str(tmp_path / "ck")
    _train(TRAIN_FLAGS + ["--steps", "2", "--ckpt-dir", ck,
                          "--ckpt-every", "2"])
    bad = [v if v != "3" else "4" for v in TRAIN_FLAGS]
    with pytest.raises(RuntimeError, match="DIFFERENT stream"):
        _train(bad + ["--steps", "4", "--ckpt-dir", ck])


# ---------------------------------------------------------------------------
# ServeEngine: drain, snapshot, warm restart, eviction
# ---------------------------------------------------------------------------

def _cx(rng, n=64):
    return (rng.standard_normal(n) + 1j * rng.standard_normal(n)) \
        .astype(np.complex64)


def test_engine_stop_drains_backlog_then_rejects(rng):
    """request_stop stops ADMISSION (submit raises EngineStopped) but the
    already-admitted backlog is fully served before run() returns."""
    engine = ServeEngine(max_batch=4, max_pending=64)
    engine.register("fft", 64)
    for rid in range(6):
        engine.submit("fft", 64, _cx(rng), rid=rid)
    engine.request_stop()
    with pytest.raises(EngineStopped):
        engine.submit("fft", 64, _cx(rng))
    stats = engine.run(10_000)   # target unreachable: exit is the drain
    assert stats["served"] == 6
    assert set(engine.results) == set(range(6))


def test_engine_snapshot_refuses_pending(rng, tmp_path):
    engine = ServeEngine(max_batch=4, max_pending=64)
    engine.register("fft", 64)
    engine.submit("fft", 64, _cx(rng), rid=0)
    with pytest.raises(RuntimeError, match="pending"):
        engine.snapshot(str(tmp_path))
    engine.request_stop()
    engine.run(10_000)
    engine.snapshot(str(tmp_path))   # drained: allowed


def test_engine_warm_restart_carries_lifetime(rng, tmp_path):
    """snapshot -> from_snapshot: buckets re-registered, rid sequence and
    lifetime counters continue, latency record carried, restarts bumped."""
    d = str(tmp_path)
    engine = ServeEngine(max_batch=4, max_pending=64, model_shards=1)
    engine.register("fft", 64)
    engine.register("rfft", 128, strict=True)
    for _ in range(5):
        engine.submit("fft", 64, _cx(rng))
    engine.run(5)
    engine.request_stop()
    engine.run(10_000)
    engine.snapshot(d)

    eng2 = ServeEngine.from_snapshot(d)
    assert set(eng2._bound) == {("fft", 64), ("rfft", 128)}
    assert eng2._strict[("rfft", 128)] is True
    assert eng2.restarts == 1
    assert eng2._next_rid == 5      # rids stay unique across the restart
    for _ in range(3):
        eng2.submit("fft", 64, _cx(rng))
    stats = eng2.run(3)
    assert stats["served"] == 3                      # this-call view
    life = stats["lifetime"]
    assert life == {"served": 8, "batches": stats["batches"] + 2,
                    "restarts": 1}
    assert stats["buckets"]["fft/n=64"]["lifetime_served"] == 8
    assert len(eng2._prev_latencies_s) == 5   # latency record carried over
    assert stats["latency_ms"]["p50"] > 0

    # second generation: counters keep accumulating
    eng2.request_stop()
    eng2.run(10_000)
    eng2.snapshot(d)
    eng3 = ServeEngine.from_snapshot(d)
    assert eng3.restarts == 2
    assert eng3._prev_served == 8


def test_engine_snapshot_latency_record_plateaus(rng, tmp_path):
    """Regression: the snapshot used to persist the FULL per-request
    latency record, and every warm restart re-loaded and re-extended it —
    a long-lived restart loop grew the snapshot payload (and the
    percentile input) without bound. The snapshot now keeps only the most
    recent ``LATENCY_WINDOW`` samples, so across restart generations the
    persisted record PLATEAUS at the window size instead of growing."""
    d = str(tmp_path)
    engine = ServeEngine(max_batch=4, max_pending=64)
    engine.register("fft", 64)
    sizes = []
    for _ in range(3):
        engine.submit("fft", 64, _cx(rng))
        engine.run(1)
        # a long generation: far more samples than the window retains
        engine._latencies_s.extend([1e-4] * (LATENCY_WINDOW + 500))
        engine.request_stop()
        engine.run(10_000)
        engine.snapshot(d)
        engine = ServeEngine.from_snapshot(d)
        sizes.append(len(engine._prev_latencies_s))
    # every generation added ~LATENCY_WINDOW+501 samples; unbounded growth
    # would show ~3x the window by now
    assert sizes == [LATENCY_WINDOW] * 3
    # and the restarted engine still reports percentiles over the carry
    engine.submit("fft", 64, _cx(rng))
    stats = engine.run(1)
    assert stats["latency_ms"]["p50"] > 0


def test_cli_engine_elastic_resize(tmp_path):
    """--elastic end to end in a subprocess: injected stragglers trip the
    watchdog, the CLI drains + snapshots + warm-restarts the engine with
    --model-shards halved, and the second generation (chaos is armed only
    on the first) serves the remaining requests to completion.
    --max-pending is deliberately small so the producer still holds
    unsubmitted load when the eviction drain sheds it — the
    ``remaining > 0`` restart branch is the one under test."""
    d = str(tmp_path / "snap")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--service", "engine",
         "--ops", "fft", "--ns", "64", "--requests", "48", "--batch", "4",
         "--max-pending", "8", "--model-shards", "8",
         "--snapshot-dir", d, "--elastic",
         "--watchdog-threshold", "2.0", "--watchdog-evict-after", "2",
         "--watchdog-warmup", "2",
         "--inject-straggler-ms", "300", "--inject-straggler-after", "3"],
        env=env, capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    out = res.stdout
    assert "watchdog evicted batch" in out, out
    assert "elastic restart: model_shards 8 -> 4" in out, out
    # the full stream was served across both generations, and the final
    # snapshot records the elastic restart in the lifetime counters
    eng = ServeEngine.from_snapshot(d)
    assert eng._prev_served == 48
    assert eng.restarts == 2          # elastic + this from_snapshot


def test_engine_from_snapshot_rejects_foreign_checkpoint(tmp_path):
    """A train checkpoint dir is not an engine snapshot: schema-gated."""
    d = str(tmp_path)
    ckpt_lib.save(d, 3, {"params": jnp.ones((2,))}, extra={"data": {}})
    with pytest.raises(ValueError, match="schema"):
        ServeEngine.from_snapshot(d)
    with pytest.raises(FileNotFoundError):
        ServeEngine.from_snapshot(str(tmp_path / "empty"))


def test_engine_watchdog_eviction_and_elastic_restart(rng, tmp_path):
    """Synthetic slow batches trip the engine's watchdog; the on_evict hook
    fires with the engine, and elastic_restart produces a warm engine with
    the resized context and the watchdog baseline carried over."""
    hooked = []
    engine = ServeEngine(
        max_batch=4, max_pending=64,
        watchdog_cfg=WatchdogConfig(warmup_steps=2, threshold=2.0,
                                    evict_after=2),
        on_evict=lambda eng, idx: hooked.append((eng, idx)))
    engine.register("fft", 64)
    for i, dt in enumerate([0.1, 0.1, 0.1, 0.9, 0.9]):
        engine.watchdog.observe(i, dt)
    assert engine.evictions == [4]
    assert hooked and hooked[0][0] is engine and hooked[0][1] == 4

    engine.request_stop()
    engine.run(10_000)
    eng2 = engine.elastic_restart(str(tmp_path), max_batch=8)
    assert eng2.restarts == 1 and eng2.max_batch == 8
    assert eng2.watchdog.ewma == pytest.approx(engine.watchdog.ewma)
    assert len(eng2.watchdog.events) == len(engine.watchdog.events)
    assert eng2.watchdog.cfg.evict_after == 2     # cfg survives the restart
    # the restarted engine serves again
    eng2.submit("fft", 64, _cx(rng), rid=100)
    assert eng2.run(1)["served"] == 1


def test_cli_engine_sigterm_drains_and_snapshots(tmp_path):
    """SIGTERM mid-stream: the CLI drains the admitted backlog, snapshots,
    and exits 0. Also a regression pin for the handler deadlock — the
    handler must NOT take the engine's condition lock on the interrupted
    main thread (it hands request_stop to a separate thread), so a signal
    landing inside the scheduler's own `with cv` block cannot wedge."""
    d = str(tmp_path / "snap")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--service", "engine",
         "--ops", "fft", "--ns", "64", "--requests", "2000000",
         "--batch", "8", "--snapshot-dir", d],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    try:
        while True:
            line = proc.stdout.readline()
            assert line, "serve exited before the ready marker"
            if "serving 2000000 requests" in line:
                break
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=180)
    finally:
        proc.kill()
    assert proc.returncode == 0, out
    assert "snapshot ->" in out, out
    step = ckpt_lib.latest_step(d)
    assert step is not None
    eng = ServeEngine.from_snapshot(d)
    assert eng.restarts == 1 and eng._prev_served == step


def test_cli_engine_snapshot_warm_restart(tmp_path):
    """Two runs of the engine service CLI with the same --snapshot-dir:
    the second warm-restarts from the first's snapshot and the lifetime
    counters span both processes."""
    from repro.launch import serve
    d = str(tmp_path / "snap")
    argv = ["--service", "engine", "--ops", "fft,rfft", "--ns", "64",
            "--requests", "8", "--batch", "4", "--snapshot-dir", d]
    first = serve.main(argv)
    assert first["served"] == 8 and first["lifetime"]["restarts"] == 0
    assert ckpt_lib.latest_step(d) == 8

    second = serve.main(argv)
    assert second["served"] == 8
    assert second["lifetime"] == {"served": 16, "batches":
                                  first["batches"] + second["batches"],
                                  "restarts": 1}
    assert ckpt_lib.latest_step(d) == 16


# ---------------------------------------------------------------------------
# Perf-trajectory ratchet
# ---------------------------------------------------------------------------

def _bench(cycle=0.52, byte=0.55, tput=1.0e6, cycles=4096.0):
    return {
        "real_complex_cycle_ratio": {"1024": cycle},
        "dist_real_complex_byte_ratio": {"rfft": byte},
        "records": [
            {"op": "polymul", "n": 256, "throughput_per_s": tput,
             "pim_cycles": cycles},
            {"op": "fft", "n": 256, "throughput_per_s": 123.0},  # wall-clock
        ],
        "serve": {"p50_ms": 1.0, "p99_ms": 2.0},
        "gate": {"pass": True},
    }


def test_trajectory_metrics_exclude_wall_clock():
    m = trajectory.deterministic_metrics(_bench())
    assert set(m) == {"real_complex_cycle_ratio/n=1024",
                      "dist_real_complex_byte_ratio/rfft",
                      "pim_throughput/polymul/n=256",
                      "pim_cycles/polymul/n=256"}
    assert m["real_complex_cycle_ratio/n=1024"] == (0.52, "min")
    assert m["pim_throughput/polymul/n=256"] == (1.0e6, "max")


def test_trajectory_self_compare_and_slack():
    base = _bench()
    assert trajectory.compare(base, base) == []
    # drift inside the slack passes in both directions
    assert trajectory.compare(base, _bench(cycle=0.52 * 1.019,
                                           tput=1.0e6 * 0.981)) == []


def test_trajectory_flags_regressions_each_direction():
    base = _bench()
    worse_ratio = trajectory.compare(base, _bench(cycle=0.52 * 1.05))
    assert len(worse_ratio) == 1 \
        and "real_complex_cycle_ratio" in worse_ratio[0]
    worse_tput = trajectory.compare(base, _bench(tput=1.0e6 * 0.90))
    assert len(worse_tput) == 1 and "pim_throughput" in worse_tput[0]
    # an IMPROVEMENT in a min-metric never violates
    assert trajectory.compare(base, _bench(cycle=0.30)) == []


def test_trajectory_dropped_metric_is_a_violation():
    base = _bench()
    new = _bench()
    del new["dist_real_complex_byte_ratio"]["rfft"]
    v = trajectory.compare(base, new)
    assert len(v) == 1 and "missing from this run" in v[0]
    # a NEW metric with no baseline passes freely
    extra = _bench()
    extra["real_complex_cycle_ratio"]["2048"] = 0.5
    assert trajectory.compare(base, extra) == []


def test_trajectory_history_extends_and_caps():
    base = _bench()
    base["history"] = [{"utc": f"t{i}"} for i in range(trajectory
                                                      .HISTORY_CAP)]
    hist = trajectory.extend_history(base, _bench())
    assert len(hist) == trajectory.HISTORY_CAP
    assert hist[0] == {"utc": "t1"}          # oldest entry rolled off
    entry = hist[-1]
    assert entry["gate_pass"] is True
    assert entry["serve_ms"] == {"p50_ms": 1.0, "p99_ms": 2.0}
    assert entry["metrics"]["real_complex_cycle_ratio/n=1024"] == 0.52
    assert trajectory.extend_history(None, _bench())[0] is not None


def test_trajectory_cli_against_committed_baseline(tmp_path):
    """The CI re-check: a self-compare of the committed BENCH_fourier.json
    exits 0; an injected regression exits 1."""
    committed = trajectory.load_git("HEAD", cwd=REPO)
    if committed is None:
        pytest.skip("BENCH_fourier.json not committed at HEAD yet")
    cur = str(tmp_path / "BENCH_fourier.json")
    with open(cur, "w") as f:
        json.dump(committed, f)
    base = str(tmp_path / "base.json")
    with open(base, "w") as f:
        json.dump(committed, f)
    assert trajectory.main(["--current", cur, "--baseline", base]) == 0
    bad = dict(committed)
    bad["real_complex_cycle_ratio"] = {
        k: v * 1.2 for k, v in committed["real_complex_cycle_ratio"].items()}
    with open(cur, "w") as f:
        json.dump(bad, f)
    assert trajectory.main(["--current", cur, "--baseline", base]) == 1


# ---------------------------------------------------------------------------
# Dist half
# ---------------------------------------------------------------------------

@pytest.mark.dist
def test_restore_placement_on_multi_device_mesh():
    """``_restore_state`` must land params/opt replicated over the WHOLE
    mesh and grad_err sharded P("pod") — not unsharded on device 0 with an
    implicit first-step reshard (or, worse, a mixed-device jit error)."""
    run_in_subprocess_devices("""
        import argparse, jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import collectives, compat
        from repro.ft import checkpoint as ckpt_lib
        from repro.ft.watchdog import StepWatchdog
        from repro.launch import train as train_mod
        import tempfile, os

        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                axis_types=compat.axis_types_auto(3))
        params = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
        opt = {"m": jax.tree.map(jnp.zeros_like, params)}
        errs = jax.tree.map(
            lambda z: jnp.broadcast_to(z[None], (2, *z.shape)),
            collectives.zeros_like_errs(params))

        with tempfile.TemporaryDirectory() as d:
            saved = {"params": jax.tree.map(lambda x: x + 1.0, params),
                     "opt": jax.tree.map(lambda x: x + 2.0, opt),
                     "grad_err": jax.tree.map(lambda x: x + 3.0, errs)}
            ckpt_lib.save(d, 7, saved, extra={
                "data": {"next_step": 7, "seed": 0, "global_batch": 8,
                         "seq": 16}})
            args = argparse.Namespace(ckpt_dir=d, seed=0, batch=8, seq=16)
            wd = StepWatchdog()
            step0, p, o, e = train_mod._restore_state(
                args, mesh, params, opt, errs, wd)

        assert step0 == 7, step0
        n_dev = len(jax.devices())
        assert n_dev == 8, n_dev
        for name, tree, spec in (("params", p, P()), ("opt", o, P()),
                                 ("grad_err", e, P("pod"))):
            for leaf in jax.tree.leaves(tree):
                sh = leaf.sharding
                assert isinstance(sh, NamedSharding), (name, sh)
                assert sh.spec == spec, (name, sh.spec, spec)
                assert len(sh.device_set) == n_dev, (name, sh.device_set)
        np.testing.assert_array_equal(np.asarray(p["w"]),
                                      np.ones((4, 3)))
        np.testing.assert_array_equal(np.asarray(e["w"])[1],
                                      np.full((4, 3), 3.0))
        # pod-local residual: each pod's block restored independently
        assert np.asarray(e["w"]).shape == (2, 4, 3)
        print("PLACEMENT OK")
    """, n_devices=8)


KILL_FLAGS = ["--arch", "qwen3-1.7b", "--smoke", "--batch", "4",
              "--seq", "16", "--seed", "3", "--mesh", "2x1x1",
              "--compress-grads", "--steps", "12"]


def _spawn_train(extra, n_devices=2):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train"] + KILL_FLAGS + extra,
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)


def _parse_loss_log(path):
    """step -> hex loss; duplicate steps (re-run after resume) must agree
    BITWISE — that agreement is the resume-safety claim."""
    out = {}
    with open(path) as f:
        for line in f:
            step, hexval = line.split()
            if step in out:
                assert out[step] == hexval, \
                    f"step {step} diverged after resume: " \
                    f"{out[step]} vs {hexval}"
            out[step] = hexval
    return out


@pytest.mark.dist
def test_kill_and_resume_bitwise_identical(tmp_path):
    """THE acceptance test: SIGKILL a --compress-grads run mid-stream,
    resume from its last checkpoint, and the loss trajectory (logged as
    float.hex per step) is bitwise-identical to an uninterrupted run —
    params, opt state, the error-feedback residual, the watchdog baseline
    and the data cursor all survived the kill."""
    log_ref = str(tmp_path / "ref.log")
    proc = _spawn_train(["--loss-log", log_ref])
    out, _ = proc.communicate(timeout=600)
    assert proc.returncode == 0, out

    ck = str(tmp_path / "ck")
    log_kill = str(tmp_path / "kill.log")
    victim = _spawn_train(["--loss-log", log_kill, "--ckpt-dir", ck,
                           "--ckpt-every", "3"])
    try:
        deadline = time.time() + 300
        while ckpt_lib.latest_step(ck) is None:
            assert victim.poll() is None, \
                f"train exited before a checkpoint: " \
                f"{victim.communicate()[0]}"
            assert time.time() < deadline, "no checkpoint within 300s"
            time.sleep(0.05)
        victim.send_signal(signal.SIGKILL)
        victim.communicate(timeout=120)
    finally:
        victim.kill()
    assert victim.returncode == -signal.SIGKILL
    killed_at = ckpt_lib.latest_step(ck)
    assert killed_at is not None and killed_at < 12, \
        f"kill landed too late (ckpt step {killed_at}): nothing to resume"

    resume = _spawn_train(["--loss-log", log_kill, "--ckpt-dir", ck,
                           "--ckpt-every", "3"])
    out, _ = resume.communicate(timeout=600)
    assert resume.returncode == 0, out
    assert f"resumed from step {killed_at}" in out \
           and "(grad_err restored)" in out, out

    ref = _parse_loss_log(log_ref)
    got = _parse_loss_log(log_kill)   # asserts re-run steps agree bitwise
    assert set(ref) == {str(s) for s in range(12)}
    assert got == ref, "resumed trajectory diverged from uninterrupted run"
