"""Distribution-system tests (subprocess meshes): sharding invariance of
the loss, dry-run cell machinery on a small mesh, collective accounting."""
import json

from conftest import run_in_subprocess_devices


def test_loss_invariant_under_sharding():
    """Same params+batch give the same loss on 1 device and an 8-device
    (data x model) mesh — the sharding annotations change layout, not
    math."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import get_config
from repro.models import lm
from repro.launch import specs as S

cfg = get_config("qwen3-1.7b").scaled_down()
params = lm.init_params(cfg, jax.random.key(0))
batch = {
    "tokens": jax.random.randint(jax.random.key(1), (8, 32), 0,
                                 cfg.vocab_size),
    "labels": jax.random.randint(jax.random.key(2), (8, 32), 0,
                                 cfg.vocab_size),
}
loss_1dev = float(jax.jit(lambda p, b: lm.loss_fn(cfg, p, b))(params, batch))

mesh = jax.make_mesh((2, 4), ("data", "model"))
pspecs = S.sanitize_tree(lm.param_specs(cfg), params, mesh)
psh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                   is_leaf=lambda x: isinstance(x, P))
params_sh = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
bsh = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
       for k, v in batch.items()}
from repro.dist import compat
with compat.set_mesh(mesh):
    loss_8dev = float(jax.jit(lambda p, b: lm.loss_fn(cfg, p, b))(
        params_sh, bsh))
assert abs(loss_1dev - loss_8dev) < 2e-3, (loss_1dev, loss_8dev)
print("OK", loss_1dev, loss_8dev)
""", n_devices=8)
    assert "OK" in out


def test_dryrun_cell_small_mesh():
    """run_cell works end-to-end on a small (2,2,2) pod mesh: lower,
    compile, memory/cost/collective extraction."""
    out = run_in_subprocess_devices("""
from repro.dist import compat
from repro.launch.dryrun import run_cell

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"),
                        axis_types=compat.axis_types_auto(3))
res = run_cell("qwen3-1.7b", "decode_32k", mesh, verbose=False)
assert res["status"] == "ok", res
assert res["flops_per_device"] > 0
assert res["peak_bytes_per_device"] > 0
cb = res["collective_bytes"]
assert sum(v for k, v in cb.items() if k != "counts") > 0
res2 = run_cell("rwkv6-7b", "long_500k", mesh, verbose=False)
assert res2["status"] == "ok", res2
res3 = run_cell("llama3-405b", "long_500k", mesh, verbose=False)
assert res3["status"] == "skipped"
print("OK")
""", n_devices=8, timeout=900)
    assert "OK" in out


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[8]{0} all-reduce-start(%y), to_apply=%add
  %t = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
  %cp = u32[2]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 4
    assert out["all-reduce"] == 8 * 2
    assert out["all-to-all"] == 2 * 16 * 4
    assert out["collective-permute"] == 2 * 4
    assert out["counts"]["all-gather"] == 1
