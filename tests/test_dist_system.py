"""Distribution-system tests (subprocess meshes): sharding invariance of
the loss, dry-run cell machinery on a small mesh, collective accounting,
and the distributed four-step NTT's exactness + ledger parity."""
import json

import pytest

from conftest import run_in_subprocess_devices

pytestmark = pytest.mark.dist


def test_loss_invariant_under_sharding():
    """Same params+batch give the same loss on 1 device and an 8-device
    (data x model) mesh — the sharding annotations change layout, not
    math."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import get_config
from repro.models import lm
from repro.launch import specs as S

cfg = get_config("qwen3-1.7b").scaled_down()
params = lm.init_params(cfg, jax.random.key(0))
batch = {
    "tokens": jax.random.randint(jax.random.key(1), (8, 32), 0,
                                 cfg.vocab_size),
    "labels": jax.random.randint(jax.random.key(2), (8, 32), 0,
                                 cfg.vocab_size),
}
loss_1dev = float(jax.jit(lambda p, b: lm.loss_fn(cfg, p, b))(params, batch))

mesh = jax.make_mesh((2, 4), ("data", "model"))
pspecs = S.sanitize_tree(lm.param_specs(cfg), params, mesh)
psh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                   is_leaf=lambda x: isinstance(x, P))
params_sh = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
bsh = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
       for k, v in batch.items()}
from repro.dist import compat
with compat.set_mesh(mesh):
    loss_8dev = float(jax.jit(lambda p, b: lm.loss_fn(cfg, p, b))(
        params_sh, bsh))
assert abs(loss_1dev - loss_8dev) < 2e-3, (loss_1dev, loss_8dev)
print("OK", loss_1dev, loss_8dev)
""", n_devices=8)
    assert "OK" in out


def test_dryrun_cell_small_mesh():
    """run_cell works end-to-end on a small (2,2,2) pod mesh: lower,
    compile, memory/cost/collective extraction."""
    out = run_in_subprocess_devices("""
from repro.dist import compat
from repro.launch.dryrun import run_cell

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"),
                        axis_types=compat.axis_types_auto(3))
res = run_cell("qwen3-1.7b", "decode_32k", mesh, verbose=False)
assert res["status"] == "ok", res
assert res["flops_per_device"] > 0
assert res["peak_bytes_per_device"] > 0
cb = res["collective_bytes"]
assert sum(v for k, v in cb.items() if k != "counts") > 0
res2 = run_cell("rwkv6-7b", "long_500k", mesh, verbose=False)
assert res2["status"] == "ok", res2
res3 = run_cell("llama3-405b", "long_500k", mesh, verbose=False)
assert res3["status"] == "skipped"
print("OK")
""", n_devices=8, timeout=900)
    assert "OK" in out


def test_distributed_ntt_exact_and_ledger_parity_8dev():
    """Four-step NTT on an 8-virtual-device mesh: bit-exact (==) against
    the local reference/kernel, roundtrip identity, Z-order polymul
    cancellation, and the all-to-all byte ledger equal to the closed-form
    ``four_step_collective_stats`` — the TPU-side counter-parity contract
    (the CrossbarSim side lives in tests/test_pim_ntt.py)."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.ntt import ref
from repro.core.ntt import distributed as dntt
from repro.dist import collectives

mesh = jax.make_mesh((8,), ("data",))
n, B, D = 1024, 4, 8
params = ref.NTTParams.make(n)
rng = np.random.default_rng(0)
sh = NamedSharding(mesh, P(None, "data"))

x = rng.integers(0, params.q, size=(B, n)).astype(np.uint32)
xj = jax.device_put(jnp.asarray(x), sh)
y = np.asarray(jax.jit(dntt.make_sharded_ntt(mesh, params))(xj))
assert (y == ref.ntt(x, params).astype(np.uint32)).all(), "fwd != reference"

back = np.asarray(jax.jit(dntt.make_sharded_ntt(mesh, params, inverse=True))(
    jax.device_put(jnp.asarray(y), sh)))
assert (back == x).all(), "roundtrip != identity"

a = rng.integers(0, params.q, size=(B, n)).astype(np.uint32)
b = rng.integers(0, params.q, size=(B, n)).astype(np.uint32)
for nega in (True, False):
    c = np.asarray(jax.jit(dntt.make_sharded_ntt_polymul(
        mesh, params, negacyclic=nega))(
        jax.device_put(jnp.asarray(a), sh), jax.device_put(jnp.asarray(b), sh)))
    want = (ref.negacyclic_polymul if nega else ref.cyclic_polymul)(a, b, params)
    assert (c == want.astype(np.uint32)).all(), f"polymul nega={nega}"

# Also == the LOCAL Pallas kernel (not just the numpy reference).
from repro.kernels.ntt import ntt_polymul
local = np.asarray(ntt_polymul(jnp.asarray(a), jnp.asarray(b), params))
dist = np.asarray(jax.jit(dntt.make_sharded_ntt_polymul(mesh, params))(
    jax.device_put(jnp.asarray(a), sh), jax.device_put(jnp.asarray(b), sh)))
assert (dist == local).all(), "distributed != local kernel"

# Ledger parity: counts and bytes match the closed form per traced call.
spec = jax.ShapeDtypeStruct((B, n), jnp.uint32)
for op, build in (
        ("ntt", lambda: dntt.make_sharded_ntt(mesh, params)),
        ("intt", lambda: dntt.make_sharded_ntt(mesh, params, inverse=True)),
        ("polymul", lambda: dntt.make_sharded_ntt_polymul(mesh, params))):
    with collectives.ledger() as led:
        nargs = 2 if op == "polymul" else 1
        jax.jit(build()).lower(*([spec] * nargs))
    want = dntt.four_step_collective_stats(n, B, D, op=op)
    assert led.counts["all-to-all"] == want["count"], (op, led.as_dict())
    assert led.bytes_by_kind["all-to-all"] == want["bytes"], (op, led.as_dict())

# Z-order saves 1 of 3 transposes per transform: 6 for polymul, not 9.
pm = dntt.four_step_collective_stats(n, B, D, op="polymul")
fwd = dntt.four_step_collective_stats(n, B, D, op="ntt", ordered=True)
assert pm["count"] == 6 < 3 * fwd["count"]
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_serve_polymul_mod_distributed_8dev():
    """Serve endpoint for the planner's distributed exact tier: ``--op
    polymul-mod --model-shards 8`` dispatches ``core/ntt/distributed``
    (instead of raising or silently falling back to the local kernel), the
    route/plan record says so, and the served products are bit-exact (==)
    against the local fused kernel AND the end-to-end driver completes."""
    out = run_in_subprocess_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.launch import serve
from repro.core.ntt.ref import negacyclic_polymul
from repro.kernels.ntt import ntt_polymul

# Route + exactness through the service object.
svc = serve.FFTService(512, 2, "polymul-mod", model_shards=8)
assert svc.route == "polymul-mod-distributed", svc.route
assert svc.plan.tier == "distributed" and svc.plan.exact
assert svc.plan.seq_shards == 8
q = svc.ntt_params.q
rng = np.random.default_rng(0)
a = rng.integers(0, q, (2, 512)).astype(np.uint32)
b = rng.integers(0, q, (2, 512)).astype(np.uint32)
got = np.asarray(svc._fn(jnp.asarray(a), jnp.asarray(b)))
assert (got == negacyclic_polymul(a, b, svc.ntt_params).astype(np.uint32)).all()
local = np.asarray(ntt_polymul(jnp.asarray(a), jnp.asarray(b),
                               svc.ntt_params))
assert (got == local).all(), "distributed serve != local kernel"

# RNS + sequence sharding is rejected loudly (limbs shard, not sequences).
try:
    serve.FFTService(512, 2, "polymul-mod", modulus_bits=100, model_shards=8)
except ValueError:
    pass
else:
    raise AssertionError("RNS + model_shards should raise")

# End-to-end driver: queue -> batch -> distributed kernel -> results.
stats = serve.main(["--service", "fft", "--n", "512", "--batch", "2",
                    "--requests", "4", "--op", "polymul-mod",
                    "--model-shards", "8"])
assert stats["served"] == 4, stats
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[8]{0} all-reduce-start(%y), to_apply=%add
  %t = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
  %cp = u32[2]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 4
    assert out["all-reduce"] == 8 * 2
    assert out["all-to-all"] == 2 * 16 * 4
    assert out["collective-permute"] == 2 * 4
    assert out["counts"]["all-gather"] == 1
