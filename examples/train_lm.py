"""End-to-end driver: train a ~10M-param qwen3-family model for a few
hundred steps on CPU with the full production stack (synthetic sharded data
pipeline, prefetch, AdamW, checkpoints, watchdog, auto-resume).

Run:  PYTHONPATH=src python examples/train_lm.py
"""
from repro.launch import train

if __name__ == "__main__":
    losses = train.main([
        "--arch", "qwen3-1.7b", "--smoke",
        "--steps", "300", "--batch", "16", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_quickstart_ckpt", "--ckpt-every", "100",
    ])
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"final loss {losses[-1]:.3f} (from {losses[0]:.3f})")
