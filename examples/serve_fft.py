"""Batched FFT service — the paper's workload as a serving system.

Requests stream into a queue, are dynamically batched, and executed through
the Fourier core. Run:  PYTHONPATH=src python examples/serve_fft.py
"""
from repro.launch import serve

if __name__ == "__main__":
    stats = serve.main([
        "--service", "fft", "--op", "polymul",
        "--n", "2048", "--batch", "64", "--requests", "512",
    ])
    assert stats["served"] == 512
