"""Batched FFT serving — the paper's workload as a serving system.

Two tiers (docs/serving.md):

* single-op service: one (op, n) bucket, dynamic batching;
* mixed-op engine: a stream of requests each carrying its own (op, n),
  shape-bucketed and continuously batched from ONE process, with tail
  batches at actual size and p50/p99 latency in the stats.

Run:  PYTHONPATH=src python examples/serve_fft.py
"""
from repro.launch import serve

if __name__ == "__main__":
    # Single-op: the fused real polymul endpoint.
    stats = serve.main([
        "--service", "fft", "--op", "polymul-real",
        "--n", "2048", "--batch", "64", "--requests", "512",
    ])
    assert stats["served"] == 512

    # Mixed-op continuous batching: three ops x two lengths, one engine.
    stats = serve.main([
        "--service", "engine", "--ops", "fft,rfft,polymul-real",
        "--ns", "1024,2048", "--batch", "16", "--requests", "96",
    ])
    assert stats["served"] == 96
    assert len(stats["buckets"]) == 6
    for bucket in stats["buckets"].values():
        assert max(bucket["batch_sizes"]) <= 16   # tails never padded up
