"""Quickstart: the FourierPIM-on-TPU public API in 60 seconds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import fft as F

rng = np.random.default_rng(0)

# --- batched FFT (paper §4: the high-throughput batched primitive) --------
x = jnp.asarray(rng.standard_normal((8, 1024))
                + 1j * rng.standard_normal((8, 1024)), jnp.complex64)
X = F.fft(x)                       # Pallas kernel on TPU; XLA path on CPU
assert np.allclose(np.asarray(F.ifft(X)), np.asarray(x), atol=1e-4)
print("fft/ifft roundtrip ok:", X.shape)

# --- polynomial multiplication via the convolution theorem (paper §5) -----
a = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
b = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
c = F.polymul(a, b, mode="linear")          # degree-1022 product, length 1024
ref = np.stack([np.convolve(np.asarray(a)[i], np.asarray(b)[i])
                for i in range(4)])
assert np.allclose(np.asarray(c)[:, :1023], ref, atol=1e-2)
print("polymul (real packing, Eq. 10) matches direct convolution")

# --- two real FFTs for the price of one (paper Eq. 10) --------------------
xr = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
yr = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
Xk, Yk = F.realpack_fft(xr, yr)
assert np.allclose(np.asarray(Xk), np.fft.fft(np.asarray(xr)), atol=1e-3)
print("real-packed FFT ok")

# --- FFT causal long convolution (the model-layer integration) ------------
sig = jnp.asarray(rng.standard_normal((2, 1000)), jnp.float32)
taps = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
y = F.fft_causal_conv(sig, taps)
print("fft_causal_conv:", y.shape, "— O(S log S) token mixing primitive")

# --- planner: how a shape would execute on the production mesh ------------
for n in (4096, 1 << 19):
    plan = F.plan(n, batch=256, model_shards=16)
    print(f"n={n}: {plan.describe()}")
