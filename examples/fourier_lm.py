"""FourierPIM primitive as a sequence model: train an LM whose token mixer
is the paper's FFT causal convolution (O(S log S)) instead of attention,
and compare against an attention baseline of the same size.

Run:  PYTHONPATH=src python examples/fourier_lm.py
"""
from repro.launch import train

if __name__ == "__main__":
    print("--- Fourier-mixing LM (paper primitive as the mixer) ---")
    fourier_losses = train.main([
        "--arch", "fourierpim-lm", "--smoke",
        "--steps", "150", "--batch", "16", "--seq", "128"])

    print("--- attention baseline (same budget) ---")
    attn_losses = train.main([
        "--arch", "qwen3-1.7b", "--smoke",
        "--steps", "150", "--batch", "16", "--seq", "128"])

    print(f"fourier mixer: {fourier_losses[0]:.3f} -> "
          f"{fourier_losses[-1]:.3f}")
    print(f"attention    : {attn_losses[0]:.3f} -> {attn_losses[-1]:.3f}")
    assert fourier_losses[-1] < fourier_losses[0] - 0.5, \
        "fourier mixer must learn"
