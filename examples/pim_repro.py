"""Reproduce the paper's evaluation (Figures 5 and 6) on the crossbar
simulator + device models, and verify a simulated in-memory FFT against
numpy on random data (the paper's §6 correctness protocol).

Run:  PYTHONPATH=src python examples/pim_repro.py
"""
import numpy as np

from benchmarks import fft_pim_bench, polymul_pim_bench
from repro.core.pim import FOURIERPIM_8, FP32, pim_fft

# §6 correctness protocol: random input, compare to ground truth
rng = np.random.default_rng(0)
x = rng.standard_normal(8192) + 1j * rng.standard_normal(8192)
res = pim_fft(x, FOURIERPIM_8, FP32)
err = np.max(np.abs(res.output - np.fft.fft(x)))
print(f"simulator vs numpy.fft: max err {err:.2e} "
      f"({res.counters.cycles} cycles, "
      f"{res.counters.energy_j(FOURIERPIM_8) * 1e6:.1f} uJ)")
assert err < 1e-8

print("\n=== Figure 5 (FFT) ===")
fig5 = fft_pim_bench.run()
print("\n=== Figure 6 (polynomial multiplication) ===")
fig6 = polymul_pim_bench.run()

best = max(r["thr8_vs_3070"] for r in fig5.values())
print(f"\nheadline: up to {best:.1f}x FFT throughput vs RTX 3070 "
      f"(paper: 5-6x at these configs)")
